// Smoke: load spmm baseline + ell artifacts for er_s probe bucket, compare vs oracle.
use autosage::gen::preset;
use autosage::ops::{pack_inputs, reference, OpData};
use autosage::ops::pack::unpad_output;
use autosage::runtime::{Device, Manifest};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let m = Manifest::load(Path::new("artifacts"))?;
    println!("manifest entries: {}", m.entries.len());
    let dev = Device::cpu()?;
    println!("device: {} {}", dev.platform_name(), dev.signature());
    let (g, _) = preset("er_s", 42);
    let probe = g.probe_sample(512, 1);
    let f = 64usize;
    let b: Vec<f32> = (0..probe.n_rows * f).map(|i| ((i % 83) as f32) * 0.01).collect();
    let want = reference::spmm(&probe, &b, f);
    for name in ["spmm_base_er_s_probe_F64", "spmm_ell_r8_f32_er_s_probe_F64", "spmm_ell_r32_f32_er_s_probe_F64"] {
        let e = m.by_name(name).expect(name);
        let data = OpData::new().with("b", b.clone());
        let inputs = pack_inputs(e, &probe, &data)?;
        let t0 = std::time::Instant::now();
        let out = dev.run_f32(e, &inputs)?;
        let ms = t0.elapsed().as_secs_f64()*1e3;
        let out = unpad_output(out, e.param_usize("n_pad").unwrap(), probe.n_rows, f);
        let diff = reference::max_abs_diff(&out, &want);
        println!("{name}: diff={diff:.2e} first-run={ms:.1}ms");
        assert!(diff < 1e-3);
    }
    println!("runtime smoke OK");
    Ok(())
}
