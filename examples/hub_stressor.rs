//! Hub-skew stressor (paper §8.5 + Table 10): sweep hub-skew
//! configurations, compare the CTA-per-hub split against the vendor
//! baseline, and sweep the split threshold against the measured
//! heavy-row fraction (§8 Ablations, "Split threshold").
//!
//! ```bash
//! cargo run --release --example hub_stressor
//! ```

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::graph::ell::{auto_hub_threshold, HubSplit};
use autosage::scheduler::{InputFeatures, Op};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::from_env().map_err(anyhow::Error::msg)?;
    cfg.cache_path = String::new();
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, None)?;

    println!("== split vs baseline on hub-skewed graphs (F=128) ==");
    for (name, label) in [
        ("t10a", "N=2048, hub deg 512, other 64"),
        ("t10b", "N=2048, hub deg 1024, other 32"),
        ("hub_s", "N=4096, 15% hubs deg 512, other 4"),
    ] {
        let (g, _) = preset(name, 42);
        let b = sage.time_op(&g, Op::Spmm, 128, "baseline", 7, 2000.0)?;
        let s = sage.time_op(&g, Op::Spmm, 128, "hub_gather", 7, 2000.0)?;
        let d = sage.decide(&g, Op::Spmm, 128)?;
        println!(
            "{label}\n  baseline {:8.3}ms | split {:8.3}ms | speedup {:5.3}x | \
             scheduler picked: {}",
            b.median_ms,
            s.median_ms,
            b.median_ms / s.median_ms,
            d.choice.variant()
        );
    }

    println!("\n== split-threshold sweep vs heavy-row fraction (hub_s) ==");
    let (g, _) = preset("hub_s", 42);
    let auto_t = auto_hub_threshold(&g);
    println!("auto threshold (p99 degree): {auto_t}");
    for hub_t in [4usize, 8, 16, 64, 256] {
        let heavy = InputFeatures::heavy_fraction(&g, hub_t);
        // Feasibility of the catalog's hub bucket at this threshold:
        let fits = HubSplit::from_csr(&g, hub_t, 4096, hub_t.max(8), 1024, 512);
        match fits {
            Ok(hs) => println!(
                "  hub_t {hub_t:>4}: heavy-row fraction {heavy:.4} \
                 ({} hubs, light pad waste {:.1}%)",
                hs.n_hubs,
                100.0 * hs.light.pad_waste()
            ),
            Err(e) => println!(
                "  hub_t {hub_t:>4}: heavy-row fraction {heavy:.4} \
                 (bucket infeasible: {e})"
            ),
        }
    }

    println!("\n== guardrail view (hub_s, F sweep) ==");
    for f in [64usize, 128, 256] {
        let d = sage.decide(&g, Op::Spmm, f)?;
        println!(
            "  F={f:<4} choice={:<12} probe: baseline {:.3}ms best {:.3}ms",
            d.choice.variant(),
            d.t_baseline_ms,
            d.t_star_ms
        );
    }
    println!("hub_stressor OK");
    Ok(())
}
