//! CSR attention pipeline (paper §8.7): SDDMM → row-softmax → SpMM on
//! the Products-like graph, showing probe-dominated cold start vs
//! near-zero-overhead cached replay, with telemetry written to disk.
//!
//! ```bash
//! cargo run --release --example csr_attention
//! ```

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::{DecisionSource, Op};
use autosage::util::rng::Rng;
use autosage::util::timing::Stopwatch;

fn main() -> anyhow::Result<()> {
    let cache_path = std::env::temp_dir().join("autosage_attn_cache.json");
    let _ = std::fs::remove_file(&cache_path);
    let mut cfg = Config::from_env().map_err(anyhow::Error::msg)?;
    cfg.cache_path = cache_path.display().to_string();

    let telemetry_dir = Path::new("results/attention_telemetry");
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, Some(telemetry_dir))?;

    let (g, _) = preset("products_s", 42);
    let f = 64usize;
    let mut rng = Rng::new(99);
    let mut dense = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.next_f32() - 0.5) * 0.5).collect()
    };
    let (q, k, v) = (dense(g.n_rows * f), dense(g.n_rows * f), dense(g.n_rows * f));

    // Cold start: the decision probes candidates on the induced subgraph.
    let sw = Stopwatch::start();
    let d1 = sage.decide(&g, Op::Attention, f)?;
    let out = sage.attention_with(&g, &q, &k, &v, f, d1.choice.variant())?;
    println!(
        "cold : {:7.1}ms total (probe {:5.1}ms) choice={} source={:?}",
        sw.ms(),
        d1.probe_wall_ms,
        d1.choice.variant(),
        d1.source
    );

    // Verify numerics against the Rust oracle.
    let want = reference::csr_attention(&g, &q, &k, &v, f);
    let diff = reference::max_abs_diff(&out, &want);
    println!("max |Δ| vs oracle: {diff:.2e}");
    assert!(diff < 2e-3);

    // Warm replay: same (device, graph, F, op) key hits the cache.
    let sw = Stopwatch::start();
    let d2 = sage.decide(&g, Op::Attention, f)?;
    let _ = sage.attention_with(&g, &q, &k, &v, f, d2.choice.variant())?;
    println!(
        "warm : {:7.1}ms total (probe {:5.1}ms) choice={} source={:?}",
        sw.ms(),
        d2.probe_wall_ms,
        d2.choice.variant(),
        d2.source
    );
    assert_eq!(d2.source, DecisionSource::Cache);
    assert_eq!(d1.choice.variant(), d2.choice.variant());

    // Replay from a *fresh process* (simulated: new AutoSage instance,
    // same cache file) — the paper's deterministic replay mode.
    let mut cfg2 = Config::from_env().map_err(anyhow::Error::msg)?;
    cfg2.cache_path = cache_path.display().to_string();
    cfg2.replay_only = true;
    let mut sage2 = AutoSage::new(Path::new("artifacts"), cfg2, None)?;
    let d3 = sage2.decide(&g, Op::Attention, f)?;
    println!(
        "replay-only new process: choice={} source={:?}",
        d3.choice.variant(),
        d3.source
    );
    assert_eq!(d3.source, DecisionSource::Cache);
    assert_eq!(d3.choice.variant(), d1.choice.variant());

    let flushed = sage.telemetry.flush(sage.config())?;
    if let Some(p) = flushed {
        println!("telemetry: {} (+ .meta.json sidecar)", p.display());
    }
    let _ = std::fs::remove_file(&cache_path);
    println!("csr_attention OK");
    Ok(())
}
