//! End-to-end driver: a 2-layer GCN forward pass over the Products-like
//! graph where EVERY sparse aggregation goes through the AutoSAGE
//! coordinator service (request queue → scheduler → PJRT kernels), and
//! the dense transform runs as an AOT `linear_relu` artifact.
//!
//! Proves all layers compose: Rust coordinator (L3) → AOT jax graphs
//! (L2) → Pallas/XLA kernels (L1), Python nowhere at runtime. Reports
//! per-op and end-to-end latency for AutoSAGE vs all-baseline, and
//! checks numerics against the pure-Rust oracle. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example gcn_e2e
//! ```

use std::path::{Path, PathBuf};

use autosage::config::Config;
use autosage::coordinator::{AutoSage, ServiceHandle};
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::Op;
use autosage::util::rng::Rng;
use autosage::util::timing::Stopwatch;

const F: usize = 64; // feature width of both GCN layers

fn main() -> anyhow::Result<()> {
    let (g, _) = preset("products_s", 42);
    println!(
        "GCN-2 forward on products_s: {} rows, {} nnz, F={F}",
        g.n_rows,
        g.nnz()
    );

    // Model parameters (fixed seed — shared by both execution paths).
    let mut rng = Rng::new(4242);
    let h0: Vec<f32> = (0..g.n_rows * F).map(|_| rng.next_f32() - 0.5).collect();
    let w1: Vec<f32> = (0..F * F).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
    let b1: Vec<f32> = vec![0.01; F];
    let w2 = w1.clone();
    let b2 = b1.clone();

    // ---- oracle (pure Rust) --------------------------------------------
    let l1 = reference::gcn_layer(&g, &h0, F, &w1, F, &b1);
    let want = reference::gcn_layer(&g, &l1, F, &w2, F, &b2);

    // ---- direct facade: autosage vs baseline, timed --------------------
    let mut cfg = Config::from_env().map_err(anyhow::Error::msg)?;
    cfg.cache_path = String::new();
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, None)?;

    let mut forward = |sage: &mut AutoSage, variant: Option<&str>| -> anyhow::Result<(Vec<f32>, f64, Vec<String>)> {
        let sw = Stopwatch::start();
        let mut choices = Vec::new();
        let mut h = h0.clone();
        for (w, b) in [(&w1, &b1), (&w2, &b2)] {
            let agg = match variant {
                Some(v) => sage.spmm_with(&g, &h, F, v)?,
                None => {
                    let d = sage.decide(&g, Op::Spmm, F)?;
                    choices.push(d.choice.variant().to_string());
                    sage.spmm_with(&g, &h, F, d.choice.variant())?
                }
            };
            h = sage.linear_relu(&agg, g.n_rows, F, w, F, b)?;
        }
        Ok((h, sw.ms(), choices))
    };

    let (out_base, ms_base, _) = forward(&mut sage, Some("baseline"))?;
    // Cold: includes one probe (layer 2 hits the in-memory cache).
    let (out_auto_cold, ms_cold, choices) = forward(&mut sage, None)?;
    // Warm: both layers replay from cache.
    let (out_auto, ms_auto, _) = forward(&mut sage, None)?;

    let diff_base = reference::max_abs_diff(&out_base, &want);
    let diff_auto = reference::max_abs_diff(&out_auto, &want);
    println!("numerics: baseline |Δ| {diff_base:.2e}, autosage |Δ| {diff_auto:.2e}");
    assert!(diff_base < 2e-2 && diff_auto < 2e-2);
    let d_paths = reference::max_abs_diff(&out_auto, &out_auto_cold);
    assert!(d_paths < 1e-5, "cold/warm paths disagree: {d_paths}");

    println!("per-layer choices (cold pass): {choices:?}");
    println!(
        "end-to-end: all-baseline {ms_base:.1}ms | autosage cold {ms_cold:.1}ms \
         | autosage warm {ms_auto:.1}ms | warm speedup {:.3}x",
        ms_base / ms_auto
    );

    // ---- service-queue path (deployment shape) -------------------------
    println!("\nservice queue (worker thread owns the device):");
    let svc = ServiceHandle::spawn(PathBuf::from("artifacts"), {
        let mut c = Config::from_env().map_err(anyhow::Error::msg)?;
        c.cache_path = String::new();
        c
    });
    let sw = Stopwatch::start();
    let resp = svc.call(Op::Spmm, g.clone(), F, vec![("b".into(), h0.clone())])?;
    let first = sw.ms();
    let agg = resp.result?;
    assert_eq!(agg.len(), g.n_rows * F);
    let sw = Stopwatch::start();
    let resp2 = svc.call(Op::Spmm, g.clone(), F, vec![("b".into(), h0.clone())])?;
    let second = sw.ms();
    let _ = resp2.result?;
    println!(
        "  request 1 (cold, probes): {first:.1}ms  variant={}  cached={}",
        resp.variant, resp.from_cache
    );
    println!(
        "  request 2 (warm replay) : {second:.1}ms  variant={}  cached={}",
        resp2.variant, resp2.from_cache
    );
    assert!(resp2.from_cache, "second request must hit the schedule cache");
    println!("gcn_e2e OK");
    Ok(())
}
