use autosage::runtime::{Device, Manifest};
use autosage::ops::{pack_inputs, OpData};
use autosage::gen::preset;
use std::path::Path;
fn main() -> anyhow::Result<()> {
    let m = Manifest::load(Path::new("artifacts"))?;
    let dev = Device::cpu()?;
    let (g, _) = preset("er_s", 42);
    let sub = g.probe_sample(512, 1);
    let e = m.by_name("spmm_ellg_er_s_probe_F64").unwrap();
    let data = OpData::new().with("b", vec![0.5f32; 512*64]);
    let inputs = pack_inputs(e, &sub, &data)?;
    let exe = dev.load(e)?;
    let bufs = dev.upload(e, &inputs)?;
    let out = dev.execute_buffers(&exe, &bufs)?;
    let mut probe1 = [0f32; 1];
    match out.copy_raw_to_host_sync(&mut probe1, 0) {
        Ok(()) => println!("partial fetch works: {probe1:?}"),
        Err(e) => println!("partial fetch FAILS: {e}"),
    }
    // timing comparison
    let iters = 200;
    let t0 = std::time::Instant::now();
    for _ in 0..iters { let o = dev.execute_buffers(&exe, &bufs)?; dev.sync(&o)?; }
    println!("full-literal sync: {:.3}ms/iter", t0.elapsed().as_secs_f64()*1e3/iters as f64);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let o = dev.execute_buffers(&exe, &bufs)?;
        let mut p = [0f32; 1];
        if o.copy_raw_to_host_sync(&mut p, 0).is_err() { dev.sync(&o)?; }
    }
    println!("partial-fetch sync: {:.3}ms/iter", t0.elapsed().as_secs_f64()*1e3/iters as f64);
    Ok(())
}
