use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::scheduler::Op;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.cache_path = String::new();
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, None)?;
    for ds in ["er_s", "hub_s", "reddit_s", "products_s"] {
        let (g, _) = preset(ds, 42);
        for f in [64usize, 128] {
            print!("{ds} F={f}:");
            for v in ["baseline", "ell_gather", "hub_gather", "ell_r32_f32", "ell_r8_f128"] {
                match sage.time_op(&g, Op::Spmm, f, v, 5, 2000.0) {
                    Ok(t) => print!("  {v}={:.2}ms", t.median_ms),
                    Err(_) => print!("  {v}=n/a"),
                }
            }
            println!();
        }
    }
    Ok(())
}
