//! Quickstart: schedule and run one SpMM with AutoSAGE.
//!
//! ```bash
//! cargo run --release --example quickstart            # native backend
//! make artifacts && AUTOSAGE_BACKEND=pjrt \
//!   cargo run --release --features pjrt --example quickstart
//! ```
//!
//! Builds the ER stressor graph, lets the scheduler pick a kernel
//! (estimate → micro-probe → guardrail), runs it, and checks the result
//! against the pure-Rust oracle.

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::Op;
use autosage::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::from_env().map_err(anyhow::Error::msg)?;
    cfg.cache_path = String::new(); // keep the demo stateless

    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, None)?;
    println!(
        "backend: {} ({})",
        sage.backend_name(),
        sage.backend_signature()
    );

    // The paper's ER stressor (scaled): N=4096, avg degree 4.
    let (g, spec) = preset("er_s", 42);
    println!(
        "graph: {} ({} rows, {} nnz, max degree {})",
        spec.name, g.n_rows, g.nnz(), g.max_degree()
    );

    // Random dense features B: [n, F].
    let f = 64usize;
    let mut rng = Rng::new(7);
    let b: Vec<f32> = (0..g.n_rows * f).map(|_| rng.next_f32() - 0.5).collect();

    // 1. The scheduling decision (cache → estimate → probe → guardrail).
    let d = sage.decide(&g, Op::Spmm, f)?;
    println!(
        "decision: {} (variant {}) — probed baseline {:.3}ms, best {:.3}ms, \
         probe overhead {:.1}ms",
        d.choice_label(),
        d.choice.variant(),
        d.t_baseline_ms,
        d.t_star_ms,
        d.probe_wall_ms
    );

    // 2. Run C = A @ B through the chosen kernel.
    let c = sage.spmm_auto(&g, &b, f)?;

    // 3. Verify against the pure-Rust oracle.
    let want = reference::spmm(&g, &b, f);
    let diff = reference::max_abs_diff(&c, &want);
    println!("max |Δ| vs oracle: {diff:.2e}");
    assert!(diff < 1e-3, "kernel output mismatch");

    // 4. Compare full-graph latency: chosen vs vendor baseline.
    let tb = sage.time_op(&g, Op::Spmm, f, "baseline", 7, 2000.0)?;
    let tc = sage.time_op(&g, Op::Spmm, f, d.choice.variant(), 7, 2000.0)?;
    println!(
        "full graph: baseline {:.3}ms, chosen {:.3}ms, speedup {:.3}x",
        tb.median_ms,
        tc.median_ms,
        tb.median_ms / tc.median_ms
    );
    println!("quickstart OK");
    Ok(())
}
