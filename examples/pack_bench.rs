//! Micro-bench for the L3 packing hot path (EXPERIMENTS.md §Perf L3-2).
use autosage::gen::preset;
use autosage::ops::{pack_inputs, OpData};
use autosage::runtime::Manifest;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Packing cost is backend-independent; use the real artifact
    // manifest when present, else the synthetic catalog.
    let m = if Path::new("artifacts/manifest.json").exists() {
        Manifest::load(Path::new("artifacts"))?
    } else {
        Manifest::synthetic()
    };
    let (g, _) = preset("hub_s", 42);
    for name in ["spmm_ellg_hub_s_full_F128", "spmm_hubg_hub_s_full_F128",
                 "spmm_base_hub_s_full_F128"] {
        let e = m.by_name(name).unwrap();
        let data = OpData::new().with("b", vec![0.5f32; g.n_rows * 128]);
        // warmup
        let _ = pack_inputs(e, &g, &data)?;
        let iters = 20;
        let t0 = Instant::now();
        for _ in 0..iters {
            let t = pack_inputs(e, &g, &data)?;
            std::hint::black_box(&t);
        }
        println!("{name}: {:.3}ms/pack", t0.elapsed().as_secs_f64() * 1e3 / iters as f64);
    }
    Ok(())
}
