"""CSR attention pipeline (SDDMM -> row-softmax -> SpMM), Sec. 8.7."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import ell_to_coo, make_ell

TOL = dict(rtol=5e-4, atol=5e-4)


def _qkv(rng, n_pad, f):
    return (rng.standard_normal((n_pad, f)).astype(np.float32),
            rng.standard_normal((n_pad, f)).astype(np.float32),
            rng.standard_normal((n_pad, f)).astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), w=st.sampled_from([2, 4, 8]))
def test_fused_attention_matches_ref(seed, w):
    rng = np.random.default_rng(seed)
    n_pad, f = 128, 64
    colind, _, mask = make_ell(rng, n_pad, w)
    q, k, v = _qkv(rng, n_pad, f)
    (got,) = model.attention_fused(colind, mask, q, k, v, r=8, ft=32)
    want = np.asarray(ref.csr_attention(colind, mask, q, k, v))
    np.testing.assert_allclose(np.asarray(got), want, **TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_baseline_attention_matches_ref(seed):
    """Covers the ELL->COO slot-order compaction inside the baseline."""
    rng = np.random.default_rng(seed)
    n_pad, w, f = 64, 4, 32
    colind, _, mask = make_ell(rng, n_pad, w)
    nnz_pad = int(mask.sum()) + 13
    row, col, _ = ell_to_coo(colind, np.zeros_like(mask), mask, nnz_pad)
    q, k, v = _qkv(rng, n_pad, f)
    (got,) = model.attention_baseline(colind, mask, row, col, q, k, v)
    want = np.asarray(ref.csr_attention(colind, mask, q, k, v))
    np.testing.assert_allclose(np.asarray(got), want, **TOL)


def test_attention_rows_are_convex_combinations():
    """Each output row lies inside the convex hull of its neighbors' V."""
    rng = np.random.default_rng(4)
    n_pad, w, f = 64, 4, 32
    colind, _, mask = make_ell(rng, n_pad, w, density=1.0)
    q, k, v = _qkv(rng, n_pad, f)
    (got,) = model.attention_fused(colind, mask, q, k, v, r=8, ft=32)
    got = np.asarray(got)
    hi = v.max(axis=0, keepdims=True)
    lo = v.min(axis=0, keepdims=True)
    nonempty = mask.sum(axis=1) > 0
    assert np.all(got[nonempty] <= hi + 1e-4)
    assert np.all(got[nonempty] >= lo - 1e-4)
