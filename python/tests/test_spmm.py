"""SpMM kernels vs the dense oracle (the core correctness signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import baselines, ref, spmm_ell_rowtile, spmm_hub_split
from .conftest import ell_to_coo, make_ell

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("r,ft", [(8, 32), (32, 32), (8, 128)])
@pytest.mark.parametrize("n_pad,w,f", [(64, 8, 128), (128, 16, 128),
                                       (256, 4, 256)])
def test_spmm_ell_matches_ref(r, ft, n_pad, w, f):
    rng = np.random.default_rng(7)
    colind, val, mask = make_ell(rng, n_pad, w)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(spmm_ell_rowtile(colind, val, b, r=r, ft=ft))
    want = np.asarray(ref.spmm(colind, val, np.ones_like(mask), b))
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(5, 8),
    w=st.sampled_from([1, 2, 4, 8, 16]),
    f_mult=st.integers(1, 4),
    density=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_ell_hypothesis(log_n, w, f_mult, density, seed):
    """Shape/density sweep: the row-tile kernel equals the dense oracle."""
    rng = np.random.default_rng(seed)
    n_pad, f = 2 ** log_n, 32 * f_mult
    colind, val, mask = make_ell(rng, n_pad, w, density=density)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(spmm_ell_rowtile(colind, val, b, r=8, ft=32))
    want = np.asarray(ref.spmm(colind, val, np.ones_like(mask), b))
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.1, 1.0))
def test_spmm_baseline_scatter_matches_ref(seed, density):
    rng = np.random.default_rng(seed)
    n_pad, w, f = 128, 8, 64
    colind, val, mask = make_ell(rng, n_pad, w, density=density)
    row, col, v = ell_to_coo(colind, val, mask, nnz_pad=n_pad * w + 17)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(baselines.spmm_coo_scatter(row, col, v, b))
    want = np.asarray(ref.spmm(colind, val, mask, b))
    np.testing.assert_allclose(got, want, **TOL)


def test_spmm_zero_matrix():
    """All-padding input must produce exactly zero output."""
    n_pad, w, f = 64, 4, 32
    colind = np.zeros((n_pad, w), np.int32)
    val = np.zeros((n_pad, w), np.float32)
    b = np.ones((n_pad, f), np.float32)
    got = np.asarray(spmm_ell_rowtile(colind, val, b, r=8, ft=32))
    assert np.all(got == 0.0)


@pytest.mark.parametrize("ft", [32, 128])
def test_spmm_hub_split_matches_ref(ft):
    """Light+hub decomposition reproduces the unsplit aggregation."""
    rng = np.random.default_rng(3)
    n_pad, w_l, f = 256, 4, 128
    h_pad, w_h = 16, 64
    light_ci, light_v, light_m = make_ell(rng, n_pad, w_l)
    n_hub = 9
    hub_rows = np.zeros(h_pad, np.int32)
    hub_rows[:n_hub] = rng.choice(n_pad, n_hub, replace=False).astype(np.int32)
    hub_ci = rng.integers(0, n_pad, (h_pad, w_h)).astype(np.int32)
    hub_v = rng.standard_normal((h_pad, w_h)).astype(np.float32)
    hub_v[n_hub:] = 0.0  # padded hub rows contribute nothing
    # hub rows appear with zeroed slots in the light arrays
    light_ci[hub_rows[:n_hub]] = 0
    light_v[hub_rows[:n_hub]] = 0.0
    b = rng.standard_normal((n_pad, f)).astype(np.float32)

    got = np.asarray(spmm_hub_split(light_ci, light_v, hub_rows, hub_ci,
                                    hub_v, b, r=8, ft=ft))
    want = np.array(ref.spmm(light_ci, light_v, np.ones_like(light_m), b))
    hub_part = np.asarray(ref.spmm(hub_ci, hub_v,
                                   np.ones((h_pad, w_h), np.float32), b))
    for i in range(n_hub):
        want[hub_rows[i]] += hub_part[i]
    np.testing.assert_allclose(got, want, **TOL)


def test_spmm_hub_padded_rows_alias_row0_safely():
    """Padded hub entries scatter zeros into row 0 — must not corrupt it."""
    rng = np.random.default_rng(11)
    n_pad, w_l, f, h_pad, w_h = 64, 2, 32, 8, 16
    light_ci, light_v, _ = make_ell(rng, n_pad, w_l)
    hub_rows = np.zeros(h_pad, np.int32)      # ALL padded -> alias row 0
    hub_ci = np.zeros((h_pad, w_h), np.int32)
    hub_v = np.zeros((h_pad, w_h), np.float32)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(spmm_hub_split(light_ci, light_v, hub_rows, hub_ci,
                                    hub_v, b, r=8, ft=32))
    want = np.asarray(ref.spmm(light_ci, light_v,
                               np.ones_like(light_v), b))
    np.testing.assert_allclose(got, want, **TOL)
