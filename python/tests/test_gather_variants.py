"""Gather-family kernels (the grid-free executable twins) vs the oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import make_ell

TOL = dict(rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    log_n=st.integers(5, 8),
    w=st.sampled_from([1, 2, 4, 8, 16]),
    f=st.sampled_from([32, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_ell_gather_matches_ref(log_n, w, f, seed):
    rng = np.random.default_rng(seed)
    n_pad = 2 ** log_n
    colind, val, mask = make_ell(rng, n_pad, w)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    (got,) = model.spmm_ell_gather(colind, val, b)
    want = np.asarray(ref.spmm(colind, val, np.ones_like(mask), b))
    np.testing.assert_allclose(np.asarray(got), want, **TOL)


def test_spmm_hub_gather_matches_split_construction():
    rng = np.random.default_rng(8)
    n_pad, w_l, f, h_pad, w_h = 128, 4, 64, 8, 32
    light_ci, light_v, light_m = make_ell(rng, n_pad, w_l)
    n_hub = 5
    hub_rows = np.zeros(h_pad, np.int32)
    hub_rows[:n_hub] = rng.choice(n_pad, n_hub, replace=False).astype(np.int32)
    hub_ci = rng.integers(0, n_pad, (h_pad, w_h)).astype(np.int32)
    hub_v = rng.standard_normal((h_pad, w_h)).astype(np.float32)
    hub_v[n_hub:] = 0.0
    light_ci[hub_rows[:n_hub]] = 0
    light_v[hub_rows[:n_hub]] = 0.0
    b = rng.standard_normal((n_pad, f)).astype(np.float32)

    (got,) = model.spmm_hub_gather(light_ci, light_v, hub_rows, hub_ci, hub_v, b)
    want = np.array(ref.spmm(light_ci, light_v, np.ones_like(light_m), b))
    hub_part = np.asarray(
        ref.spmm(hub_ci, hub_v, np.ones((h_pad, w_h), np.float32), b))
    for i in range(n_hub):
        want[hub_rows[i]] += hub_part[i]
    np.testing.assert_allclose(np.asarray(got), want, **TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_attention_fused_gather_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n_pad, w, f = 64, 4, 32
    colind, _, mask = make_ell(rng, n_pad, w)
    q = rng.standard_normal((n_pad, f)).astype(np.float32)
    k = rng.standard_normal((n_pad, f)).astype(np.float32)
    v = rng.standard_normal((n_pad, f)).astype(np.float32)
    (got,) = model.attention_fused_gather(colind, mask, q, k, v)
    want = np.asarray(ref.csr_attention(colind, mask, q, k, v))
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-4, atol=5e-4)


def test_gather_and_pallas_spmm_agree():
    """The two kernel families are numerically interchangeable."""
    rng = np.random.default_rng(15)
    n_pad, w, f = 64, 8, 64
    colind, val, _ = make_ell(rng, n_pad, w)
    b = rng.standard_normal((n_pad, f)).astype(np.float32)
    (a,) = model.spmm_ell_gather(colind, val, b)
    (p,) = model.spmm_ell(colind, val, b, r=8, ft=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(p), **TOL)
