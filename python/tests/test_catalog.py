"""Catalog invariants: the shape contract the Rust side relies on."""

from compile import catalog


def test_names_unique():
    cat = catalog.build_catalog()
    names = [e.name for e in cat]
    assert len(names) == len(set(names))


def test_tile_divisibility():
    """Every Pallas instantiation obeys F % ft == 0 and n_pad % r == 0."""
    for e in catalog.build_catalog():
        p = e.params
        if "ft" in p:
            assert p["f"] % p["ft"] == 0, e.name
        if "r" in p:
            assert p["n_pad"] % p["r"] == 0, e.name


def test_wide_lane_requires_f_mod_128():
    """The vec analog: f128 variants only exist when F % 128 == 0."""
    for e in catalog.build_catalog():
        if "_f128" in e.variant:
            assert e.params["f"] % 128 == 0, e.name


def test_probe_buckets_exist_for_every_full_spmm_bucket():
    cat = catalog.build_catalog()
    def key(e):
        return (e.op, e.variant, e.params.get("preset"), e.params.get("f"))
    full = {key(e) for e in cat if "_full_" in e.name and e.op == "spmm"}
    probe = {key(e) for e in cat if "_probe_" in e.name and e.op == "spmm"}
    assert full == probe


def test_input_shapes_match_params():
    for e in catalog.build_catalog():
        p = e.params
        for (name, dtype, shape) in e.inputs:
            if name in ("colind", "val", "mask") and len(shape) == 2:
                assert shape[0] == p["n_pad"], e.name
                assert shape[1] in (p.get("w"), p.get("w_light")), e.name
            if name in ("row", "col") and e.op in ("spmm", "attention"):
                assert shape == [p["nnz_pad"]], e.name
            if name == "b":
                assert shape == [p["n_pad"], p["f"]], e.name
