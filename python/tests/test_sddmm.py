"""SDDMM kernel + gather-dot baseline vs the dense oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import baselines, ref, sddmm_ell_rowtile
from .conftest import make_ell

TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("r,ft", [(8, 32), (8, 128)])
@pytest.mark.parametrize("n_pad,w,f", [(64, 8, 128), (256, 16, 128)])
def test_sddmm_ell_matches_ref(r, ft, n_pad, w, f):
    rng = np.random.default_rng(5)
    colind, _, mask = make_ell(rng, n_pad, w)
    x = rng.standard_normal((n_pad, f)).astype(np.float32)
    y = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(sddmm_ell_rowtile(colind, mask, x, y, r=r, ft=ft))
    want = np.asarray(ref.sddmm(colind, mask, x, y))
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(5, 8),
    w=st.sampled_from([1, 2, 4, 8, 16]),
    f_mult=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_sddmm_hypothesis(log_n, w, f_mult, seed):
    """Feature-tile accumulation across the grid is exact for any F/ft."""
    rng = np.random.default_rng(seed)
    n_pad, f = 2 ** log_n, 32 * f_mult
    colind, _, mask = make_ell(rng, n_pad, w)
    x = rng.standard_normal((n_pad, f)).astype(np.float32)
    y = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(sddmm_ell_rowtile(colind, mask, x, y, r=8, ft=32))
    want = np.asarray(ref.sddmm(colind, mask, x, y))
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sddmm_baseline_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n_pad, w, f = 128, 8, 96
    colind, _, mask = make_ell(rng, n_pad, w)
    x = rng.standard_normal((n_pad, f)).astype(np.float32)
    y = rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(baselines.sddmm_gather_dot(colind, mask, x, y))
    want = np.asarray(ref.sddmm(colind, mask, x, y))
    np.testing.assert_allclose(got, want, **TOL)


def test_sddmm_padding_never_leaks():
    """Padded slots must be exactly zero regardless of gathered garbage."""
    rng = np.random.default_rng(1)
    n_pad, w, f = 64, 8, 64
    colind, _, mask = make_ell(rng, n_pad, w, density=0.3)
    x = 1e6 * rng.standard_normal((n_pad, f)).astype(np.float32)
    y = 1e6 * rng.standard_normal((n_pad, f)).astype(np.float32)
    got = np.asarray(sddmm_ell_rowtile(colind, mask, x, y, r=8, ft=32))
    assert np.all(got[mask == 0] == 0.0)
