"""Shared fixtures/strategies: random padded-ELL graphs and features."""

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def make_ell(rng, n_pad, w, density=0.6, skew=False):
    """Random padded ELL: (colind, val, mask), valid slots left-packed.

    Left-packing matches the Rust packer (CSR rows are contiguous), and
    exercises the same memory pattern the kernels see in production.
    """
    degs = rng.integers(0, w + 1, n_pad)
    if skew:
        hubs = rng.random(n_pad) < 0.1
        degs = np.where(hubs, w, rng.integers(0, max(w // 8, 1) + 1, n_pad))
    degs = np.minimum((degs * density).astype(np.int64) + (degs > 0), w)
    mask = (np.arange(w)[None, :] < degs[:, None]).astype(np.float32)
    colind = rng.integers(0, n_pad, (n_pad, w)).astype(np.int32)
    colind = np.where(mask > 0, colind, 0).astype(np.int32)
    val = rng.standard_normal((n_pad, w)).astype(np.float32) * mask
    return colind, val, mask


def ell_to_coo(colind, val, mask, nnz_pad):
    """Row-major compaction of valid slots -> padded COO (row, col, val)."""
    n_pad, w = colind.shape
    rows = np.repeat(np.arange(n_pad, dtype=np.int32), w)
    valid = mask.reshape(-1) > 0
    r, c, v = rows[valid], colind.reshape(-1)[valid], val.reshape(-1)[valid]
    nnz = r.shape[0]
    assert nnz <= nnz_pad, (nnz, nnz_pad)
    pad = nnz_pad - nnz
    return (np.concatenate([r, np.zeros(pad, np.int32)]),
            np.concatenate([c, np.zeros(pad, np.int32)]),
            np.concatenate([v, np.zeros(pad, np.float32)]))
