"""Masked row-softmax: correctness, stability, degenerate rows."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import baselines, ref, softmax_ell_rows
from .conftest import make_ell

TOL = dict(rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(4, 8),
    w=st.sampled_from([1, 2, 4, 8, 32]),
    scale=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_matches_ref(log_n, w, scale, seed):
    rng = np.random.default_rng(seed)
    n_pad = 2 ** log_n
    _, val, mask = make_ell(rng, n_pad, w)
    val = (val * scale).astype(np.float32)
    got = np.asarray(softmax_ell_rows(val, mask, r=8))
    want = np.asarray(ref.softmax_rows(val, mask))
    np.testing.assert_allclose(got, want, **TOL)


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(2)
    _, val, mask = make_ell(rng, 128, 16)
    got = np.asarray(softmax_ell_rows(val, mask, r=8))
    sums = got.sum(axis=1)
    nonempty = mask.sum(axis=1) > 0
    np.testing.assert_allclose(sums[nonempty], 1.0, rtol=1e-5)
    assert np.all(sums[~nonempty] == 0.0)


def test_softmax_huge_logits_stable():
    """exp overflow guard: max-subtraction keeps results finite."""
    val = np.array([[1e4, 1e4 - 1, 0.0, 0.0]], np.float32).repeat(8, axis=0)
    mask = np.array([[1, 1, 0, 0]], np.float32).repeat(8, axis=0)
    got = np.asarray(softmax_ell_rows(val, mask, r=8))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got[:, :2].sum(axis=1), 1.0, rtol=1e-5)
    assert np.all(got[:, 2:] == 0.0)


def test_softmax_fully_masked_row_is_zero_not_nan():
    val = np.full((8, 4), 5.0, np.float32)
    mask = np.zeros((8, 4), np.float32)
    got = np.asarray(softmax_ell_rows(val, mask, r=8))
    assert np.all(got == 0.0)


def test_softmax_baseline_equals_kernel():
    rng = np.random.default_rng(9)
    _, val, mask = make_ell(rng, 256, 8)
    a = np.asarray(softmax_ell_rows(val, mask, r=8))
    b = np.asarray(baselines.softmax_ell_jnp(val, mask))
    np.testing.assert_allclose(a, b, **TOL)
