"""Pure-jnp correctness oracles (the pytest ground truth).

Deliberately written in the most obvious dense form, with no shared code
with either the Pallas kernels or the XLA baselines, so a bug in those
cannot be mirrored here.
"""

import jax.numpy as jnp

_NEG = -1e30
_TINY = 1e-30


def ell_to_dense(colind, val, mask, n_cols):
    """Densify a padded ELL matrix -> f32[n_pad, n_cols]."""
    n_pad, w = colind.shape
    dense = jnp.zeros((n_pad, n_cols), val.dtype)
    rows = jnp.repeat(jnp.arange(n_pad), w)
    return dense.at[rows, colind.reshape(-1)].add((val * mask).reshape(-1))


def spmm(colind, val, mask, b):
    """Dense reference: densify A then matmul."""
    a = ell_to_dense(colind, val, mask, b.shape[0])
    return a @ b


def sddmm(colind, mask, x, y):
    """Dense reference: full XY^T then sample at the stored pattern."""
    full = x @ y.T  # (n_pad, n_pad)
    n_pad, w = colind.shape
    rows = jnp.repeat(jnp.arange(n_pad), w).reshape(n_pad, w)
    return full[rows, colind] * mask


def softmax_rows(val, mask):
    """Masked stable row softmax."""
    z = jnp.where(mask > 0, val, _NEG)
    mx = jnp.max(z, axis=1, keepdims=True)
    e = jnp.where(mask > 0, jnp.exp(z - mx), 0.0)
    s = jnp.sum(e, axis=1, keepdims=True)
    return e / jnp.maximum(s, _TINY)


def csr_attention(colind, mask, q, k, v):
    """SDDMM -> row softmax -> SpMM, all via the dense references."""
    scores = sddmm(colind, mask, q, k)
    attn = softmax_rows(scores, mask)
    return spmm(colind, attn, mask, v)
