"""Row-block ELL SDDMM Pallas kernel (paper: row-wise CSR dot-products).

For every stored edge (i, j):  out[i, slot] = <X_i, Y_j>, masked.

The feature dimension is tiled (same ``ft`` knob as SpMM) and the grid
*accumulates* partial dot products across feature tiles into the same
output block — the output BlockSpec maps every feature step to block
(i, 0), which Pallas treats as a revisited block (sequential grid), the
TPU analog of a warp keeping its partial sums in registers while it
strides the feature dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(ci_ref, x_ref, y_ref, o_ref):
    j = pl.program_id(1)
    ci = ci_ref[...]  # (r, w) int32
    x = x_ref[...]    # (r, ft)
    y = y_ref[...]    # (n_pad, ft)
    r, w = ci.shape
    ft = x.shape[1]
    g = jnp.take(y, ci.reshape(-1), axis=0).reshape(r, w, ft)
    part = jnp.einsum("rf,rwf->rw", x, g)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("r", "ft"))
def sddmm_ell_rowtile(colind, mask, x, y, *, r=8, ft=32):
    """out[i, s] = mask[i, s] * <x_i, y_colind[i, s]>.

    colind: i32[n_pad, w], mask: f32[n_pad, w],
    x, y: f32[n_pad, f] -> f32[n_pad, w]
    """
    n_pad, w = colind.shape
    f = x.shape[1]
    assert n_pad % r == 0, (n_pad, r)
    assert f % ft == 0, (f, ft)
    grid = (n_pad // r, f // ft)
    out = pl.pallas_call(
        _sddmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((r, ft), lambda i, j: (i, j)),
            pl.BlockSpec((n_pad, ft), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, w), x.dtype),
        interpret=True,
    )(colind, x, y)
    # Padded slots computed garbage dots against row 0 — mask them out.
    return out * mask
