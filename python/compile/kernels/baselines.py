"""Vendor-baseline ops (plain jnp / XLA-native, no Pallas).

These play the role of the paper's baselines:

  * ``spmm_coo_scatter``  <-> cuSPARSE CSR SpMM: the skew-immune,
    nnz-proportional vendor path (XLA scatter-add / segment-sum).
  * ``sddmm_gather_dot``  <-> the paper's explicit gather–dot SDDMM
    baseline (Sec. 6 "Baselines").
  * ``softmax_ell_jnp``   <-> plain-XLA masked row softmax.

The guardrail always has one of these as the fallback; candidates must
beat them through the micro-probe on the *same* device.
"""

import jax
import jax.numpy as jnp

_NEG = -1e30
_TINY = 1e-30


@jax.jit
def spmm_coo_scatter(row, col, val, b):
    """C = A @ B with A in padded COO form (pads: row=col=0, val=0).

    row, col: i32[nnz_pad], val: f32[nnz_pad], b: f32[n_pad, f].
    """
    contrib = val[:, None] * jnp.take(b, col, axis=0)  # (nnz_pad, f)
    out = jnp.zeros(b.shape, b.dtype)
    return out.at[row].add(contrib)


@jax.jit
def sddmm_gather_dot(colind, mask, x, y):
    """Gather–dot SDDMM over ELL: out[i,s] = mask * <x_i, y_colind[i,s]>."""
    n_pad, w = colind.shape
    g = jnp.take(y, colind.reshape(-1), axis=0).reshape(n_pad, w, -1)
    return jnp.einsum("nf,nwf->nw", x, g) * mask


@jax.jit
def softmax_ell_jnp(val, mask):
    """Masked stable row softmax (plain XLA)."""
    z = jnp.where(mask > 0, val, _NEG)
    mx = jnp.max(z, axis=1, keepdims=True)
    e = jnp.where(mask > 0, jnp.exp(z - mx), 0.0)
    s = jnp.sum(e, axis=1, keepdims=True)
    return e / jnp.maximum(s, _TINY)
