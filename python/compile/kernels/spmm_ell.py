"""Row-block ELL SpMM Pallas kernel (paper: warp-per-row template).

Grid step = (r rows) x (ft features).  The neighbor lists of an r-row
block are staged into VMEM via BlockSpec; the dense feature matrix B is
sliced along features only (on a real TPU the (n_pad, ft) B panel would
be streamed HBM->VMEM by the pipeline; the cost model in the Rust
scheduler charges for that traffic).

The "vec" variant is the same kernel instantiated with ft=128 (full VPU
lane width) and requires F % 128 == 0 -- the TPU analog of the paper's
vec4 alignment constraint (F % 4 == 0 and 16B alignment).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(ci_ref, v_ref, b_ref, o_ref):
    """One grid step: C[rows, fslice] = sum_w val * B[colind, fslice]."""
    ci = ci_ref[...]  # (r, w) int32
    v = v_ref[...]    # (r, w) f32
    b = b_ref[...]    # (n_pad, ft) f32
    r, w = ci.shape
    ft = b.shape[1]
    # Gather the neighbor feature rows: (r*w, ft) -> (r, w, ft).
    g = jnp.take(b, ci.reshape(-1), axis=0).reshape(r, w, ft)
    # Weighted reduction over the neighbor axis.
    o_ref[...] = jnp.einsum("rw,rwf->rf", v, g)


@functools.partial(jax.jit, static_argnames=("r", "ft"))
def spmm_ell_rowtile(colind, val, b, *, r=8, ft=32):
    """C = A @ B with A in padded ELL form.

    colind: i32[n_pad, w], val: f32[n_pad, w], b: f32[n_pad, f] -> f32[n_pad, f]
    """
    n_pad, w = colind.shape
    f = b.shape[1]
    assert n_pad % r == 0, (n_pad, r)
    assert f % ft == 0, (f, ft)
    grid = (n_pad // r, f // ft)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((r, w), lambda i, j: (i, 0)),
            pl.BlockSpec((n_pad, ft), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((r, ft), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f), b.dtype),
        interpret=True,
    )(colind, val, b)
