"""Numerically-stable masked row-softmax over ELL values (paper Sec. 4.1).

Used between SDDMM and SpMM in the CSR attention pipeline.  Stability:
subtract the per-row max of the *valid* slots; fully-padded rows produce
all-zero outputs (guarded denominator) rather than NaNs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30
_TINY = 1e-30


def _softmax_kernel(v_ref, m_ref, o_ref):
    v = v_ref[...]  # (r, w)
    m = m_ref[...]  # (r, w)
    z = jnp.where(m > 0, v, _NEG)
    mx = jnp.max(z, axis=1, keepdims=True)
    e = jnp.where(m > 0, jnp.exp(z - mx), 0.0)
    s = jnp.sum(e, axis=1, keepdims=True)
    o_ref[...] = e / jnp.maximum(s, _TINY)


@functools.partial(jax.jit, static_argnames=("r",))
def softmax_ell_rows(val, mask, *, r=8):
    """Row-wise softmax over valid slots. val, mask: f32[n_pad, w]."""
    n_pad, w = val.shape
    assert n_pad % r == 0, (n_pad, r)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(n_pad // r,),
        in_specs=[
            pl.BlockSpec((r, w), lambda i: (i, 0)),
            pl.BlockSpec((r, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((r, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, w), val.dtype),
        interpret=True,
    )(val, mask)
