"""Hub-split SpMM (paper: CTA-per-hub template).

Rows are partitioned by a degree threshold ``hub_t`` (Rust side,
``graph::ell::hub_partition``):

  * light rows  -> narrow ELL arrays (width w_l); hub rows appear with all
    slots zeroed, so the light kernel contributes 0 for them.
  * hub rows    -> a dedicated dense block: ``hub_rows: i32[h_pad]`` (row
    ids, pads -> 0), ``hub_colind/hub_val: [h_pad, w_h]``.

The light part reuses the row-tile kernel; the hub part gives every heavy
row its own grid step, tiling its (possibly huge) neighbor list through
VMEM in ``wc``-sized chunks — the TPU analog of dedicating a whole CTA to
one hub row.  Padded hub rows have val == 0, so scatter-adding their zero
contribution into row 0 is harmless.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spmm_ell import spmm_ell_rowtile


def _hub_kernel(ci_ref, v_ref, b_ref, o_ref):
    """One grid step: one hub row x one feature tile, looped over chunks."""
    ci = ci_ref[...]  # (1, w_h) int32
    v = v_ref[...]    # (1, w_h) f32
    b = b_ref[...]    # (n_pad, ft) f32
    ft = b.shape[1]
    w_h = ci.shape[1]
    # Chunk the neighbor list through VMEM: the analog of a CTA's warps
    # cooperatively streaming a hub row's neighbors.
    wc = min(w_h, 256)
    n_chunks = w_h // wc

    def body(c, acc):
        sl = jax.lax.dynamic_slice(ci, (0, c * wc), (1, wc)).reshape(-1)
        vv = jax.lax.dynamic_slice(v, (0, c * wc), (1, wc)).reshape(-1)
        g = jnp.take(b, sl, axis=0)  # (wc, ft)
        return acc + vv @ g

    acc = jax.lax.fori_loop(0, n_chunks, body, jnp.zeros((ft,), b.dtype))
    o_ref[...] = acc.reshape(1, ft)


@functools.partial(jax.jit, static_argnames=("ft",))
def _spmm_hub_part(hub_colind, hub_val, b, *, ft=32):
    """C_hub[h_pad, f]: per-hub-row aggregation (1 grid step per hub row)."""
    h_pad, w_h = hub_colind.shape
    n_pad, f = b.shape
    assert f % ft == 0, (f, ft)
    grid = (h_pad, f // ft)
    return pl.pallas_call(
        _hub_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w_h), lambda i, j: (i, 0)),
            pl.BlockSpec((1, w_h), lambda i, j: (i, 0)),
            pl.BlockSpec((n_pad, ft), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ft), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h_pad, f), b.dtype),
        interpret=True,
    )(hub_colind, hub_val, b)


@functools.partial(jax.jit, static_argnames=("r", "ft"))
def spmm_hub_split(light_colind, light_val, hub_rows, hub_colind, hub_val, b,
                   *, r=8, ft=32):
    """C = A @ B with A split into light-ELL + hub blocks.

    light_colind/light_val: [n_pad, w_l]; hub_rows: i32[h_pad];
    hub_colind/hub_val: [h_pad, w_h]; b: f32[n_pad, f] -> f32[n_pad, f]
    """
    c_light = spmm_ell_rowtile(light_colind, light_val, b, r=r, ft=ft)
    c_hub = _spmm_hub_part(hub_colind, hub_val, b, ft=ft)
    return c_light.at[hub_rows].add(c_hub)
