"""AutoSAGE L1 kernels (Pallas, interpret mode) and pure-jnp baselines.

All kernels operate on the padded ELL encoding of a CSR graph:

  colind : int32[n_pad, w]   column indices, padded slots -> 0
  val    : f32[n_pad, w]     edge values, padded slots -> 0.0
  mask   : f32[n_pad, w]     1.0 for real slots, 0.0 for padding

Padding with (col=0, val=0) makes SpMM correct without a mask (a zero
value contributes nothing); SDDMM and row-softmax take the explicit mask.

Variant knobs (the TPU analog of the paper's CUDA knobs, see
DESIGN.md "Hardware adaptation"):

  r  : rows per grid step        (warp-per-row  -> row-block)
  ft : feature tile              (vec4/scalar   -> lane width 128 vs 32)
  hub split                      (CTA-per-hub   -> dedicated hub kernel)
"""

from .spmm_ell import spmm_ell_rowtile
from .spmm_hub import spmm_hub_split
from .sddmm_ell import sddmm_ell_rowtile
from .softmax_ell import softmax_ell_rows
from . import baselines
from . import ref

__all__ = [
    "spmm_ell_rowtile",
    "spmm_hub_split",
    "sddmm_ell_rowtile",
    "softmax_ell_rows",
    "baselines",
    "ref",
]
