"""L2: the jax compute graphs that get AOT-lowered into artifacts.

Every public function here is a *variant entry point* the catalog can
instantiate at concrete shapes.  Each returns a 1-tuple so the Rust side
can uniformly unwrap with ``to_tuple1`` (the lowering uses
``return_tuple=True``).

Naming convention for SpMM/SDDMM variants mirrors the Rust scheduler's
candidate ids (``scheduler::estimate``):

  spmm.baseline_scatter    COO scatter-add           (vendor fallback)
  spmm.ell_r{R}_f{FT}      row-tile Pallas kernel    (warp-per-row analog)
  spmm.hub_r{R}_f{FT}      hub-split Pallas kernels  (CTA-per-hub analog)
  sddmm.baseline_gather    gather-dot                (vendor fallback)
  sddmm.ell_r{R}_f{FT}     row-tile Pallas kernel
  softmax.baseline / softmax.ell_r{R}
  attn.baseline / attn.fused_r{R}_f{FT}   SDDMM -> softmax -> SpMM
"""

import jax.numpy as jnp

from .kernels import (
    baselines,
    sddmm_ell_rowtile,
    softmax_ell_rows,
    spmm_ell_rowtile,
    spmm_hub_split,
)

# ---------------------------------------------------------------- SpMM


def spmm_baseline(row, col, val, b):
    """Vendor path: COO scatter-add (cuSPARSE stand-in)."""
    return (baselines.spmm_coo_scatter(row, col, val, b),)


def spmm_ell(colind, val, b, *, r, ft):
    return (spmm_ell_rowtile(colind, val, b, r=r, ft=ft),)


def spmm_hub(light_colind, light_val, hub_rows, hub_colind, hub_val, b, *, r, ft):
    return (
        spmm_hub_split(light_colind, light_val, hub_rows, hub_colind, hub_val,
                       b, r=r, ft=ft),
    )


def spmm_ell_gather(colind, val, b):
    """Whole-row ELL gather-sum (GE-SpMM-style coalesced row gather).

    No grid: XLA fuses gather + weighted reduction in one pass.  On a
    real TPU this is the limit case of the row-tile kernel with
    r = n_pad (one mega-block); on the CPU testbed it avoids the
    per-grid-step emulation overhead of interpret mode, so it is the
    Pallas templates' fast twin in the candidate space.
    """
    n_pad, w = colind.shape
    g = jnp.take(b, colind.reshape(-1), axis=0).reshape(n_pad, w, -1)
    return (jnp.einsum("nw,nwf->nf", val, g),)


def spmm_hub_gather(light_colind, light_val, hub_rows, hub_colind, hub_val, b):
    """Hub split built from whole-row gathers (CTA-per-hub analog)."""
    c = spmm_ell_gather(light_colind, light_val, b)[0]
    ch = spmm_ell_gather(hub_colind, hub_val, b)[0]
    return (c.at[hub_rows].add(ch),)


# --------------------------------------------------------------- SDDMM


def sddmm_baseline(colind, mask, x, y):
    return (baselines.sddmm_gather_dot(colind, mask, x, y),)


def sddmm_ell(colind, mask, x, y, *, r, ft):
    return (sddmm_ell_rowtile(colind, mask, x, y, r=r, ft=ft),)


# ------------------------------------------------------------- softmax


def softmax_baseline(val, mask):
    return (baselines.softmax_ell_jnp(val, mask),)


def softmax_ell(val, mask, *, r):
    return (softmax_ell_rows(val, mask, r=r),)


# ------------------------------------------------- CSR attention (8.7)


def attention_baseline(colind, mask, row, col, q, k, v):
    """All-vendor pipeline: gather-dot -> jnp softmax -> scatter SpMM.

    ``row``/``col`` are the COO copy of the pattern for the scatter SpMM;
    the softmax output is scattered into the COO value slots by (row-major)
    slot order, which the Rust packer guarantees matches.
    """
    scores = baselines.sddmm_gather_dot(colind, mask, q, k)
    attn = baselines.softmax_ell_jnp(scores, mask)
    coo_val = _ell_vals_to_coo(attn, mask, row.shape[0])
    return (baselines.spmm_coo_scatter(row, col, coo_val, v),)


def attention_fused(colind, mask, q, k, v, *, r, ft):
    """All-Pallas fused pipeline lowered as ONE artifact (no host hops)."""
    scores = sddmm_ell_rowtile(colind, mask, q, k, r=r, ft=ft)
    attn = softmax_ell_rows(scores, mask, r=r)
    return (spmm_ell_rowtile(colind, attn * mask, v, r=r, ft=ft),)


def attention_fused_gather(colind, mask, q, k, v):
    """Fused gather-kernel pipeline: one artifact, no scatter, no COO
    compaction — the fast twin of `attention_fused` (see
    `spmm_ell_gather`)."""
    scores = baselines.sddmm_gather_dot(colind, mask, q, k)
    attn = baselines.softmax_ell_jnp(scores, mask)
    return (spmm_ell_gather(colind, attn * mask, v)[0],)


def _ell_vals_to_coo(ell_val, mask, nnz_pad):
    """Compact ELL values to the COO slot order used by the Rust packer.

    The packer emits COO entries row-major by (row, slot); here we select
    the valid slots in the same order and pad with zeros.
    """
    flat = ell_val.reshape(-1)
    valid = mask.reshape(-1) > 0
    # Stable compaction: indices of valid slots in row-major order.
    order = jnp.argsort(~valid, stable=True)
    compacted = flat[order]
    return compacted[:nnz_pad] * 1.0


# ----------------------------------------------- dense helper for E2E


def linear_relu(h, w, b):
    """Dense transform for the GCN end-to-end example: relu(h @ w + b)."""
    return (jnp.maximum(h @ w + b, 0.0),)
