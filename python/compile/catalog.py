"""Artifact catalog: every (op, variant, shape-bucket) the system compiles.

XLA/PJRT executables are shape-static, so the scheduler picks among
pre-compiled *buckets*.  The shape contract below is shared with the Rust
generators (``rust/src/gen``): each preset's generator guarantees

  * max row degree   <= w_plain      (degree cap in the generator)
  * hub-row count    <= h_pad        (when a hub split is cataloged)
  * total nnz        <= nnz_pad

and the Rust bucketer (``graph::ell``) pads up to these shapes.  The
scheduler can also *cross-bucket*: any artifact whose (n_pad, w, f)
dominates the input is a legal candidate; padding waste is charged by the
roofline estimate.

Probe buckets (n_pad = 512) exist for every full bucket so the micro-probe
runs the *same variant* on the induced subgraph, as in the paper.
"""

from dataclasses import dataclass, field
from typing import Optional

PROBE_N = 512

# --------------------------------------------------------------- presets


@dataclass
class HubSpec:
    w_light: int          # ELL width of the light partition
    h_pad: int            # padded hub-row count (full graph)
    w_hub: int            # per-hub-row neighbor width
    h_pad_probe: int      # padded hub-row count at probe size


@dataclass
class Preset:
    """Shape contract for one named synthetic workload (see DESIGN §4)."""
    name: str
    n_pad: int            # padded row count
    w_plain: int          # plain-ELL width (== generator degree cap)
    nnz_pad: int          # padded COO length (vendor baseline input)
    nnz_pad_probe: int
    fs: list              # feature widths benchmarks sweep
    hub: Optional[HubSpec] = None
    sddmm_fs: list = field(default_factory=list)  # F values for SDDMM/attn


PRESETS = [
    # ER N=200k p=2e-5 (avg deg 4) scaled to N=4096, avg deg 4.
    # Hub spec here is a *narrow-bucket* split: rows with deg > 8 (the
    # Poisson tail, ~2%) go to the hub block so the light ELL stays at
    # w=8 instead of the full 32 — ER's analog of load-imbalance relief.
    Preset("er_s", n_pad=4096, w_plain=32, nnz_pad=32768, nnz_pad_probe=8192,
           fs=[32, 64, 128, 256], sddmm_fs=[64, 128],
           hub=HubSpec(w_light=8, h_pad=256, w_hub=32, h_pad_probe=64)),
    # Hub-skew N=200k k=4 h=0.15 scaled: N=4096, base deg 4, 15% hubs deg<=512.
    Preset("hub_s", n_pad=4096, w_plain=512, nnz_pad=524288,
           nnz_pad_probe=65536, fs=[64, 128, 256],
           hub=HubSpec(w_light=8, h_pad=1024, w_hub=512, h_pad_probe=128)),
    # Reddit (233k nodes, avg deg ~492) scaled: N=4096 power-law,
    # avg deg ~32, degree cap 256.
    Preset("reddit_s", n_pad=4096, w_plain=256, nnz_pad=262144,
           nnz_pad_probe=65536, fs=[32, 64, 96, 128, 192, 256],
           hub=HubSpec(w_light=128, h_pad=256, w_hub=256, h_pad_probe=64)),
    # OGBN-Products (2.4M nodes, avg deg ~50) scaled: N=8192 power-law,
    # avg deg ~16, degree cap 128.
    Preset("products_s", n_pad=8192, w_plain=128, nnz_pad=262144,
           nnz_pad_probe=32768, fs=[32, 64, 96, 128, 192, 256],
           hub=HubSpec(w_light=64, h_pad=256, w_hub=128, h_pad_probe=64),
           sddmm_fs=[64, 128]),
    # Table 10 row configs, scaled /10: hubs with fixed heavy degree.
    Preset("t10a", n_pad=2048, w_plain=512, nnz_pad=262144,
           nnz_pad_probe=65536, fs=[128],
           hub=HubSpec(w_light=64, h_pad=64, w_hub=512, h_pad_probe=32)),
    Preset("t10b", n_pad=2048, w_plain=1024, nnz_pad=131072,
           nnz_pad_probe=65536, fs=[128],
           hub=HubSpec(w_light=32, h_pad=64, w_hub=1024, h_pad_probe=32)),
]

# SpMM row-tile instantiations: (r, ft) pairs; ft=128 is the wide-lane
# ("vec") path and is only legal when F % 128 == 0.
SPMM_TILES = [(8, 32), (32, 32), (8, 128)]
HUB_TILES = [(8, 32), (8, 128)]
SDDMM_TILES = [(8, 32), (8, 128)]
SOFTMAX_R = 8

# ------------------------------------------------------------- entries


@dataclass
class Entry:
    """One artifact: a concrete (op, variant, shapes) instantiation."""
    name: str             # unique artifact id == file stem
    op: str               # spmm | sddmm | softmax | attention | linear_relu
    variant: str          # scheduler candidate id
    params: dict          # shape bucket + tile knobs (all ints)
    inputs: list          # [(name, dtype, shape), ...] in call order


def _spmm_entries(out, preset, n_pad, nnz_pad, h_pad, tag):
    p = preset
    for f in p.fs:
        base = dict(n_pad=n_pad, w=p.w_plain, f=f, preset=p.name)
        # Vendor baseline: COO scatter.
        out.append(Entry(
            f"spmm_base_{p.name}_{tag}_F{f}", "spmm", "baseline_scatter",
            dict(base, nnz_pad=nnz_pad),
            [("row", "s32", [nnz_pad]), ("col", "s32", [nnz_pad]),
             ("val", "f32", [nnz_pad]), ("b", "f32", [n_pad, f])]))
        # Whole-row gather kernel (grid-free; r = n_pad limit case).
        out.append(Entry(
            f"spmm_ellg_{p.name}_{tag}_F{f}", "spmm", "ell_gather",
            dict(base),
            [("colind", "s32", [n_pad, p.w_plain]),
             ("val", "f32", [n_pad, p.w_plain]),
             ("b", "f32", [n_pad, f])]))
        # Row-tile Pallas variants.
        for (r, ft) in SPMM_TILES:
            if f % ft != 0:
                continue
            out.append(Entry(
                f"spmm_ell_r{r}_f{ft}_{p.name}_{tag}_F{f}", "spmm",
                f"ell_r{r}_f{ft}", dict(base, r=r, ft=ft),
                [("colind", "s32", [n_pad, p.w_plain]),
                 ("val", "f32", [n_pad, p.w_plain]),
                 ("b", "f32", [n_pad, f])]))
        # Hub-split variants.
        if p.hub is not None:
            h = p.hub
            out.append(Entry(
                f"spmm_hubg_{p.name}_{tag}_F{f}", "spmm", "hub_gather",
                dict(base, w_light=h.w_light, h_pad=h_pad, w_hub=h.w_hub),
                [("light_colind", "s32", [n_pad, h.w_light]),
                 ("light_val", "f32", [n_pad, h.w_light]),
                 ("hub_rows", "s32", [h_pad]),
                 ("hub_colind", "s32", [h_pad, h.w_hub]),
                 ("hub_val", "f32", [h_pad, h.w_hub]),
                 ("b", "f32", [n_pad, f])]))
            for (r, ft) in HUB_TILES:
                if f % ft != 0:
                    continue
                out.append(Entry(
                    f"spmm_hub_r{r}_f{ft}_{p.name}_{tag}_F{f}", "spmm",
                    f"hub_r{r}_f{ft}",
                    dict(base, r=r, ft=ft, w_light=h.w_light,
                         h_pad=h_pad, w_hub=h.w_hub),
                    [("light_colind", "s32", [n_pad, h.w_light]),
                     ("light_val", "f32", [n_pad, h.w_light]),
                     ("hub_rows", "s32", [h_pad]),
                     ("hub_colind", "s32", [h_pad, h.w_hub]),
                     ("hub_val", "f32", [h_pad, h.w_hub]),
                     ("b", "f32", [n_pad, f])]))


def _sddmm_entries(out, preset, n_pad, tag):
    p = preset
    for f in p.sddmm_fs:
        base = dict(n_pad=n_pad, w=p.w_plain, f=f, preset=p.name)
        shp = [("colind", "s32", [n_pad, p.w_plain]),
               ("mask", "f32", [n_pad, p.w_plain]),
               ("x", "f32", [n_pad, f]), ("y", "f32", [n_pad, f])]
        out.append(Entry(f"sddmm_base_{p.name}_{tag}_F{f}", "sddmm",
                         "baseline_gather", base, shp))
        for (r, ft) in SDDMM_TILES:
            if f % ft != 0:
                continue
            out.append(Entry(
                f"sddmm_ell_r{r}_f{ft}_{p.name}_{tag}_F{f}", "sddmm",
                f"ell_r{r}_f{ft}", dict(base, r=r, ft=ft), shp))


def _softmax_entries(out, preset, n_pad, tag):
    p = preset
    if not p.sddmm_fs:
        return
    base = dict(n_pad=n_pad, w=p.w_plain, preset=p.name)
    shp = [("val", "f32", [n_pad, p.w_plain]),
           ("mask", "f32", [n_pad, p.w_plain])]
    out.append(Entry(f"softmax_base_{p.name}_{tag}", "softmax", "baseline",
                     base, shp))
    out.append(Entry(f"softmax_ell_r{SOFTMAX_R}_{p.name}_{tag}", "softmax",
                     f"ell_r{SOFTMAX_R}", dict(base, r=SOFTMAX_R), shp))


def _attention_entries(out, preset, n_pad, nnz_pad, tag):
    p = preset
    for f in p.sddmm_fs:
        base = dict(n_pad=n_pad, w=p.w_plain, f=f, preset=p.name)
        out.append(Entry(
            f"attn_base_{p.name}_{tag}_F{f}", "attention", "baseline",
            dict(base, nnz_pad=nnz_pad),
            [("colind", "s32", [n_pad, p.w_plain]),
             ("mask", "f32", [n_pad, p.w_plain]),
             ("row", "s32", [nnz_pad]), ("col", "s32", [nnz_pad]),
             ("q", "f32", [n_pad, f]), ("k", "f32", [n_pad, f]),
             ("v", "f32", [n_pad, f])]))
        out.append(Entry(
            f"attn_fgather_{p.name}_{tag}_F{f}", "attention", "fused_gather",
            base,
            [("colind", "s32", [n_pad, p.w_plain]),
             ("mask", "f32", [n_pad, p.w_plain]),
             ("q", "f32", [n_pad, f]), ("k", "f32", [n_pad, f]),
             ("v", "f32", [n_pad, f])]))
        for (r, ft) in SDDMM_TILES:
            if f % ft != 0:
                continue
            out.append(Entry(
                f"attn_fused_r{r}_f{ft}_{p.name}_{tag}_F{f}", "attention",
                f"fused_r{r}_f{ft}", dict(base, r=r, ft=ft),
                [("colind", "s32", [n_pad, p.w_plain]),
                 ("mask", "f32", [n_pad, p.w_plain]),
                 ("q", "f32", [n_pad, f]), ("k", "f32", [n_pad, f]),
                 ("v", "f32", [n_pad, f])]))


def _linear_entries(out):
    # Dense transform buckets for the GCN end-to-end example (products_s).
    for (n_pad, f_in, f_out) in [(8192, 64, 64), (8192, 128, 128),
                                 (8192, 128, 64), (8192, 64, 128)]:
        out.append(Entry(
            f"linear_relu_n{n_pad}_{f_in}x{f_out}", "linear_relu", "dense",
            dict(n_pad=n_pad, f_in=f_in, f_out=f_out),
            [("h", "f32", [n_pad, f_in]), ("w", "f32", [f_in, f_out]),
             ("bias", "f32", [f_out])]))


def build_catalog():
    """Enumerate every artifact Entry."""
    out = []
    for p in PRESETS:
        # Full-size buckets.
        h_pad = p.hub.h_pad if p.hub else 0
        _spmm_entries(out, p, p.n_pad, p.nnz_pad, h_pad, "full")
        _sddmm_entries(out, p, p.n_pad, "full")
        _softmax_entries(out, p, p.n_pad, "full")
        _attention_entries(out, p, p.n_pad, p.nnz_pad, "full")
        # Probe-size buckets (induced subgraph, min 512 rows).
        hp = p.hub.h_pad_probe if p.hub else 0
        _spmm_entries(out, p, PROBE_N, p.nnz_pad_probe, hp, "probe")
        _sddmm_entries(out, p, PROBE_N, "probe")
        _softmax_entries(out, p, PROBE_N, "probe")
        _attention_entries(out, p, PROBE_N, p.nnz_pad_probe, "probe")
    _linear_entries(out)
    names = [e.name for e in out]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return out


if __name__ == "__main__":
    cat = build_catalog()
    print(f"{len(cat)} artifacts")
    for e in cat[:10]:
        print(" ", e.name)
