"""AOT: lower every catalog entry to HLO text + write manifest.json.

HLO *text* (not ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
rust `xla` crate) rejects; the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Incremental: an artifact is re-lowered only if missing or if any source
in python/compile/ is newer (make drives this at the directory level; the
--force flag bypasses the per-file skip).

Usage: python -m compile.aot --out ../artifacts [--filter SUBSTR] [--force]
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import catalog, model

_DTYPES = {"f32": jnp.float32, "s32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    return_tuple=False: every artifact has exactly one output, and an
    array (non-tuple) root lets the Rust runtime fence timing loops with
    a 4-byte `copy_raw_to_host_sync` probe instead of materializing the
    whole output literal per iteration (EXPERIMENTS.md §Perf L3-1).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def entry_fn(e: catalog.Entry):
    """Resolve a catalog entry to the L2 function with knobs bound."""
    p = e.params
    op, v = e.op, e.variant
    if op == "spmm":
        if v == "baseline_scatter":
            return model.spmm_baseline
        if v == "ell_gather":
            return model.spmm_ell_gather
        if v == "hub_gather":
            return model.spmm_hub_gather
        if v.startswith("ell"):
            return functools.partial(model.spmm_ell, r=p["r"], ft=p["ft"])
        if v.startswith("hub"):
            return functools.partial(model.spmm_hub, r=p["r"], ft=p["ft"])
    if op == "sddmm":
        if v == "baseline_gather":
            return model.sddmm_baseline
        return functools.partial(model.sddmm_ell, r=p["r"], ft=p["ft"])
    if op == "softmax":
        if v == "baseline":
            return model.softmax_baseline
        return functools.partial(model.softmax_ell, r=p["r"])
    if op == "attention":
        if v == "baseline":
            return model.attention_baseline
        if v == "fused_gather":
            return model.attention_fused_gather
        return functools.partial(model.attention_fused, r=p["r"], ft=p["ft"])
    if op == "linear_relu":
        return model.linear_relu
    raise ValueError(f"unknown op/variant: {op}/{v}")


def lower_entry(e: catalog.Entry) -> str:
    specs = [jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dt])
             for (_, dt, shape) in e.inputs]
    fn = entry_fn(e)
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--filter", default="", help="only build matching names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cat = catalog.build_catalog()
    if args.filter:
        cat = [e for e in cat if args.filter in e.name]

    manifest = {"version": 1, "jax": jax.__version__, "entries": []}
    built = skipped = 0
    t0 = time.time()
    for i, e in enumerate(cat):
        path = os.path.join(args.out, e.name + ".hlo.txt")
        if args.force or not os.path.exists(path):
            text = lower_entry(e)
            with open(path, "w") as f:
                f.write(text)
            built += 1
        else:
            skipped += 1
        manifest["entries"].append({
            "name": e.name,
            "op": e.op,
            "variant": e.variant,
            "params": e.params,
            "path": e.name + ".hlo.txt",
            "inputs": [{"name": n, "dtype": d, "shape": s}
                       for (n, d, s) in e.inputs],
        })
        if (i + 1) % 50 == 0:
            print(f"  [{i + 1}/{len(cat)}] {time.time() - t0:.1f}s",
                  file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts: {built} built, {skipped} up-to-date, "
          f"{len(cat)} total in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
