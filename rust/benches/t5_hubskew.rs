//! Paper table 5 bench target (see README.md §Benchmarks). `harness = false`
//! because criterion is unavailable offline; bench_kit provides the
//! warmup/median/cap protocol.
fn main() {
    autosage::bench_kit::tables::bench_main("5");
}
