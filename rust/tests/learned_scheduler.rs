//! Learned-scheduler integration: training from real probe + audit
//! telemetry is byte-deterministic, a confident prediction skips the
//! micro-probe on a cold key, a forced misprediction stays oracle-safe,
//! a low-confidence prediction defers to the probe and is graded, and
//! degenerate inputs fail typed before any prediction runs.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::graph::Csr;
use autosage::model::{
    examples_from_audit, examples_from_cache, merge_and_cap, read_model, write_model,
    CostModel, Example, DEFAULT_MAX_DEPTH, TRAIN_EXAMPLE_CAP,
};
use autosage::obs::metrics::MetricsRegistry;
use autosage::ops::reference;
use autosage::scheduler::features::FEATURE_NAMES;
use autosage::scheduler::{entry_fits, probe, DecisionSource, EstimateError, Op};

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 3;
    cfg.probe_cap_ms = 300.0;
    cfg
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("autosage_learned_scheduler_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A model that predicts `label` for `op` with confidence 1.0 no matter
/// the input: one single-class example makes a pure leaf, and Laplace
/// smoothing over one class is (1+1)/(1+1).
fn constant_model(op: &str, label: &str) -> CostModel {
    let examples = vec![Example {
        op: op.to_string(),
        features: vec![1.0; FEATURE_NAMES.len()],
        label: label.to_string(),
    }];
    CostModel::train(&examples, &[], 1, DEFAULT_MAX_DEPTH).unwrap()
}

fn counter(reg: &Arc<MetricsRegistry>, name: &str) -> u64 {
    reg.counter(name).load(Ordering::Relaxed)
}

/// The first non-baseline spmm variant deployable on `g` — what a
/// correct (or deliberately wrong) model would be allowed to predict.
fn fitting_spmm_variants(sage: &AutoSage, g: &Csr, f: usize) -> Vec<String> {
    let mut out: Vec<String> = sage
        .manifest
        .candidates("spmm", Some(f), false)
        .into_iter()
        .filter(|e| e.variant != Op::Spmm.baseline_variant() && entry_fits(e, g))
        .map(|e| e.variant.clone())
        .collect();
    out.sort();
    out.dedup();
    out
}

/// Tentpole acceptance: mining the schedule cache + audit stream from
/// real probe runs and training twice under one seed produces
/// byte-identical `.asgm` files, and the round trip preserves the model.
#[test]
fn training_from_real_telemetry_is_byte_deterministic() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let reg = Arc::new(MetricsRegistry::new());
    sage.set_metrics(Some(reg.clone()));
    for &(name, op, f) in &[
        ("er_s", Op::Spmm, 64),
        ("hub_s", Op::Spmm, 64),
        ("er_s", Op::Spmm, 128),
        ("er_s", Op::Sddmm, 64),
    ] {
        let (g, _) = preset(name, 42);
        sage.decide(&g, op, f).unwrap();
    }

    // Both telemetry sources carry labeled rows after probe decisions.
    let from_cache = examples_from_cache(&sage.scheduler.cache);
    assert!(!from_cache.is_empty(), "probe resolutions must store features");
    let audit_jsonl: Vec<String> = reg
        .audit_snapshot()
        .iter()
        .map(|s| s.to_json().to_string())
        .collect();
    let from_audit = examples_from_audit(&audit_jsonl.join("\n")).unwrap();
    assert!(!from_audit.is_empty(), "probe outcomes must reach the audit stream");

    let examples = merge_and_cap(vec![from_cache, from_audit], TRAIN_EXAMPLE_CAP, 42);
    let a = CostModel::train(&examples, &[], 42, DEFAULT_MAX_DEPTH).unwrap();
    let b = CostModel::train(&examples, &[], 42, DEFAULT_MAX_DEPTH).unwrap();
    assert_eq!(a, b, "same telemetry + seed must train the same model");

    let pa = tmpfile("det_a.asgm");
    let pb = tmpfile("det_b.asgm");
    write_model(&pa, &a).unwrap();
    write_model(&pb, &b).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "model files must be byte-identical for CI content comparison"
    );
    let back = read_model(&pa).unwrap();
    assert_eq!(back, a);
    assert_eq!(back.seed, 42);
    let _ = std::fs::remove_file(&pa);
    let _ = std::fs::remove_file(&pb);
}

/// A confident prediction of a deployable variant decides a cold key
/// with zero probes, stores a feature-less cache entry (no self-training
/// feedback), and the deployed kernel still matches the oracle.
#[test]
fn confident_prediction_skips_probe_and_matches_oracle() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let reg = Arc::new(MetricsRegistry::new());
    sage.set_metrics(Some(reg.clone()));
    let (g, _) = preset("er_s", 42);
    let f = 64;
    let variant = fitting_spmm_variants(&sage, &g, f)
        .into_iter()
        .next()
        .expect("some non-baseline spmm artifact fits er_s");
    sage.set_model(Some(Arc::new(constant_model("spmm", &variant))));
    assert!(sage.has_model());

    let d = sage.decide(&g, Op::Spmm, f).unwrap();
    assert_eq!(d.source, DecisionSource::Model);
    assert_eq!(d.choice.variant(), variant);
    assert_eq!(d.probe_wall_ms, 0.0);
    assert!(d.features.is_none(), "model decisions carry no training features");
    assert_eq!(counter(&reg, "autosage_model_predictions_total"), 1);
    assert_eq!(counter(&reg, "autosage_scheduler_probes_total"), 0);
    assert!(
        examples_from_cache(&sage.scheduler.cache).is_empty(),
        "predicted cache entries must never become training examples"
    );

    // The predicted kernel computes the exact answer.
    let data = probe::synth_operands(Op::Spmm, g.n_rows, f, 42);
    let b = data.dense.get("b").unwrap();
    let out = sage.spmm_auto(&g, b, f).unwrap();
    let want = reference::spmm(&g, b, f);
    let diff = reference::max_abs_diff(&out, &want);
    assert!(diff < 1e-4, "predicted variant {variant}: max diff {diff}");
}

/// Forced misprediction: point the model at a deployable variant that is
/// NOT what the probe would pick. The scheduler commits to it (that is
/// the latency bet the confidence gate makes) but the output is still
/// oracle-exact — mispredictions cost time, never correctness.
#[test]
fn forced_misprediction_is_oracle_safe() {
    let f = 64;
    let (g, _) = preset("er_s", 42);

    // Ground truth from a model-free probe run.
    let mut oracle_sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let winner = oracle_sage
        .decide(&g, Op::Spmm, f)
        .unwrap()
        .choice
        .variant()
        .to_string();

    // Predict any deployable variant that is not the probe's winner
    // ("baseline" is always deployable, so a wrong pick always exists).
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let reg = Arc::new(MetricsRegistry::new());
    sage.set_metrics(Some(reg.clone()));
    let mut options = fitting_spmm_variants(&sage, &g, f);
    options.push("baseline".to_string());
    let wrong = options
        .into_iter()
        .find(|v| *v != winner)
        .expect("a deployable non-winner always exists");
    sage.set_model(Some(Arc::new(constant_model("spmm", &wrong))));

    let d = sage.decide(&g, Op::Spmm, f).unwrap();
    assert_eq!(d.source, DecisionSource::Model);
    assert_eq!(d.choice.variant(), wrong);
    assert_ne!(d.choice.variant(), winner);
    assert_eq!(counter(&reg, "autosage_scheduler_probes_total"), 0);

    let data = probe::synth_operands(Op::Spmm, g.n_rows, f, 42);
    let b = data.dense.get("b").unwrap();
    let out = sage.spmm_auto(&g, b, f).unwrap();
    let want = reference::spmm(&g, b, f);
    let diff = reference::max_abs_diff(&out, &want);
    assert!(diff < 1e-4, "mispredicted variant {wrong}: max diff {diff}");
}

/// Below the confidence gate the probe still runs and grades the
/// prediction: exactly one of agree/disagree increments, and the
/// decision is a full probe resolution carrying training features.
#[test]
fn low_confidence_prediction_defers_to_probe_and_is_graded() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let reg = Arc::new(MetricsRegistry::new());
    sage.set_metrics(Some(reg.clone()));
    // Two classes on identical features cannot split: the leaf holds
    // one example each, so confidence is (1+1)/(2+2) = 0.5 < 0.8.
    let examples = vec![
        Example {
            op: "spmm".to_string(),
            features: vec![1.0; FEATURE_NAMES.len()],
            label: "baseline".to_string(),
        },
        Example {
            op: "spmm".to_string(),
            features: vec![1.0; FEATURE_NAMES.len()],
            label: "zz_other".to_string(),
        },
    ];
    let model = CostModel::train(&examples, &[], 1, DEFAULT_MAX_DEPTH).unwrap();
    let pred = model.predict("spmm", &[2.0; 13]).unwrap();
    assert!((pred.confidence - 0.5).abs() < 1e-9, "{}", pred.confidence);
    sage.set_model(Some(Arc::new(model)));

    let (g, _) = preset("er_s", 42);
    let d = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(d.source, DecisionSource::Probe, "low confidence must probe");
    assert!(d.features.is_some(), "probe resolutions still feed the trainer");
    assert_eq!(counter(&reg, "autosage_model_predictions_total"), 0);
    assert_eq!(counter(&reg, "autosage_model_low_confidence_probes_total"), 1);
    assert_eq!(counter(&reg, "autosage_scheduler_probes_total"), 1);
    let agree = counter(&reg, "autosage_model_agree_total");
    let disagree = counter(&reg, "autosage_model_disagree_total");
    assert_eq!(agree + disagree, 1, "exactly one grading per deferred prediction");
}

/// Degenerate inputs hit the typed `EstimateError` before the model is
/// consulted — prediction never masks input validation.
#[test]
fn degenerate_input_fails_typed_before_prediction() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let reg = Arc::new(MetricsRegistry::new());
    sage.set_metrics(Some(reg.clone()));
    sage.set_model(Some(Arc::new(constant_model("spmm", "baseline"))));
    let rows: Vec<Vec<(u32, f32)>> = vec![vec![], vec![]];
    let empty = Csr::from_rows(2, rows);
    let err = sage.decide(&empty, Op::Spmm, 64).unwrap_err();
    assert!(
        err.chain().any(|c| c.downcast_ref::<EstimateError>().is_some()),
        "expected typed EstimateError, got: {err:#}"
    );
    assert_eq!(counter(&reg, "autosage_model_predictions_total"), 0);
    assert_eq!(counter(&reg, "autosage_model_low_confidence_probes_total"), 0);
}

/// `AUTOSAGE_MODEL` wiring: a model file on disk loads through the
/// config at construction; a missing file is a construction-time error,
/// not a silent no-model fallback.
#[test]
fn model_loads_via_config_path() {
    let path = tmpfile("via_config.asgm");
    write_model(&path, &constant_model("spmm", "baseline")).unwrap();
    let mut cfg = native_cfg();
    cfg.model_path = path.display().to_string();
    let sage = AutoSage::new(Path::new("x"), cfg, None).unwrap();
    assert!(sage.has_model());
    let _ = std::fs::remove_file(&path);

    let mut cfg = native_cfg();
    cfg.model_path = tmpfile("definitely_missing.asgm").display().to_string();
    assert!(AutoSage::new(Path::new("x"), cfg, None).is_err());
}
