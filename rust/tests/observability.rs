//! Integration: the flight recorder — run manifests survive key
//! reordering and reject tampering, one trace id spans loadgen →
//! shard → backend → reply for a coalesced batch, the perf gate
//! fails on a synthetic slowdown against the checked-in baselines,
//! and schedule-cache state (entries AND warm-only hit counters)
//! persists through pool shutdown.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use autosage::config::Config;
use autosage::gen::preset;
use autosage::obs::manifest::{canonical_hash, validate};
use autosage::obs::{compare, PerfProfile, RunManifest};
use autosage::obs::trace::Recorder;
use autosage::scheduler::{Op, ScheduleCache};
use autosage::server::{run_load_traced, LoadSpec, ServerPool};
use autosage::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("autosage_obs_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg(workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Keep debug-mode probes on 512-row subgraphs and short loops.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 200.0;
    cfg.serve_workers = workers;
    cfg
}

fn sample_manifest(dir: &Path) -> RunManifest {
    std::fs::write(dir.join("rows.csv"), "op,ms\nspmm,1.5\n").unwrap();
    let mut m = RunManifest::new(
        "run-obs-1",
        "bench",
        42,
        "native",
        Json::obj(vec![("alpha", Json::num(0.95))]),
    );
    m.add_graph("er_s", "cafe000000000000", 1000, 8000);
    m.add_metric("p50_ms", 1.25);
    m.add_metric("speedup", 1.4);
    m.add_artifact(dir, "rows.csv").unwrap();
    m
}

/// Serialize a parsed manifest with its top-level keys in REVERSE
/// order. The self-hash is defined over the canonical (sorted, compact)
/// form, so the physically reordered file must still validate.
fn reverse_key_order(parsed: &Json) -> String {
    let obj = parsed.as_obj().expect("manifest root is an object");
    let mut out = String::from("{");
    for (i, (k, v)) in obj.iter().rev().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}:{}", Json::Str(k.clone()), v);
    }
    out.push('}');
    out
}

#[test]
fn manifest_self_hash_is_stable_under_key_reordering() {
    let dir = tmp("reorder");
    let m = sample_manifest(&dir);
    let p = m.write(&dir).unwrap();
    let rep = validate(&p).unwrap();
    assert_eq!(rep.run_id, "run-obs-1");
    assert_eq!(rep.kind, "bench");
    assert_eq!(rep.n_artifacts, 1);

    let pretty = std::fs::read_to_string(&p).unwrap();
    let parsed = Json::parse(&pretty).unwrap();
    let scrambled = reverse_key_order(&parsed);
    assert_ne!(scrambled, pretty, "reordering must change the bytes");
    let reparsed = Json::parse(&scrambled).unwrap();
    assert_eq!(
        canonical_hash(&reparsed),
        canonical_hash(&parsed),
        "canonical hash must not depend on physical key order"
    );

    std::fs::write(&p, &scrambled).unwrap();
    let rep = validate(&p).unwrap();
    assert_eq!(rep.run_id, "run-obs-1");
}

#[test]
fn corrupted_manifests_are_rejected() {
    // A flipped metric value breaks the self-hash.
    let dir = tmp("tamper_metric");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    std::fs::write(&p, text.replace("1.25", "9.99")).unwrap();
    let err = validate(&p).unwrap_err();
    assert!(format!("{err:#}").contains("self-hash mismatch"), "{err:#}");

    // A rewritten artifact (same length) breaks its sha256.
    let dir = tmp("tamper_artifact");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    std::fs::write(dir.join("rows.csv"), "op,ms\nspmm,1.7\n").unwrap();
    let err = validate(&p).unwrap_err();
    assert!(format!("{err:#}").contains("sha256 mismatch"), "{err:#}");

    // A deleted artifact fails hashing outright.
    let dir = tmp("missing_artifact");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    std::fs::remove_file(dir.join("rows.csv")).unwrap();
    assert!(validate(&p).is_err());

    // A truncated file is not JSON at all.
    let dir = tmp("truncated");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    std::fs::write(&p, &text[..text.len() / 2]).unwrap();
    assert!(validate(&p).is_err());
}

/// The tentpole trace guarantee: for a coalesced batch, the leader's
/// trace id links the loadgen root `request` span, the shard `queue`
/// wait, the (single) `schedule` decision with its scheduler sub-spans,
/// the backend `execute`, and the `reply` event — end to end.
#[test]
fn one_trace_id_spans_loadgen_to_reply_for_a_coalesced_batch() {
    let mut c = cfg(1);
    c.serve_batch_max = 8;
    c.serve_batch_window_us = 300_000;
    let rec = Arc::new(Recorder::new("trace-it"));
    let pool = Arc::new(
        ServerPool::spawn_traced(PathBuf::from("artifacts"), c, Some(Arc::clone(&rec)))
            .unwrap(),
    );
    let spec = LoadSpec {
        clients: 4,
        requests_per_client: 1,
        f: 64,
        presets: vec!["er_s".into()],
        ops: vec![Op::Spmm],
        seed: 42,
        verify: true,
    };
    let report = run_load_traced(Arc::clone(&pool), &spec, Some(Arc::clone(&rec))).unwrap();
    assert_eq!(report.errors, 0, "{}", report.text);
    assert_eq!(report.mismatches, 0, "{}", report.text);
    assert_eq!(report.probes, 1, "{}", report.text);

    let spans = rec.snapshot();
    let names_of = |t| -> BTreeSet<&str> {
        spans
            .iter()
            .filter(|s| s.trace == t)
            .map(|s| s.name.as_str())
            .collect()
    };

    // Exactly one request span per client, each tracing through the
    // shard to execute + reply.
    let request_spans: Vec<_> = spans.iter().filter(|s| s.name == "request").collect();
    assert_eq!(request_spans.len(), 4);
    for r in &request_spans {
        assert!(r.parent.is_none(), "request is the root span");
        let names = names_of(r.trace);
        for n in ["queue", "execute", "reply"] {
            assert!(names.contains(n), "trace {} missing {n}: {names:?}", r.trace);
        }
    }

    // The cold batch leader's trace carries the full decision chain.
    let sched: Vec<_> = spans.iter().filter(|s| s.name == "schedule").collect();
    assert!(!sched.is_empty(), "no schedule span recorded");
    let cold = sched
        .iter()
        .find(|s| s.attrs.iter().any(|(k, v)| k == "source" && v == "probe"))
        .expect("one batch must schedule via probe");
    assert!(
        cold.attrs
            .iter()
            .any(|(k, v)| k == "batch_size" && v.parse::<usize>().unwrap() >= 1),
        "{:?}",
        cold.attrs
    );
    let names = names_of(cold.trace);
    for n in [
        "request",
        "queue",
        "schedule",
        "cache_miss",
        "estimate",
        "probe",
        "guardrail",
        "execute",
        "reply",
    ] {
        assert!(names.contains(n), "leader trace missing {n}: {names:?}");
    }
    // Scheduler sub-spans parent under the schedule span; the schedule
    // span parents under the loadgen root.
    let root = spans
        .iter()
        .find(|s| s.trace == cold.trace && s.name == "request")
        .unwrap();
    assert_eq!(cold.parent, Some(root.span));
    for n in ["estimate", "probe", "guardrail"] {
        let sub = spans
            .iter()
            .find(|s| s.trace == cold.trace && s.name == n)
            .unwrap();
        assert_eq!(sub.parent, Some(cold.span), "{n} must parent under schedule");
    }

    // JSONL flush: every line parses and carries the run id.
    let dir = tmp("jsonl");
    let p = rec.flush_jsonl(&dir.join("trace.jsonl")).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    assert_eq!(text.lines().count(), spans.len());
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("run_id").as_str(), Some("trace-it"));
    }
}

/// The checked-in BENCH_*.json baselines parse, self-compare clean, and
/// the gate demonstrably fails on a synthetic slowdown.
#[test]
fn perf_gate_fails_on_synthetic_slowdown() {
    let serve = PerfProfile::load(Path::new("benchmarks/BENCH_serve_smoke.json")).unwrap();
    assert_eq!(serve.name, "serve_bench");
    assert!(compare(&serve, &serve).passed());
    let bench = PerfProfile::load(Path::new("benchmarks/BENCH_bench_fixture.json")).unwrap();
    assert_eq!(bench.name, "bench");
    assert!(compare(&bench, &bench).passed());

    // Synthetic regression: throughput collapses 100x, p99 blows up
    // 100x — far beyond even the wide CI tolerances.
    let mut slow = serve.clone();
    let t = serve.metrics["throughput_rps"];
    slow.metrics.get_mut("throughput_rps").unwrap().value = t.value * 0.01;
    let p = serve.metrics["p99_ms"];
    slow.metrics.get_mut("p99_ms").unwrap().value = p.value * 100.0;
    let rep = compare(&serve, &slow);
    assert!(!rep.passed(), "gate must fail on a 100x slowdown");
    assert!(rep.regressions >= 2, "{}", rep.render("base", "slow"));
    assert!(rep.render("base", "slow").contains("REGRESSED"));

    // A dropped metric also fails (renames can't silently pass).
    let mut missing = serve.clone();
    missing.metrics.remove("probes");
    let rep = compare(&serve, &missing);
    assert!(!rep.passed());
    assert_eq!(rep.missing, 1);

    // Exact counters in the serve baseline gate the determinism
    // contract: the seeded smoke workload's totals are not noisy.
    for key in ["requests_total", "errors", "oracle_mismatches", "unique_keys"] {
        let m = serve.metrics[key];
        assert_eq!(m.tol_rel, 0.0, "{key} must gate exactly");
        let mut off = serve.clone();
        off.metrics.get_mut(key).unwrap().value = m.value + 1.0;
        assert!(!compare(&serve, &off).passed(), "{key} drift must fail");
    }
}

/// Satellites (a) + (c) end to end: probed decisions persist at pool
/// shutdown (not on the request path), and a warm-only second run still
/// flushes its hit counters to disk.
#[test]
fn cache_entries_and_warm_only_counters_persist_through_shutdown() {
    let dir = tmp("cache_persist");
    let path = dir.join("sched_cache.json");
    let mut c = cfg(1);
    c.cache_path = path.display().to_string();
    // Throttle far beyond the test's runtime: only the shutdown flush
    // may write, proving Drop persistence works.
    c.cache_flush_ms = 3_600_000;

    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c.clone()).unwrap());
    let (g, _) = preset("er_s", 17);
    let f = 64;
    let b = vec![0.5f32; g.n_rows * f];
    let r1 = pool
        .call(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
        .unwrap();
    assert!(r1.result.is_ok());
    assert!(!r1.from_cache, "first request must probe");
    assert!(
        !path.exists(),
        "persistence must be deferred off the request path (throttled)"
    );
    drop(pool);
    let cache = ScheduleCache::load(&path).unwrap();
    assert_eq!(cache.len(), 1, "probed decision must persist at shutdown");
    assert_eq!(cache.misses, 1);
    assert_eq!(cache.hits, 0);

    // Warm-only run: no inserts, only a counter mutation — it still
    // reaches disk (satellite: `autosage cache stats` stays accurate).
    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c).unwrap());
    let r2 = pool.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap();
    assert!(r2.result.is_ok());
    assert!(r2.from_cache, "decision must replay from the persisted cache");
    assert_eq!(r2.variant, r1.variant);
    drop(pool);
    let cache = ScheduleCache::load(&path).unwrap();
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.hits, 1, "warm-only hit counter must flush");
}
