//! Integration: the flight recorder — run manifests survive key
//! reordering and reject tampering, one trace id spans loadgen →
//! shard → backend → reply for a coalesced batch, the perf gate
//! fails on a synthetic slowdown against the checked-in baselines,
//! schedule-cache state (entries AND warm-only hit counters)
//! persists through pool shutdown, and the observability pipeline
//! end to end: head sampling is deterministic under a fixed seed,
//! `serve-bench --smoke --out` emits trace.jsonl / metrics.prom /
//! audit.jsonl that the `metrics` / `obs` / `manifest` CLI verbs all
//! accept, and rate-0 sampling still audits every request.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use autosage::config::Config;
use autosage::gen::preset;
use autosage::obs::manifest::{canonical_hash, validate};
use autosage::obs::metrics::validate_serving_snapshot;
use autosage::obs::report::{calibration_table, stage_breakdown};
use autosage::obs::{compare, AuditSample, MetricsRegistry, PerfProfile, RunManifest};
use autosage::obs::trace::Recorder;
use autosage::scheduler::{Op, ScheduleCache};
use autosage::server::{prometheus_snapshot, run_load_traced, LoadSpec, ServerPool};
use autosage::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("autosage_obs_it").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg(workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Keep debug-mode probes on 512-row subgraphs and short loops.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 200.0;
    cfg.serve_workers = workers;
    cfg
}

fn sample_manifest(dir: &Path) -> RunManifest {
    std::fs::write(dir.join("rows.csv"), "op,ms\nspmm,1.5\n").unwrap();
    let mut m = RunManifest::new(
        "run-obs-1",
        "bench",
        42,
        "native",
        Json::obj(vec![("alpha", Json::num(0.95))]),
    );
    m.add_graph("er_s", "cafe000000000000", 1000, 8000);
    m.add_metric("p50_ms", 1.25);
    m.add_metric("speedup", 1.4);
    m.add_artifact(dir, "rows.csv").unwrap();
    m
}

/// Serialize a parsed manifest with its top-level keys in REVERSE
/// order. The self-hash is defined over the canonical (sorted, compact)
/// form, so the physically reordered file must still validate.
fn reverse_key_order(parsed: &Json) -> String {
    let obj = parsed.as_obj().expect("manifest root is an object");
    let mut out = String::from("{");
    for (i, (k, v)) in obj.iter().rev().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}:{}", Json::Str(k.clone()), v);
    }
    out.push('}');
    out
}

#[test]
fn manifest_self_hash_is_stable_under_key_reordering() {
    let dir = tmp("reorder");
    let m = sample_manifest(&dir);
    let p = m.write(&dir).unwrap();
    let rep = validate(&p).unwrap();
    assert_eq!(rep.run_id, "run-obs-1");
    assert_eq!(rep.kind, "bench");
    assert_eq!(rep.n_artifacts, 1);

    let pretty = std::fs::read_to_string(&p).unwrap();
    let parsed = Json::parse(&pretty).unwrap();
    let scrambled = reverse_key_order(&parsed);
    assert_ne!(scrambled, pretty, "reordering must change the bytes");
    let reparsed = Json::parse(&scrambled).unwrap();
    assert_eq!(
        canonical_hash(&reparsed),
        canonical_hash(&parsed),
        "canonical hash must not depend on physical key order"
    );

    std::fs::write(&p, &scrambled).unwrap();
    let rep = validate(&p).unwrap();
    assert_eq!(rep.run_id, "run-obs-1");
}

#[test]
fn corrupted_manifests_are_rejected() {
    // A flipped metric value breaks the self-hash.
    let dir = tmp("tamper_metric");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    std::fs::write(&p, text.replace("1.25", "9.99")).unwrap();
    let err = validate(&p).unwrap_err();
    assert!(format!("{err:#}").contains("self-hash mismatch"), "{err:#}");

    // A rewritten artifact (same length) breaks its sha256.
    let dir = tmp("tamper_artifact");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    std::fs::write(dir.join("rows.csv"), "op,ms\nspmm,1.7\n").unwrap();
    let err = validate(&p).unwrap_err();
    assert!(format!("{err:#}").contains("sha256 mismatch"), "{err:#}");

    // A deleted artifact fails hashing outright.
    let dir = tmp("missing_artifact");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    std::fs::remove_file(dir.join("rows.csv")).unwrap();
    assert!(validate(&p).is_err());

    // A truncated file is not JSON at all.
    let dir = tmp("truncated");
    let p = sample_manifest(&dir).write(&dir).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    std::fs::write(&p, &text[..text.len() / 2]).unwrap();
    assert!(validate(&p).is_err());
}

/// The tentpole trace guarantee: for a coalesced batch, the leader's
/// trace id links the loadgen root `request` span, the shard `queue`
/// wait, the (single) `schedule` decision with its scheduler sub-spans,
/// the backend `execute`, and the `reply` event — end to end.
#[test]
fn one_trace_id_spans_loadgen_to_reply_for_a_coalesced_batch() {
    let mut c = cfg(1);
    c.serve_batch_max = 8;
    c.serve_batch_window_us = 300_000;
    let rec = Arc::new(Recorder::new("trace-it"));
    let pool = Arc::new(
        ServerPool::spawn_traced(PathBuf::from("artifacts"), c, Some(Arc::clone(&rec)))
            .unwrap(),
    );
    let spec = LoadSpec {
        clients: 4,
        requests_per_client: 1,
        f: 64,
        presets: vec!["er_s".into()],
        ops: vec![Op::Spmm],
        seed: 42,
        verify: true,
        max_retries: 0,
        retry_backoff_us: 200,
        approx_frac: 0.0,
    };
    let report = run_load_traced(Arc::clone(&pool), &spec, Some(Arc::clone(&rec))).unwrap();
    assert_eq!(report.errors, 0, "{}", report.text);
    assert_eq!(report.mismatches, 0, "{}", report.text);
    assert_eq!(report.probes, 1, "{}", report.text);

    let spans = rec.snapshot();
    let names_of = |t| -> BTreeSet<&str> {
        spans
            .iter()
            .filter(|s| s.trace == t)
            .map(|s| s.name.as_str())
            .collect()
    };

    // Exactly one request span per client, each tracing through the
    // shard to execute + reply.
    let request_spans: Vec<_> = spans.iter().filter(|s| s.name == "request").collect();
    assert_eq!(request_spans.len(), 4);
    for r in &request_spans {
        assert!(r.parent.is_none(), "request is the root span");
        let names = names_of(r.trace);
        for n in ["queue", "execute", "reply"] {
            assert!(names.contains(n), "trace {} missing {n}: {names:?}", r.trace);
        }
    }

    // The cold batch leader's trace carries the full decision chain.
    let sched: Vec<_> = spans.iter().filter(|s| s.name == "schedule").collect();
    assert!(!sched.is_empty(), "no schedule span recorded");
    let cold = sched
        .iter()
        .find(|s| s.attrs.iter().any(|(k, v)| k == "source" && v == "probe"))
        .expect("one batch must schedule via probe");
    assert!(
        cold.attrs
            .iter()
            .any(|(k, v)| k == "batch_size" && v.parse::<usize>().unwrap() >= 1),
        "{:?}",
        cold.attrs
    );
    let names = names_of(cold.trace);
    for n in [
        "request",
        "queue",
        "schedule",
        "cache_miss",
        "estimate",
        "probe",
        "guardrail",
        "execute",
        "reply",
    ] {
        assert!(names.contains(n), "leader trace missing {n}: {names:?}");
    }
    // Scheduler sub-spans parent under the schedule span; the schedule
    // span parents under the loadgen root.
    let root = spans
        .iter()
        .find(|s| s.trace == cold.trace && s.name == "request")
        .unwrap();
    assert_eq!(cold.parent, Some(root.span));
    for n in ["estimate", "probe", "guardrail"] {
        let sub = spans
            .iter()
            .find(|s| s.trace == cold.trace && s.name == n)
            .unwrap();
        assert_eq!(sub.parent, Some(cold.span), "{n} must parent under schedule");
    }

    // JSONL flush: every line parses and carries the run id.
    let dir = tmp("jsonl");
    let p = rec.flush_jsonl(&dir.join("trace.jsonl")).unwrap();
    let text = std::fs::read_to_string(&p).unwrap();
    assert_eq!(text.lines().count(), spans.len());
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("run_id").as_str(), Some("trace-it"));
    }
}

/// The checked-in BENCH_*.json baselines parse, self-compare clean, and
/// the gate demonstrably fails on a synthetic slowdown.
#[test]
fn perf_gate_fails_on_synthetic_slowdown() {
    let serve = PerfProfile::load(Path::new("benchmarks/BENCH_serve_smoke.json")).unwrap();
    assert_eq!(serve.name, "serve_bench");
    assert!(compare(&serve, &serve).passed());
    let bench = PerfProfile::load(Path::new("benchmarks/BENCH_bench_fixture.json")).unwrap();
    assert_eq!(bench.name, "bench");
    assert!(compare(&bench, &bench).passed());

    // Synthetic regression: throughput collapses 100x, p99 blows up
    // 100x — far beyond even the wide CI tolerances.
    let mut slow = serve.clone();
    let t = serve.metrics["throughput_rps"];
    slow.metrics.get_mut("throughput_rps").unwrap().value = t.value * 0.01;
    let p = serve.metrics["p99_ms"];
    slow.metrics.get_mut("p99_ms").unwrap().value = p.value * 100.0;
    let rep = compare(&serve, &slow);
    assert!(!rep.passed(), "gate must fail on a 100x slowdown");
    assert!(rep.regressions >= 2, "{}", rep.render("base", "slow"));
    assert!(rep.render("base", "slow").contains("REGRESSED"));

    // A dropped metric also fails (renames can't silently pass).
    let mut missing = serve.clone();
    missing.metrics.remove("probes");
    let rep = compare(&serve, &missing);
    assert!(!rep.passed());
    assert_eq!(rep.missing, 1);

    // Exact counters in the serve baseline gate the determinism
    // contract: the seeded smoke workload's totals are not noisy.
    for key in ["requests_total", "errors", "oracle_mismatches", "unique_keys"] {
        let m = serve.metrics[key];
        assert_eq!(m.tol_rel, 0.0, "{key} must gate exactly");
        let mut off = serve.clone();
        off.metrics.get_mut(key).unwrap().value = m.value + 1.0;
        assert!(!compare(&serve, &off).passed(), "{key} drift must fail");
    }
}

/// Satellites (a) + (c) end to end: probed decisions persist at pool
/// shutdown (not on the request path), and a warm-only second run still
/// flushes its hit counters to disk.
#[test]
fn cache_entries_and_warm_only_counters_persist_through_shutdown() {
    let dir = tmp("cache_persist");
    let path = dir.join("sched_cache.json");
    let mut c = cfg(1);
    c.cache_path = path.display().to_string();
    // Throttle far beyond the test's runtime: only the shutdown flush
    // may write, proving Drop persistence works.
    c.cache_flush_ms = 3_600_000;

    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c.clone()).unwrap());
    let (g, _) = preset("er_s", 17);
    let f = 64;
    let b = vec![0.5f32; g.n_rows * f];
    let r1 = pool
        .call(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
        .unwrap();
    assert!(r1.result.is_ok());
    assert!(!r1.from_cache, "first request must probe");
    assert!(
        !path.exists(),
        "persistence must be deferred off the request path (throttled)"
    );
    drop(pool);
    let cache = ScheduleCache::load(&path).unwrap();
    assert_eq!(cache.len(), 1, "probed decision must persist at shutdown");
    assert_eq!(cache.misses, 1);
    assert_eq!(cache.hits, 0);

    // Warm-only run: no inserts, only a counter mutation — it still
    // reaches disk (satellite: `autosage cache stats` stays accurate).
    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c).unwrap());
    let r2 = pool.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap();
    assert!(r2.result.is_ok());
    assert!(r2.from_cache, "decision must replay from the persisted cache");
    assert_eq!(r2.variant, r1.variant);
    drop(pool);
    let cache = ScheduleCache::load(&path).unwrap();
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.hits, 1, "warm-only hit counter must flush");
}

/// The one trace the seeded smoke workload keeps at sample rate 0.1:
/// 16 requests allocate trace ids 1..=16, and the SplitMix-based head
/// sampler under seed 42 keeps exactly id 10.
const SMOKE_SAMPLED_TRACE: &str = "000000000000000a";

/// Run `autosage serve-bench --smoke --seed 42 --out <dir>` with the
/// acceptance-spec sampling knobs and debug-build-friendly probe caps.
fn serve_bench_smoke(out_dir: &Path) -> std::process::Output {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_autosage"));
    cmd.args(["serve-bench", "--smoke", "--seed", "42", "--out"])
        .arg(out_dir)
        .env("AUTOSAGE_BACKEND", "native")
        .env("AUTOSAGE_TRACE_SAMPLE", "0.1")
        // Exercise the periodic-flush path too: the cursor must keep
        // the mid-run appends and the exit flush duplicate-free.
        .env("AUTOSAGE_TRACE_FLUSH_MS", "25")
        .env_remove("AUTOSAGE_TRACE_RING")
        .env("AUTOSAGE_PROBE_ITERS", "2")
        .env("AUTOSAGE_PROBE_CAP_MS", "200")
        .env("AUTOSAGE_PROBE_FULL_MAX", "512");
    cmd.output().expect("spawning autosage")
}

/// Run an `autosage` subcommand, asserting success; returns stdout.
fn cli(args: &[&str]) -> String {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_autosage"))
        .args(args)
        .output()
        .expect("spawning autosage");
    assert!(
        out.status.success(),
        "autosage {args:?} failed:\nstdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Distinct non-zero trace ids in a trace.jsonl body (trace 0 is the
/// synthetic id warn events use; it is not a sampled request).
fn sampled_traces(trace_jsonl: &str) -> BTreeSet<String> {
    trace_jsonl
        .lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| {
            Json::parse(l)
                .unwrap()
                .get("trace")
                .as_str()
                .map(str::to_string)
        })
        .filter(|t| t != "0000000000000000")
        .collect()
}

/// The acceptance contract: with AUTOSAGE_TRACE_SAMPLE=0.1 and a fixed
/// seed, `serve-bench --smoke --out` keeps the same single trace on
/// every rerun, metrics.prom validates with merged-histogram pool
/// percentiles and the sampling drop counters, audit.jsonl carries
/// nonzero calibration rows, and the `metrics` / `obs` / `manifest`
/// CLI verbs all accept the artifacts.
#[test]
fn serve_bench_cli_sampling_is_deterministic_and_artifacts_validate() {
    let d1 = tmp("cli_smoke_1");
    let d2 = tmp("cli_smoke_2");
    for d in [&d1, &d2] {
        let out = serve_bench_smoke(d);
        assert!(
            out.status.success(),
            "serve-bench failed:\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }

    // Head sampling: identical sampled set across reruns — exactly the
    // trace the (seed 42, rate 0.1) hash keeps.
    let t1 = std::fs::read_to_string(d1.join("trace.jsonl")).unwrap();
    let t2 = std::fs::read_to_string(d2.join("trace.jsonl")).unwrap();
    let s1 = sampled_traces(&t1);
    assert_eq!(
        s1,
        BTreeSet::from([SMOKE_SAMPLED_TRACE.to_string()]),
        "seed 42 @ rate 0.1 keeps exactly trace id 10 of the 16 smoke requests"
    );
    assert_eq!(s1, sampled_traces(&t2), "sampled set must survive reruns");

    // The kept trace still carries the full request pipeline, and the
    // periodic + exit flushes never wrote a span twice.
    let (stats, n_traces) = stage_breakdown(&t1).unwrap();
    assert_eq!(n_traces, 1);
    let names: BTreeSet<&str> = stats.iter().map(|s| s.name.as_str()).collect();
    for n in ["request", "queue", "execute", "reply"] {
        assert!(names.contains(n), "sampled trace missing {n}: {names:?}");
    }
    let span_ids: Vec<String> = t1
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            Json::parse(l)
                .unwrap()
                .get("span")
                .as_str()
                .expect("span id")
                .to_string()
        })
        .collect();
    let uniq: BTreeSet<&String> = span_ids.iter().collect();
    assert_eq!(
        uniq.len(),
        span_ids.len(),
        "flush cursor must not duplicate spans across periodic + exit flushes"
    );

    // metrics.prom: well-formed exposition with every required series.
    let prom = std::fs::read_to_string(d1.join("metrics.prom")).unwrap();
    let snap = validate_serving_snapshot(&prom).unwrap();
    assert_eq!(snap["autosage_traces_sampled_out_total"], 15.0);
    assert_eq!(snap["autosage_spans_dropped_total"], 0.0);
    assert_eq!(snap["autosage_pool_requests_total"], 16.0);
    assert!(snap["autosage_pool_latency_ms{quantile=\"0.99\"}"] > 0.0);
    assert!(
        snap.iter()
            .any(|(k, v)| k.starts_with("autosage_scheduler_decisions_total") && *v > 0.0),
        "scheduler decision counters missing: {snap:?}"
    );

    // audit.jsonl: the estimate-accuracy loop ignores sampling, so the
    // calibration table aggregates real (op, variant) rows.
    let audit = std::fs::read_to_string(d1.join("audit.jsonl")).unwrap();
    for line in audit.lines().filter(|l| !l.trim().is_empty()) {
        let s = AuditSample::from_json(&Json::parse(line).unwrap()).unwrap();
        assert!(s.measured_ms > 0.0, "{line}");
        assert!(s.predicted_ms > 0.0, "{line}");
    }
    let rows = calibration_table(&audit).unwrap();
    assert!(!rows.is_empty(), "audit.jsonl produced no calibration rows");
    assert!(rows.iter().all(|r| r.n > 0 && r.buckets > 0), "{rows:?}");

    // The CLI verbs accept everything the run emitted.
    let prom_path = d1.join("metrics.prom");
    let out = cli(&["metrics", "validate", prom_path.to_str().unwrap()]);
    assert!(out.contains("metrics OK"), "{out}");
    let out = cli(&["obs", "report", d1.to_str().unwrap()]);
    assert!(out.contains("stage latency breakdown"), "{out}");
    assert!(out.contains("estimate calibration"), "{out}");
    assert!(!out.contains("no usable audit samples"), "{out}");
    assert!(out.contains("autosage_traces_sampled_out_total"), "{out}");
    let manifest_path = d1.join("manifest.json");
    let out = cli(&["manifest", "validate", manifest_path.to_str().unwrap()]);
    assert!(out.contains("manifest OK"), "{out}");
    // metrics.prom and audit.jsonl are sha256-covered by the manifest:
    // corrupting the snapshot must now fail validation.
    std::fs::write(&prom_path, format!("{prom}\nextra_series 1\n")).unwrap();
    let rep = std::process::Command::new(env!("CARGO_BIN_EXE_autosage"))
        .args(["manifest", "validate", manifest_path.to_str().unwrap()])
        .output()
        .expect("spawning autosage");
    assert!(
        !rep.status.success(),
        "tampered metrics.prom must fail manifest validation"
    );
}

/// Sampling only throttles the *trace* stream: at rate 0.0 no request
/// spans record (only the discard counter moves), while the metrics
/// registry and the estimate-accuracy audit still see every request.
#[test]
fn rate_zero_sampling_audits_and_counts_but_records_no_request_spans() {
    let rec = Arc::new(Recorder::with_sampling("rate0-it", 0.0, 42));
    let reg = Arc::new(MetricsRegistry::new());
    let pool = Arc::new(
        ServerPool::spawn_observed(
            PathBuf::from("artifacts"),
            cfg(2),
            Some(Arc::clone(&rec)),
            Some(Arc::clone(&reg)),
        )
        .unwrap(),
    );
    let spec = LoadSpec {
        clients: 4,
        requests_per_client: 2,
        f: 64,
        presets: vec!["er_s".into()],
        ops: vec![Op::Spmm, Op::Sddmm],
        seed: 7,
        verify: false,
        max_retries: 0,
        retry_backoff_us: 200,
        approx_frac: 0.0,
    };
    let report = run_load_traced(Arc::clone(&pool), &spec, Some(Arc::clone(&rec))).unwrap();
    assert_eq!(report.errors, 0, "{}", report.text);
    assert_eq!(rec.traces_sampled_out(), 8, "all 8 requests discarded");
    let request_spans = rec
        .snapshot()
        .iter()
        .filter(|s| s.name == "request" || s.name == "execute")
        .count();
    assert_eq!(request_spans, 0, "rate 0 must record no request spans");

    // Registry + audit are sampling-independent.
    assert!(
        !reg.audit_snapshot().is_empty(),
        "audit loop must see every executed request at rate 0"
    );
    let snap_text = prometheus_snapshot(&reg, Some(pool.metrics()), Some(&*rec));
    let snap = validate_serving_snapshot(&snap_text).unwrap();
    assert_eq!(snap["autosage_traces_sampled_out_total"], 8.0);
    assert_eq!(snap["autosage_pool_requests_total"], 8.0);
    assert!(snap["autosage_pool_latency_ms{quantile=\"0.5\"}"] > 0.0);
}
