//! Integration: the scheduling pipeline end-to-end — decide → cache →
//! persist → replay across instances; replay-only semantics; guardrail
//! non-regression on measured full-graph medians.
//!
//! Runs on the native backend so a clean checkout needs no artifacts;
//! `artifacts_vs_oracle.rs` covers the PJRT path.

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::scheduler::{DecisionSource, Op};

fn cfg_with_cache(path: &str) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = path.to_string();
    // Probe induced 512-row subgraphs (not the full 4096-row buckets):
    // exercises the twin-mapping path and keeps debug-mode runs fast.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 3;
    cfg.probe_cap_ms = 300.0;
    cfg
}

#[test]
fn decide_then_cache_hit_same_instance() {
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg_with_cache(""), None).unwrap();
    let (g, _) = preset("er_s", 9);
    let d1 = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(d1.source, DecisionSource::Probe);
    assert!(d1.probe_wall_ms > 0.0);
    let d2 = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(d2.source, DecisionSource::Cache);
    assert_eq!(d1.choice.variant(), d2.choice.variant());
    assert_eq!(d2.probe_wall_ms, 0.0);
}

#[test]
fn cache_persists_across_instances_and_replay_only_works() {
    let cache = std::env::temp_dir().join("autosage_it_cache.json");
    let _ = std::fs::remove_file(&cache);
    let cache_s = cache.display().to_string();

    let (g, _) = preset("er_s", 10);
    let v1 = {
        let mut sage =
            AutoSage::new(Path::new("artifacts"), cfg_with_cache(&cache_s), None).unwrap();
        let d = sage.decide(&g, Op::Spmm, 64).unwrap();
        assert_eq!(d.source, DecisionSource::Probe);
        d.choice.variant().to_string()
    };
    assert!(cache.exists(), "cache file must be written");

    // New instance, replay-only: must hit the cache, never probe.
    let mut cfg = cfg_with_cache(&cache_s);
    cfg.replay_only = true;
    let mut sage2 = AutoSage::new(Path::new("artifacts"), cfg, None).unwrap();
    let d = sage2.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(d.source, DecisionSource::Cache);
    assert_eq!(d.choice.variant(), v1);

    // Replay-only on an UNSEEN key: forced baseline, no probe.
    let d = sage2.decide(&g, Op::Spmm, 128).unwrap();
    assert_eq!(d.source, DecisionSource::ReplayFallback);
    assert!(d.choice.is_baseline());

    let _ = std::fs::remove_file(&cache);
}

#[test]
fn different_f_and_op_get_distinct_cache_keys() {
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg_with_cache(""), None).unwrap();
    let (g, _) = preset("er_s", 11);
    let d_spmm64 = sage.decide(&g, Op::Spmm, 64).unwrap();
    let d_spmm128 = sage.decide(&g, Op::Spmm, 128).unwrap();
    let d_sddmm64 = sage.decide(&g, Op::Sddmm, 64).unwrap();
    assert_ne!(d_spmm64.key, d_spmm128.key);
    assert_ne!(d_spmm64.key, d_sddmm64.key);
    // All three were fresh probes (no key collisions).
    for d in [&d_spmm64, &d_spmm128, &d_sddmm64] {
        assert_eq!(d.source, DecisionSource::Probe);
    }
}

#[test]
fn guardrail_non_regression_on_full_graph() {
    // Proposition 1, checked against *measured* full-graph medians:
    // the chosen kernel must not be meaningfully slower than the vendor
    // baseline (allow 40% slack for single-core timing noise and
    // probe→full extrapolation error; the paper's guarantee is exact
    // only on the probed input itself).
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg_with_cache(""), None).unwrap();
    for preset_name in ["er_s", "hub_s"] {
        let (g, _) = preset(preset_name, 12);
        let d = sage.decide(&g, Op::Spmm, 64).unwrap();
        let tb = sage.time_op(&g, Op::Spmm, 64, "baseline", 5, 1000.0).unwrap();
        let tc = sage
            .time_op(&g, Op::Spmm, 64, d.choice.variant(), 5, 1000.0)
            .unwrap();
        assert!(
            tc.median_ms <= tb.median_ms * 1.4,
            "{preset_name}: chosen {} = {:.3}ms vs baseline {:.3}ms",
            d.choice.variant(),
            tc.median_ms,
            tb.median_ms
        );
    }
}

#[test]
fn alpha_one_accepts_any_probe_winner() {
    let mut cfg = cfg_with_cache("");
    cfg.alpha = 1.0;
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg, None).unwrap();
    let (g, _) = preset("er_s", 13);
    // With alpha = 1.0 the guardrail accepts any strict probe winner; the
    // decision must still be valid and runnable either way.
    let d = sage.decide(&g, Op::Spmm, 64).unwrap();
    let b = vec![0.5f32; g.n_rows * 64];
    let out = sage.spmm_with(&g, &b, 64, d.choice.variant()).unwrap();
    assert_eq!(out.len(), g.n_rows * 64);
}

#[test]
fn telemetry_records_probe_and_decision_events() {
    let mut sage = AutoSage::new(Path::new("artifacts"), cfg_with_cache(""), None).unwrap();
    let (g, _) = preset("er_s", 14);
    let _ = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert!(!sage.telemetry.events_of("decision").is_empty());
    assert!(!sage.telemetry.events_of("probe").is_empty());
    // Cache hit logs a decision but no new probe rows.
    let probes_before = sage.telemetry.events_of("probe").len();
    let _ = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(sage.telemetry.events_of("probe").len(), probes_before);
    assert_eq!(sage.telemetry.events_of("decision").len(), 2);
}
