//! End-to-end tests of the dataset ingestion & reorder subsystem: the
//! checked-in `.mtx` fixture converts to a checksummed `.asg` snapshot,
//! reorders losslessly (round-trip bit-exact), and — the oracle
//! acceptance — SpMM/SDDMM/attention outputs on the reordered layout
//! match the un-permuted baseline **bit for bit** after un-permutation
//! (row-only permutations preserve per-row slot order, hence f32
//! summation order).

use std::path::{Path, PathBuf};

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::data::{
    self, parse_passes, read_asg, reorder, write_asg, ReorderPass,
};
use autosage::graph::signature::graph_signature;
use autosage::graph::Csr;
use autosage::ops::reference;
use autosage::scheduler::Op;

fn fixture_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/skewed.mtx")
}

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join("autosage_data_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fixture_graph() -> Csr {
    data::CsrGraph::load(&fixture_path()).unwrap().csr
}

fn native_cfg() -> Config {
    Config {
        backend: "native".to_string(),
        cache_path: String::new(),
        probe_iters: 3,
        probe_cap_ms: 300.0,
        ..Config::default()
    }
}

/// Deterministic dense operand (row-major [n, f]).
fn dense(n: usize, f: usize, salt: u32) -> Vec<f32> {
    (0..n * f)
        .map(|i| {
            let x = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
            ((x % 1000) as f32) / 500.0 - 1.0
        })
        .collect()
}

#[test]
fn mtx_fixture_loads_skewed_and_normalized() {
    let loaded = data::CsrGraph::load(&fixture_path()).unwrap();
    let g = &loaded.csr;
    g.validate().unwrap();
    assert_eq!(g.n_rows, 96);
    assert_eq!(g.n_cols, 96);
    assert_eq!(g.nnz(), 313);
    assert_eq!(g.max_degree(), 16);
    let hubs = g.degrees().iter().filter(|&&d| d == 16).count();
    assert_eq!(hubs, 6, "fixture must stay degree-skewed");
    // Light rows fit the micro hub bucket's light width.
    assert!(g.degrees().iter().all(|&d| d == 16 || d <= 4));
}

#[test]
fn convert_mtx_to_asg_is_lossless_and_checksummed() {
    let out = tmpdir().join("convert.asg");
    let loaded = data::convert_to_asg(&fixture_path(), &out).unwrap();
    let snap = read_asg(&out).unwrap();
    assert_eq!(snap.csr, loaded.csr);
    assert_eq!(snap.perm, None);
    assert_eq!(
        graph_signature(&snap.csr),
        graph_signature(&fixture_graph())
    );
    // Corrupting any byte must be caught by the checksum, not served.
    let mut bytes = std::fs::read(&out).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&out, &bytes).unwrap();
    assert!(read_asg(&out).is_err());
    let _ = std::fs::remove_file(&out);
}

#[test]
fn reorder_snapshot_roundtrip_is_bit_exact() {
    // The CLI flow: convert → reorder (perm stored) → load → restore.
    let dir = tmpdir();
    let plain = dir.join("plain.asg");
    let packed = dir.join("packed.asg");
    let g = fixture_graph();
    write_asg(&plain, &g, None).unwrap();

    let passes = parse_passes("hub-pack,segment-sort").unwrap();
    let snap = read_asg(&plain).unwrap();
    let r = reorder(&snap.csr, &passes);
    write_asg(&packed, &r.graph, Some(&r.perm)).unwrap();

    let back = read_asg(&packed).unwrap();
    let restored = data::reorder::from_stored_perm(
        back.csr.clone(),
        back.perm.expect("reordered snapshot stores its perm"),
    )
    .unwrap();
    assert_eq!(restored.restore_graph(), g, "round-trip must be lossless");
    assert_eq!(graph_signature(&restored.restore_graph()), graph_signature(&g));
    assert_ne!(graph_signature(&back.csr), graph_signature(&g));
    // Hub packing on the skewed fixture must visibly improve layout.
    assert!(r.report.after.head_nnz_frac > r.report.before.head_nnz_frac);
    assert!(r.report.after.tile_fill > r.report.before.tile_fill);
    let _ = std::fs::remove_file(&plain);
    let _ = std::fs::remove_file(&packed);
}

#[test]
fn oracle_outputs_permutation_invariant_bit_for_bit() {
    let g = fixture_graph();
    let f = 16;
    let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);

    // SpMM: B is column-indexed (untouched); outputs are row-indexed.
    let b = dense(g.n_rows, f, 1);
    let base = reference::spmm(&g, &b, f);
    let re = reference::spmm(&r.graph, &b, f);
    assert_eq!(r.unpermute_rowwise(&re, f), base, "spmm not bit-identical");

    // SDDMM: X row-indexed (permute), Y column-indexed (untouched);
    // outputs are per-edge in slot order.
    let x = dense(g.n_rows, f, 2);
    let y = dense(g.n_rows, f, 3);
    let base = reference::sddmm(&g, &x, &y, f);
    let px = r.permute_rowwise(&x, f);
    let re = reference::sddmm(&r.graph, &px, &y, f);
    assert_eq!(r.unpermute_edges(&re), base, "sddmm not bit-identical");

    // Attention: Q row-indexed (permute), K/V column-indexed.
    let q = dense(g.n_rows, f, 4);
    let k = dense(g.n_rows, f, 5);
    let v = dense(g.n_rows, f, 6);
    let base = reference::csr_attention(&g, &q, &k, &v, f);
    let pq = r.permute_rowwise(&q, f);
    let re = reference::csr_attention(&r.graph, &pq, &k, &v, f);
    assert_eq!(
        r.unpermute_rowwise(&re, f),
        base,
        "attention not bit-identical"
    );
}

#[test]
fn native_backend_matches_oracle_on_loaded_graph_both_layouts() {
    let g = fixture_graph();
    let f = 64;
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let b = dense(g.n_rows, f, 7);
    let oracle = reference::spmm(&g, &b, f);
    // Fixed-variant execution on the loaded graph matches the oracle…
    let out = sage.spmm_with(&g, &b, f, "baseline").unwrap();
    assert_eq!(
        reference::max_abs_diff(&out, &oracle),
        0.0,
        "native baseline must be bit-exact vs oracle on the fixture"
    );
    // …and the reordered layout un-permutes to the same bits.
    let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
    let out_r = sage.spmm_with(&r.graph, &b, f, "baseline").unwrap();
    assert_eq!(
        reference::max_abs_diff(&r.unpermute_rowwise(&out_r, f), &oracle),
        0.0,
        "reordered layout must un-permute bit-exactly"
    );
}

#[test]
fn scheduler_runs_end_to_end_on_reordered_fixture() {
    // The acceptance bench flow: decisions succeed on both layouts and
    // key separate cache entries (the layouts have different signatures).
    let g = fixture_graph();
    let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let d0 = sage.decide(&g, Op::Spmm, 64).unwrap();
    let d1 = sage.decide(&r.graph, Op::Spmm, 64).unwrap();
    assert_ne!(d0.key, d1.key, "layouts must key separate schedule entries");
    assert!(d0.t_baseline_ms > 0.0);
    assert!(d1.t_baseline_ms > 0.0);
}

#[test]
fn scheduler_rejects_degenerate_inputs_typed() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let empty = Csr::from_rows(0, vec![]);
    let err = sage.decide(&empty, Op::Spmm, 64).unwrap_err();
    assert!(
        format!("{err:#}").contains("degenerate"),
        "want typed degenerate-input error, got: {err:#}"
    );
    let g = fixture_graph();
    let err = sage.decide(&g, Op::Spmm, 0).unwrap_err();
    assert!(format!("{err:#}").contains("F = 0"), "{err:#}");
}

#[test]
fn facade_accepts_graph_specs() {
    let dir = tmpdir();
    let path = dir.join("facade.asg");
    let g = fixture_graph();
    write_asg(&path, &g, None).unwrap();
    let sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let via_file = sage
        .graph_from_spec(&format!("file:{}", path.display()), 0)
        .unwrap();
    assert_eq!(via_file, g);
    let via_preset = sage.graph_from_spec("er_s", 42).unwrap();
    assert_eq!(via_preset.n_rows, 4096);
    assert!(sage.graph_from_spec("not_a_spec", 0).is_err());
    let _ = std::fs::remove_file(&path);
}
