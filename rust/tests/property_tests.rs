//! Property-based tests (hand-rolled driver over the deterministic PRNG;
//! proptest is unavailable offline): format round-trips, conversion
//! inverses, cache-key stability, scheduler determinism — each over
//! hundreds of randomized cases.

use autosage::coordinator::facade::{csr_slots_to_ell, ell_slots_to_csr};
use autosage::graph::ell::{CooBuffers, EllBuffers, HubSplit};
use autosage::graph::signature::graph_signature;
use autosage::graph::Csr;
use autosage::scheduler::cache::cache_key;
use autosage::util::json::Json;
use autosage::util::rng::Rng;

/// Random CSR with rows ≤ max_n, degrees ≤ max_deg.
fn arb_graph(rng: &mut Rng, max_n: usize, max_deg: usize) -> Csr {
    let n = rng.range(1, max_n);
    let rows = (0..n)
        .map(|_| {
            let d = rng.below(max_deg.min(n) + 1);
            rng.sample_distinct(n, d)
                .into_iter()
                .map(|c| (c as u32, rng.next_f32() * 2.0 - 1.0))
                .collect()
        })
        .collect();
    Csr::from_rows(n, rows)
}

fn next_pow2(x: usize) -> usize {
    x.next_power_of_two().max(1)
}

#[test]
fn prop_ell_roundtrip() {
    let mut rng = Rng::new(100);
    for case in 0..300 {
        let g = arb_graph(&mut rng, 80, 12);
        let n_pad = next_pow2(g.n_rows.max(g.n_cols));
        let w = next_pow2(g.max_degree().max(1));
        let e = EllBuffers::from_csr(&g, n_pad, w)
            .unwrap_or_else(|err| panic!("case {case}: {err}"));
        assert_eq!(e.to_csr(g.n_cols), g, "case {case}");
        assert_eq!(e.nnz(), g.nnz(), "case {case}");
    }
}

#[test]
fn prop_coo_roundtrip_order_and_padding() {
    let mut rng = Rng::new(101);
    for case in 0..300 {
        let g = arb_graph(&mut rng, 60, 8);
        let nnz_pad = g.nnz() + rng.below(50);
        let c = CooBuffers::from_csr(&g, nnz_pad).unwrap();
        // Row indices are non-decreasing (CSR slot order).
        for w in c.row[..c.nnz].windows(2) {
            assert!(w[0] <= w[1], "case {case}: rows out of order");
        }
        // Padding is all zeros.
        assert!(c.val[c.nnz..].iter().all(|&v| v == 0.0), "case {case}");
        // Mass conserved.
        let total: f32 = g.val.iter().sum();
        let packed: f32 = c.val.iter().sum();
        assert!((total - packed).abs() < 1e-3, "case {case}");
    }
}

#[test]
fn prop_hub_split_conserves_every_edge() {
    let mut rng = Rng::new(102);
    for case in 0..200 {
        let g = arb_graph(&mut rng, 60, 16);
        let hub_t = rng.range(1, 16);
        let n_pad = next_pow2(g.n_rows.max(g.n_cols));
        let degs = g.degrees();
        let n_hubs = degs.iter().filter(|&&d| d > hub_t).count();
        let h_pad = next_pow2(n_hubs.max(1));
        let w_hub = next_pow2(g.max_degree().max(1));
        let hs = HubSplit::from_csr(&g, hub_t, n_pad, hub_t.max(1), h_pad, w_hub)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(hs.n_hubs, n_hubs, "case {case}");
        // Reconstruct: light CSR + hub rows = original.
        let mut rebuilt: Vec<Vec<(u32, f32)>> = (0..g.n_rows)
            .map(|i| {
                (0..hs.light.w)
                    .filter(|s| hs.light.mask[i * hs.light.w + s] > 0.0)
                    .map(|s| {
                        (hs.light.colind[i * hs.light.w + s] as u32,
                         hs.light.val[i * hs.light.w + s])
                    })
                    .collect()
            })
            .collect();
        for k in 0..hs.n_hubs {
            let row = hs.hub_rows[k] as usize;
            for s in 0..w_hub {
                // padded hub slots have val 0 AND col 0; only take real
                // slots (tracked via degree).
                if s < degs[row] {
                    rebuilt[row].push((
                        hs.hub_colind[k * w_hub + s] as u32,
                        hs.hub_val[k * w_hub + s],
                    ));
                }
            }
        }
        let rebuilt = Csr::from_rows(g.n_cols, rebuilt);
        assert_eq!(rebuilt, g, "case {case} (hub_t {hub_t})");
    }
}

#[test]
fn prop_slot_conversions_inverse() {
    let mut rng = Rng::new(103);
    for case in 0..300 {
        let g = arb_graph(&mut rng, 60, 10);
        let slots: Vec<f32> = (0..g.nnz()).map(|_| rng.next_f32()).collect();
        let n_pad = next_pow2(g.n_rows);
        let w = next_pow2(g.max_degree().max(1));
        let ell = csr_slots_to_ell(&g, n_pad, w, &slots).unwrap();
        let back = ell_slots_to_csr(&g, w, &ell);
        assert_eq!(back, slots, "case {case}");
    }
}

#[test]
fn prop_graph_signature_stable_under_value_change_only() {
    let mut rng = Rng::new(104);
    for case in 0..200 {
        let g = arb_graph(&mut rng, 50, 8);
        let sig = graph_signature(&g);
        // Value perturbation: signature unchanged.
        let mut g2 = g.clone();
        if !g2.val.is_empty() {
            let i = rng.below(g2.val.len());
            g2.val[i] += 1.0;
            assert_eq!(sig, graph_signature(&g2), "case {case}");
        }
        // Structural perturbation: signature changes.
        if g.nnz() > 0 {
            let mut g3 = g.clone();
            let i = rng.below(g3.colind.len());
            g3.colind[i] = (g3.colind[i] + 1) % g3.n_cols as u32;
            if g3.colind != g.colind {
                assert_ne!(sig, graph_signature(&g3), "case {case}");
            }
        }
    }
}

#[test]
fn prop_cache_key_injective_over_components() {
    let mut rng = Rng::new(105);
    let mut seen = std::collections::HashMap::new();
    for _ in 0..500 {
        let dev = format!("dev{}", rng.below(5));
        let gsig = format!("{:08x}", rng.below(16) as u64);
        let f = [32, 64, 128, 256][rng.below(4)];
        let op = ["spmm", "sddmm", "attention"][rng.below(3)];
        let key = cache_key(&dev, &gsig, f, op);
        let val = (dev, gsig, f, op);
        if let Some(prev) = seen.insert(key.clone(), val.clone()) {
            assert_eq!(prev, val, "key collision on {key}");
        }
    }
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    let mut rng = Rng::new(106);
    fn arb_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) / 8.0 - 1000.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| {
                        ['a', 'Z', '"', '\\', '\n', 'π', '0', ' '][rng.below(8)]
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| arb_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), arb_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..500 {
        let v = arb_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(v, back, "case {case}");
        // pretty form parses to the same value too
        assert_eq!(v, Json::parse(&v.pretty()).unwrap(), "case {case}");
    }
}

#[test]
fn prop_probe_sample_degree_multiset_preserved() {
    let mut rng = Rng::new(107);
    for case in 0..100 {
        let g = arb_graph(&mut rng, 120, 10);
        let k = rng.range(1, g.n_rows);
        let p = g.probe_sample(k, case as u64);
        assert_eq!(p.n_rows, k.max(1).min(g.n_rows), "case {case}");
        // every probe row's degree exists in the original multiset
        let mut orig = g.degrees();
        orig.sort_unstable();
        for d in p.degrees() {
            assert!(orig.binary_search(&d).is_ok(), "case {case}: degree {d}");
        }
    }
}
