//! Validated model hot-reload: the pool's watcher picks a retrained
//! `.asgm` up off the request path, shadow-grades it as a canary
//! against live probe outcomes, promotes it on agreement (new
//! generation, counter, trace event) — and a corrupt overwrite is
//! rejected without ever reaching serving.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use autosage::config::Config;
use autosage::gen::preset;
use autosage::model::{
    write_model_generational, CostModel, Example, DEFAULT_MAX_DEPTH,
};
use autosage::obs::metrics::MetricsRegistry;
use autosage::obs::trace::Recorder;
use autosage::scheduler::features::FEATURE_NAMES;
use autosage::scheduler::Op;
use autosage::server::ServerPool;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("autosage_hot_reload_tests")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Pool config wired for fast hot-reload testing: native backend, a
/// tight watcher poll, and a one-observation canary quota with a zero
/// agreement bar so grading is deterministic (the quota, not the
/// agreement fraction, is what these tests exercise).
fn reload_cfg(model_path: &std::path::Path) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 200.0;
    cfg.serve_workers = 1;
    cfg.model_path = model_path.display().to_string();
    cfg.model_reload_ms = 10;
    cfg.model_canary_n = 1;
    cfg.model_canary_agree = 0.0;
    cfg
}

/// A model that predicts `label` for `op` with confidence 1.0: one
/// single-class example trains a pure leaf.
fn constant_model(op: &str, label: &str) -> CostModel {
    let examples = vec![Example {
        op: op.to_string(),
        features: vec![1.0; FEATURE_NAMES.len()],
        label: label.to_string(),
    }];
    CostModel::train(&examples, &[], 1, DEFAULT_MAX_DEPTH).unwrap()
}

fn spmm_call(pool: &ServerPool, seed: u64) {
    let (g, _) = preset("er_s", seed);
    let b = vec![0.5f32; g.n_rows * 64];
    let resp = pool.call(Op::Spmm, g, 64, vec![("b".into(), b)]).unwrap();
    resp.result.expect("no faults configured — requests must succeed");
}

fn wait_until(deadline: Duration, mut done: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if done() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    done()
}

/// A retrained model written over `model_path` is picked up live,
/// canaried against a real probe outcome, and promoted: generation
/// bumps, the reload counter and `model_reload` trace events fire, and
/// no restart happened anywhere.
#[test]
fn retrained_model_is_canaried_and_promoted_live() {
    let dir = tmpdir("promote");
    let model_path = dir.join("model.asgm");
    // Incumbent knows only sddmm — every SpMM request probes, and each
    // probe outcome is ground truth the canary is graded against.
    write_model_generational(
        &model_path,
        &constant_model("sddmm", Op::Sddmm.baseline_variant()),
    )
    .unwrap();

    let registry = Arc::new(MetricsRegistry::new());
    let recorder = Arc::new(Recorder::new("hot-reload-test"));
    let pool = ServerPool::spawn_observed(
        PathBuf::from("artifacts"),
        reload_cfg(&model_path),
        Some(Arc::clone(&recorder)),
        Some(Arc::clone(&registry)),
    )
    .unwrap();
    assert!(pool.has_model(), "the incumbent must load at spawn");
    assert_eq!(pool.model_generation(), 0);
    assert_eq!(pool.model_reloads(), 0);

    // Let the watcher fingerprint the incumbent before the overwrite,
    // so the retrained file registers as a change.
    std::thread::sleep(Duration::from_millis(150));
    write_model_generational(
        &model_path,
        &constant_model("spmm", Op::Spmm.baseline_variant()),
    )
    .unwrap();

    // Serve SpMM until the canary has been installed, graded against a
    // probe outcome, and promoted. Varying the graph seed keeps minting
    // cold keys, so ground truth keeps flowing whenever grading needs it.
    let mut seed = 0u64;
    let promoted = wait_until(Duration::from_secs(20), || {
        seed += 1;
        spmm_call(&pool, seed);
        pool.model_reloads() == 1
    });
    assert!(promoted, "candidate must promote within the window");
    assert_eq!(pool.model_generation(), 1, "promotion bumps the generation");
    assert_eq!(pool.model_rollbacks(), 0);
    assert!(pool.has_model());

    // The promoted incumbent serves: confidence-1.0 spmm predictions
    // now skip the probe, and requests still succeed.
    spmm_call(&pool, 9999);

    // Observable as metrics and trace events, per the required series.
    assert_eq!(
        registry
            .counter("autosage_model_reloads_total")
            .load(Ordering::Relaxed),
        1
    );
    let prom = registry.render_prometheus();
    assert!(prom.contains("autosage_model_reloads_total 1"), "{prom}");
    let spans = recorder.snapshot();
    let reload_events: Vec<_> =
        spans.iter().filter(|s| s.name == "model_reload").collect();
    let outcome = |o: &str| {
        reload_events.iter().any(|s| {
            s.attrs
                .iter()
                .any(|(k, v)| k == "outcome" && v == o)
        })
    };
    assert!(outcome("candidate"), "the canary install must leave a trace event");
    assert!(outcome("promoted"), "the promotion must leave a trace event");
}

/// A corrupt overwrite of the model file (no usable previous
/// generation) is rejected by the watcher: counted as a rollback, the
/// incumbent keeps serving, and the generation never moves.
#[test]
fn corrupt_model_overwrite_is_rejected_and_incumbent_survives() {
    let dir = tmpdir("reject");
    let model_path = dir.join("model.asgm");
    write_model_generational(
        &model_path,
        &constant_model("sddmm", Op::Sddmm.baseline_variant()),
    )
    .unwrap();

    let registry = Arc::new(MetricsRegistry::new());
    let recorder = Arc::new(Recorder::new("hot-reload-reject"));
    let pool = ServerPool::spawn_observed(
        PathBuf::from("artifacts"),
        reload_cfg(&model_path),
        Some(Arc::clone(&recorder)),
        Some(Arc::clone(&registry)),
    )
    .unwrap();
    assert!(pool.has_model());
    std::thread::sleep(Duration::from_millis(150));

    // Torn/corrupt retrain: garbage bytes, and the first generational
    // write left no `.prev` behind — nothing recoverable.
    std::fs::write(&model_path, b"not a model file at all").unwrap();
    let rejected =
        wait_until(Duration::from_secs(20), || pool.model_rollbacks() >= 1);
    assert!(rejected, "the watcher must reject the corrupt file");
    assert_eq!(pool.model_reloads(), 0, "a rejected file never promotes");
    assert_eq!(pool.model_generation(), 0);
    assert!(pool.has_model(), "the incumbent stays installed");
    spmm_call(&pool, 1);

    assert!(
        registry
            .counter("autosage_model_rollbacks_total")
            .load(Ordering::Relaxed)
            >= 1
    );
    let spans = recorder.snapshot();
    assert!(
        spans.iter().any(|s| s.name == "model_reload"
            && s.attrs.iter().any(|(k, v)| k == "outcome" && v == "rejected")),
        "rejection must leave a model_reload trace event"
    );
}
