//! Durability integration: seeded I/O fault injection wrapper
//! semantics, same-seed determinism of the injected-fault log, JSONL
//! valid-prefix salvage (plain and schema-strict), schedule-cache
//! entry quarantine round-trips, and the `autosage doctor` audit →
//! repair → clean cycle driven through the real CLI binary.

use std::path::PathBuf;
use std::process::Command;
use std::sync::{Arc, Mutex, MutexGuard};

use autosage::model::{
    read_model_generational, write_model_generational, CostModel, Example,
    DEFAULT_MAX_DEPTH,
};
use autosage::scheduler::features::FEATURE_NAMES;
use autosage::scheduler::{CachedChoice, ScheduleCache};
use autosage::server::{QuarantineEntry, QuarantineLog};
use autosage::util::iofault::{
    self, IoFaultInjector, IoFaultKind, OpClass, WRITE_RETRIES,
};
use autosage::util::json::Json;

/// The injector slot is process-global: tests that `install` one must
/// hold this lock for their whole body and uninstall before releasing.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

fn lock_faults() -> MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("autosage_durability_tests")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A trivially valid one-op model for generational-artifact fixtures.
fn tiny_model(label: &str) -> CostModel {
    let examples = vec![Example {
        op: "spmm".to_string(),
        features: vec![1.0; FEATURE_NAMES.len()],
        label: label.to_string(),
    }];
    CostModel::train(&examples, &[], 1, DEFAULT_MAX_DEPTH).unwrap()
}

fn choice(variant: &str) -> CachedChoice {
    CachedChoice {
        variant: variant.to_string(),
        t_baseline_ms: 2.0,
        t_star_ms: 1.0,
        alpha: 0.5,
        features: None,
    }
}

/// Rate-1.0 bit_flip on the write path: the write *succeeds* but the
/// byte at len/2 lands with one flipped bit — silent corruption that
/// only read-side validation can catch.
#[test]
fn bit_flip_write_corrupts_exactly_one_middle_byte() {
    let _guard = lock_faults();
    let inj = Arc::new(IoFaultInjector::new(11, 1.0, vec![IoFaultKind::BitFlip]));
    iofault::install(Some(Arc::clone(&inj)));
    let dir = tmpdir("bitflip");
    let path = dir.join("payload.bin");
    let data: Vec<u8> = (0..64u8).collect();
    iofault::write_file("test.bitflip.write", &path, &data)
        .expect("bit_flip is silent — the write must succeed");
    iofault::install(None);

    let on_disk = std::fs::read(&path).unwrap();
    assert_eq!(on_disk.len(), data.len());
    let diffs: Vec<usize> = (0..data.len())
        .filter(|&i| on_disk[i] != data[i])
        .collect();
    assert_eq!(diffs, vec![data.len() / 2], "exactly the middle byte flips");
    assert_eq!(on_disk[32] ^ data[32], 0x01, "one bit, deterministic position");
    assert_eq!(inj.injected_of(IoFaultKind::BitFlip), 1);
    assert_eq!(inj.injected_total(), 1);
}

/// Rate-1.0 short_read halves the byte stream silently; the caller
/// sees a successful read of a truncated payload.
#[test]
fn short_read_silently_truncates_to_half() {
    let _guard = lock_faults();
    let dir = tmpdir("shortread");
    let path = dir.join("payload.bin");
    std::fs::write(&path, vec![7u8; 100]).unwrap();
    let inj = Arc::new(IoFaultInjector::new(3, 1.0, vec![IoFaultKind::ShortRead]));
    iofault::install(Some(Arc::clone(&inj)));
    let got = iofault::read_file("test.shortread.read", &path).unwrap();
    iofault::install(None);
    assert_eq!(got.len(), 50, "short_read returns the first half");
    assert!(got.iter().all(|&b| b == 7));
    assert_eq!(inj.injected_of(IoFaultKind::ShortRead), 1);
}

/// Rate-1.0 failed_rename exhausts the atomic-write retry budget: the
/// destination is never created, the tmp file is cleaned up, and every
/// retry is counted in the process-wide recovery stats.
#[test]
fn failed_rename_exhausts_retries_and_leaves_no_debris() {
    let _guard = lock_faults();
    let dir = tmpdir("failedrename");
    let path = dir.join("artifact.json");
    let retries_before = iofault::recovery().snapshot()[0].1;
    let inj =
        Arc::new(IoFaultInjector::new(5, 1.0, vec![IoFaultKind::FailedRename]));
    iofault::install(Some(Arc::clone(&inj)));
    let err = iofault::write_atomic("test.rename.write", &path, b"{\"k\":1}\n")
        .expect_err("every rename injected — the retry budget must exhaust");
    iofault::install(None);

    assert!(err.to_string().contains("failed_rename"), "{err}");
    assert!(!path.exists(), "destination must stay untouched");
    let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert!(leftovers.is_empty(), "tmp file must be cleaned up: {leftovers:?}");
    assert_eq!(inj.injected_of(IoFaultKind::FailedRename), WRITE_RETRIES as u64);
    let retries_after = iofault::recovery().snapshot()[0].1;
    assert!(
        retries_after - retries_before >= (WRITE_RETRIES - 1) as u64,
        "each attempt past the first counts as a write retry"
    );
}

/// Same seed, same op sequence → byte-identical injected-fault logs and
/// totals, across two fresh injectors. This is the property the CI
/// crash-smoke job's `cmp recovery.json` leans on.
#[test]
fn same_seed_injectors_replay_the_identical_fault_set() {
    let _guard = lock_faults();
    let run = |tag: &str| -> (Vec<(String, u64, IoFaultKind)>, u64) {
        let dir = tmpdir(&format!("sameseed_{tag}"));
        let inj = Arc::new(IoFaultInjector::new(99, 0.5, vec![]));
        iofault::install(Some(Arc::clone(&inj)));
        let a = dir.join("a.json");
        let b = dir.join("b.json");
        for i in 0..20 {
            let payload = format!("{{\"i\":{i}}}\n");
            let _ = iofault::write_file("test.seed.write", &a, payload.as_bytes());
            let _ = iofault::write_atomic("test.seed.atomic", &b, payload.as_bytes());
            if a.exists() {
                let _ = iofault::read_file("test.seed.read", &a);
            }
        }
        iofault::install(None);
        (inj.log_snapshot(), inj.injected_total())
    };
    let (log1, total1) = run("one");
    let (log2, total2) = run("two");
    assert!(total1 > 0, "rate 0.5 over 60+ ops must inject something");
    assert_eq!(total1, total2);
    assert_eq!(log1, log2, "same-seed runs must inject the identical set");

    // And the pure decision function agrees with itself across instances.
    let x = IoFaultInjector::new(99, 0.5, vec![]);
    let y = IoFaultInjector::new(99, 0.5, vec![]);
    for idx in 0..100 {
        assert_eq!(
            x.decide_at("test.seed.atomic", idx, OpClass::Write),
            y.decide_at("test.seed.atomic", idx, OpClass::Write)
        );
    }
}

/// Valid-prefix salvage over a torn JSONL stream: the intact leading
/// lines survive, everything from the first unparseable line is
/// dropped and counted.
#[test]
fn jsonl_salvage_recovers_the_valid_prefix_of_a_torn_stream() {
    let text = "{\"a\":1}\n\n{\"b\":2}\n{\"c\":3}\n{\"d\":4,\"tr";
    let (kept, dropped) = iofault::salvage_jsonl(text);
    assert_eq!(kept, vec!["{\"a\":1}", "{\"b\":2}", "{\"c\":3}"]);
    assert_eq!(dropped, 1, "only the torn tail line drops");

    // Torn mid-stream: the drop count covers the whole tail, because a
    // later "valid-looking" line after a tear cannot be trusted.
    let (kept, dropped) = iofault::salvage_jsonl("{\"a\":1}\n{bad\n{\"b\":2}\n");
    assert_eq!(kept, vec!["{\"a\":1}"]);
    assert_eq!(dropped, 2);

    let (kept, dropped) = iofault::salvage_jsonl("");
    assert!(kept.is_empty());
    assert_eq!(dropped, 0);
}

/// Schema-strict quarantine salvage: a line that parses as JSON but is
/// not a QuarantineEntry ends the valid prefix, just like a torn line.
#[test]
fn quarantine_salvage_is_schema_strict() {
    let mk = |id: u64| QuarantineEntry {
        req_id: id,
        shard: 0,
        sig: format!("sig{id}"),
        op: "spmm".to_string(),
        f: 64,
        injected: true,
        msg: "injected panic".to_string(),
    };
    let mut text = String::new();
    for id in 0..3 {
        text.push_str(&mk(id).to_json().to_string());
        text.push('\n');
    }
    text.push_str("{\"not\":\"a quarantine entry\"}\n");
    text.push_str(&mk(9).to_json().to_string());
    text.push('\n');

    let (entries, dropped) = QuarantineLog::salvage_jsonl(&text);
    assert_eq!(entries.len(), 3, "schema salvage keeps the conforming prefix");
    assert_eq!(dropped, 2, "the off-schema line and everything after it drop");
    for (id, e) in entries.iter().enumerate() {
        assert_eq!(e.req_id, id as u64);
        assert_eq!(e.sig, format!("sig{id}"));
        assert!(e.injected);
    }

    // A fully well-formed stream round-trips losslessly.
    let (entries, dropped) = QuarantineLog::salvage_jsonl(
        &entries.iter().map(|e| e.to_json().to_string() + "\n").collect::<String>(),
    );
    assert_eq!(entries.len(), 3);
    assert_eq!(dropped, 0);
}

/// One textually-corrupted cache entry quarantines on load without
/// poisoning its neighbors, and a save persists the salvaged view.
#[test]
fn schedule_cache_quarantines_corrupt_entries_individually() {
    // No injector installed here — but cache save/load go through the
    // fault-wrapped I/O layer, so keep other tests' injectors out.
    let _guard = lock_faults();
    let dir = tmpdir("cachequarantine");
    let path = dir.join("cache.json");
    let mut cache = ScheduleCache::load(&path).unwrap();
    cache.insert("spmm|good|64".to_string(), choice("ell_r8_f32"));
    cache.insert("spmm|bad|64".to_string(), choice("hub_r8_f32"));
    cache.save().unwrap();

    // Corrupt exactly one entry: an empty variant fails entry
    // validation while the file as a whole stays parseable JSON.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("hub_r8_f32"));
    std::fs::write(&path, text.replace("hub_r8_f32", "")).unwrap();

    let back = ScheduleCache::load(&path).unwrap();
    assert_eq!(back.quarantined, 1, "the bad entry quarantines");
    assert_eq!(back.len(), 1, "the good entry survives");
    assert!(back.peek("spmm|good|64").is_some());
    assert!(back.peek("spmm|bad|64").is_none());
    assert!(back.is_dirty(), "a quarantining load must mark the cache dirty");

    // Whole-file corruption resets through the salvage path instead.
    std::fs::write(&path, "not json at all {{{").unwrap();
    let (empty, salvage) = ScheduleCache::load_salvaged(&path);
    assert_eq!(empty.len(), 0);
    assert!(salvage.file_reset);
    let mut corrupt = path.as_os_str().to_os_string();
    corrupt.push(".corrupt");
    assert!(
        PathBuf::from(corrupt).exists(),
        "the unparseable original is kept aside for forensics"
    );
}

/// `autosage doctor` through the real binary: a torn trace stream and a
/// stale generational model are reported read-only, repaired under
/// `--fix`, and a re-audit comes back clean.
#[test]
fn doctor_audits_repairs_and_then_finds_nothing() {
    // The fixtures are written through the fault-wrapped model writer.
    let _guard = lock_faults();
    let dir = tmpdir("doctor");

    // Fixture 1: trace.jsonl with two valid lines and a torn tail.
    std::fs::write(
        dir.join("trace.jsonl"),
        "{\"name\":\"a\"}\n{\"name\":\"b\"}\n{\"name\":\"c\",\"du",
    )
    .unwrap();

    // Fixture 2: a generational model whose current file is corrupt but
    // whose previous generation is intact.
    let model_path = dir.join("model.asgm");
    write_model_generational(&model_path, &tiny_model("ell_r8_f32")).unwrap();
    write_model_generational(&model_path, &tiny_model("hub_r8_f32")).unwrap();
    let mut bytes = std::fs::read(&model_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&model_path, &bytes).unwrap();
    let (_, fell_back) = read_model_generational(&model_path).unwrap();
    assert!(fell_back, "fixture sanity: the corrupt current must fall back");

    let doctor = |fix: bool| -> Json {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_autosage"));
        cmd.arg("doctor").arg(&dir).arg("--json");
        if fix {
            cmd.arg("--fix");
        }
        let out = cmd.output().unwrap();
        assert!(
            out.status.success(),
            "doctor failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap()
    };
    let status_of = |j: &Json, artifact: &str| -> String {
        j.get("artifacts")
            .as_arr()
            .unwrap()
            .iter()
            .find(|a| a.get("artifact").as_str() == Some(artifact))
            .unwrap_or_else(|| panic!("doctor must report {artifact}"))
            .get("status")
            .as_str()
            .unwrap()
            .to_string()
    };

    // Audit: both problems visible, nothing touched on disk.
    let audit = doctor(false);
    assert_eq!(status_of(&audit, "trace.jsonl"), "torn");
    assert_eq!(status_of(&audit, "model.asgm"), "stale");
    assert_eq!(audit.get("issues").as_usize(), Some(2));
    assert_eq!(audit.get("repaired").as_usize(), Some(0));
    assert_eq!(std::fs::read(&model_path).unwrap(), bytes, "audit must not mutate");

    // Fix: the torn tail is rewritten away, the model restored from .prev.
    let fixed = doctor(true);
    assert_eq!(status_of(&fixed, "trace.jsonl"), "repaired");
    assert_eq!(status_of(&fixed, "model.asgm"), "repaired");
    assert_eq!(fixed.get("repaired").as_usize(), Some(2));
    assert_eq!(
        std::fs::read_to_string(dir.join("trace.jsonl")).unwrap(),
        "{\"name\":\"a\"}\n{\"name\":\"b\"}\n"
    );
    let (restored, fell_back) = read_model_generational(&model_path).unwrap();
    assert!(!fell_back, "the repaired current generation reads directly");
    assert_eq!(restored, tiny_model("ell_r8_f32"), "repair promotes .prev");

    // Re-audit: everything reads clean.
    let clean = doctor(false);
    assert_eq!(status_of(&clean, "trace.jsonl"), "ok");
    assert_eq!(status_of(&clean, "model.asgm"), "ok");
    assert_eq!(clean.get("issues").as_usize(), Some(0));
}
