//! Integration: the concurrent serving subsystem — sharded worker
//! pool, shared single-flight schedule cache, request coalescing, and
//! bounded queues with backpressure. Runs on the native backend, so no
//! artifacts are needed.

use std::path::PathBuf;
use std::sync::Arc;

use autosage::config::Config;
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::Op;
use autosage::server::{run_load, LoadSpec, ServerPool, SubmitError};

fn cfg(workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Keep debug-mode probes on 512-row subgraphs and short loops.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 200.0;
    cfg.serve_workers = workers;
    cfg
}

fn pool_with(c: Config) -> Arc<ServerPool> {
    Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c).unwrap())
}

/// Many clients, mixed ops, 4 shards: every response matches the
/// single-thread oracle, and each unique (graph, op, F) key is probed
/// exactly once across the whole pool (single-flight + shared cache).
#[test]
fn concurrent_mixed_workload_matches_oracle_with_one_probe_per_key() {
    let pool = pool_with(cfg(4));
    let spec = LoadSpec {
        clients: 8,
        requests_per_client: 2,
        f: 64,
        presets: vec!["er_s".into()],
        ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
        seed: 42,
        verify: true,
        max_retries: 0,
        retry_backoff_us: 200,
        approx_frac: 0.0,
    };
    let report = run_load(Arc::clone(&pool), &spec).unwrap();
    assert_eq!(report.total, 16);
    assert_eq!(report.errors, 0, "{}", report.text);
    assert_eq!(report.mismatches, 0, "{}", report.text);
    assert_eq!(report.unique_keys, 3);
    assert_eq!(report.probes, 3, "{}", report.text);
    assert_eq!(pool.metrics().total_requests(), 16);
}

/// N concurrent misses on ONE key → exactly one probe recorded in the
/// serving metrics; all requests share the one probed decision.
#[test]
fn single_flight_concurrent_misses_probe_once() {
    let pool = pool_with(cfg(4));
    let (g, _) = preset("er_s", 7);
    let f = 64;
    let b: Vec<f32> = (0..g.n_rows * f).map(|i| (i % 17) as f32 * 0.05).collect();
    let want = reference::spmm(&g, &b, f);
    let mut joins = Vec::new();
    for _ in 0..8 {
        let pool = Arc::clone(&pool);
        let g = g.clone();
        let b = b.clone();
        joins.push(std::thread::spawn(move || {
            pool.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap()
        }));
    }
    let mut variants = std::collections::BTreeSet::new();
    for j in joins {
        let resp = j.join().unwrap();
        let out = resp.result.unwrap();
        assert!(reference::max_abs_diff(&out, &want) < 2e-3);
        variants.insert(resp.variant);
    }
    assert_eq!(variants.len(), 1, "all requests must share one decision");
    assert_eq!(pool.metrics().total_probes(), 1, "single-flight violated");
}

/// Bounded queues reject (promptly, with `QueueFull`) instead of
/// growing unboundedly or blocking the submitter.
#[test]
fn bounded_queue_rejects_instead_of_blocking() {
    let mut c = cfg(1);
    c.serve_queue_depth = 1;
    c.serve_batch_max = 1;
    let pool = pool_with(c);
    let (g, _) = preset("er_s", 9);
    let f = 64;
    let b = vec![0.25f32; g.n_rows * f];
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    // The first request sends the worker into a multi-ms probe; the
    // burst lands while the depth-1 queue is occupied.
    for _ in 0..24 {
        match pool.try_submit(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())]) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(rejected > 0, "bounded queue must reject under burst");
    assert!(!accepted.is_empty(), "some requests must be accepted");
    for rx in accepted {
        assert!(rx.recv().unwrap().result.is_ok());
    }
    assert!(pool.metrics().total_rejected() >= rejected);
}

/// Same-key requests inside the batching window execute under ONE
/// decision and are drained together.
#[test]
fn same_key_requests_coalesce_into_one_batch() {
    let mut c = cfg(1);
    c.serve_batch_max = 8;
    c.serve_batch_window_us = 300_000;
    let pool = pool_with(c);
    let (g, _) = preset("er_s", 11);
    let f = 64;
    let b: Vec<f32> = (0..g.n_rows * f).map(|i| (i % 7) as f32 * 0.1).collect();
    let want = reference::spmm(&g, &b, f);
    let rxs: Vec<_> = (0..8)
        .map(|_| {
            pool.submit(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(reference::max_abs_diff(&resp.result.unwrap(), &want) < 2e-3);
        assert!(resp.batch_size >= 1);
    }
    let snap = pool.metrics().snapshot();
    assert_eq!(snap.len(), 1);
    assert_eq!(pool.metrics().total_probes(), 1);
    assert!(
        snap[0].coalesced >= 1,
        "expected coalesced requests, got {:?}",
        snap[0]
    );
    assert!(snap[0].batches < 8, "8 same-key requests within a 300ms \
             window must not make 8 batches: {:?}", snap[0]);
}

/// A bad request errors its own response; the pool keeps serving.
#[test]
fn bad_request_errors_and_pool_survives() {
    let pool = pool_with(cfg(2));
    let (g, _) = preset("er_s", 13);
    let f = 64;
    let resp = pool.call(Op::Spmm, g.clone(), f, vec![]).unwrap();
    assert!(resp.result.is_err(), "missing operand must error");
    let b = vec![0.0f32; g.n_rows * f];
    let resp = pool.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap();
    assert!(resp.result.is_ok(), "pool must survive a bad request");
    assert!(pool.metrics().total_errors() >= 1);
}

/// Warm path: a second wave of identical requests replays decisions
/// from the shared cache (from_cache = true, no new probes).
#[test]
fn second_wave_replays_from_shared_cache() {
    let pool = pool_with(cfg(2));
    let (g, _) = preset("er_s", 17);
    let f = 64;
    let b = vec![0.5f32; g.n_rows * f];
    let r1 = pool
        .call(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
        .unwrap();
    assert!(!r1.from_cache, "first request must probe");
    let probes_after_first = pool.metrics().total_probes();
    let r2 = pool.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap();
    assert!(r2.from_cache, "second request must replay");
    assert_eq!(r2.variant, r1.variant);
    assert_eq!(pool.metrics().total_probes(), probes_after_first);
}
