//! Integration: every PJRT artifact family's output equals the
//! pure-Rust oracle on random graphs. Closes the correctness triangle:
//! Pallas kernel ≡ jnp ref (pytest) ≡ Rust oracle (this file).
//!
//! PJRT-only: needs a build with `--features pjrt`, a real (non-stub)
//! `xla` crate, and `make artifacts`. Auto-skips with a clear message
//! when any of those is missing; the native backend's equivalent
//! coverage lives in `native_vs_oracle.rs` and always runs.

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::{erdos_renyi, hub_skew, preset};
use autosage::ops::reference;
use autosage::util::rng::Rng;

const TOL: f32 = 2e-3;

fn sage() -> Option<AutoSage> {
    if !autosage::backend::pjrt_compiled() {
        eprintln!("SKIP: built without the `pjrt` feature (native backend covers these ops in native_vs_oracle.rs)");
        return None;
    }
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        return None;
    }
    let mut cfg = Config::default();
    cfg.backend = "pjrt".to_string();
    cfg.cache_path = String::new();
    match AutoSage::new(Path::new("artifacts"), cfg, None) {
        Ok(sage) => Some(sage),
        Err(e) => {
            eprintln!("SKIP: PJRT backend failed to initialize: {e:#}");
            None
        }
    }
}

fn dense(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() - 0.5).collect()
}

#[test]
fn spmm_variants_match_oracle_on_er() {
    let Some(mut sage) = sage() else { return };
    let g = erdos_renyi(700, 4.0, 32, 3);
    let f = 64;
    let mut rng = Rng::new(1);
    let b = dense(&mut rng, g.n_rows * f);
    let want = reference::spmm(&g, &b, f);
    for variant in ["baseline", "ell_gather", "hub_gather", "ell_r8_f32", "ell_r32_f32"] {
        let got = sage.spmm_with(&g, &b, f, variant).unwrap();
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < TOL, "spmm {variant}: max diff {d}");
    }
}

#[test]
fn spmm_wide_lane_matches_oracle() {
    let Some(mut sage) = sage() else { return };
    let g = erdos_renyi(700, 4.0, 32, 5);
    let f = 128;
    let mut rng = Rng::new(2);
    let b = dense(&mut rng, g.n_rows * f);
    let want = reference::spmm(&g, &b, f);
    for variant in ["ell_r8_f128", "ell_gather", "baseline"] {
        let got = sage.spmm_with(&g, &b, f, variant).unwrap();
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < TOL, "spmm {variant}: max diff {d}");
    }
}

#[test]
fn spmm_hub_split_matches_oracle_on_skew() {
    let Some(mut sage) = sage() else { return };
    // 15% hubs with degree 400 — forces real hub traffic.
    let g = hub_skew(600, 4, 0.15, 400, 7);
    let f = 64;
    let mut rng = Rng::new(3);
    let b = dense(&mut rng, g.n_rows * f);
    let want = reference::spmm(&g, &b, f);
    for variant in ["hub_gather", "hub_r8_f32", "baseline"] {
        let got = sage.spmm_with(&g, &b, f, variant).unwrap();
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < TOL, "spmm {variant}: max diff {d}");
    }
}

#[test]
fn sddmm_variants_match_oracle() {
    let Some(mut sage) = sage() else { return };
    let g = erdos_renyi(700, 4.0, 32, 11);
    let f = 64;
    let mut rng = Rng::new(4);
    let x = dense(&mut rng, g.n_rows * f);
    let y = dense(&mut rng, g.n_rows * f);
    let want = reference::sddmm(&g, &x, &y, f);
    for variant in ["baseline", "ell_r8_f32"] {
        let got = sage.sddmm_with(&g, &x, &y, f, variant).unwrap();
        assert_eq!(got.len(), g.nnz());
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < TOL, "sddmm {variant}: max diff {d}");
    }
}

#[test]
fn softmax_matches_oracle() {
    let Some(mut sage) = sage() else { return };
    let g = erdos_renyi(700, 4.0, 32, 13);
    let mut rng = Rng::new(5);
    let scores = dense(&mut rng, g.nnz());
    let want = reference::softmax_rows(&g, &scores);
    for variant in ["baseline", "ell_r8"] {
        let got = sage.softmax_with(&g, &scores, variant).unwrap();
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < 1e-4, "softmax {variant}: max diff {d}");
    }
}

#[test]
fn attention_pipeline_matches_oracle() {
    let Some(mut sage) = sage() else { return };
    let g = erdos_renyi(700, 4.0, 32, 17);
    let f = 64;
    let mut rng = Rng::new(6);
    let q = dense(&mut rng, g.n_rows * f);
    let k = dense(&mut rng, g.n_rows * f);
    let v = dense(&mut rng, g.n_rows * f);
    let want = reference::csr_attention(&g, &q, &k, &v, f);
    for variant in ["baseline", "fused_gather", "fused_r8_f32"] {
        let got = sage.attention_with(&g, &q, &k, &v, f, variant).unwrap();
        let d = reference::max_abs_diff(&got, &want);
        assert!(d < TOL, "attention {variant}: max diff {d}");
    }
}

#[test]
fn presets_run_through_auto_path() {
    let Some(mut sage) = sage() else { return };
    // Smallest preset end-to-end through the full scheduling path.
    let (g, _) = preset("er_s", 1);
    let f = 32;
    let mut rng = Rng::new(7);
    let b = dense(&mut rng, g.n_rows * f);
    let got = sage.spmm_auto(&g, &b, f).unwrap();
    let want = reference::spmm(&g, &b, f);
    let d = reference::max_abs_diff(&got, &want);
    assert!(d < TOL, "spmm_auto on er_s: max diff {d}");
}
