//! Scheduler-over-NativeBackend integration: the probe discriminates
//! between parameterized native kernels (distinct winners across the
//! synthetic presets), the guardrail never errors, and the end-to-end
//! `run`-style path completes with no artifacts directory.

use std::collections::BTreeSet;
use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::{probe, Op};

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Probe 512-row induced subgraphs with short loops — keeps the
    // whole basket fast even in debug builds.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 3;
    cfg.probe_cap_ms = 300.0;
    cfg
}

/// Acceptance: `Scheduler::decide` over `NativeBackend` produces at
/// least 3 distinct winning variants across the synthetic presets —
/// the probe can discriminate parameterized native kernels by their
/// degree-skew / feature-width dependent costs.
#[test]
fn native_probe_discriminates_kernels() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let basket: &[(&str, Op, usize)] = &[
        ("er_s", Op::Spmm, 64),
        ("er_s", Op::Spmm, 128),
        ("hub_s", Op::Spmm, 64),
        ("hub_s", Op::Spmm, 128),
        ("reddit_s", Op::Spmm, 128),
        ("products_s", Op::Spmm, 64),
        ("t10a", Op::Spmm, 128),
        ("er_s", Op::Sddmm, 64),
        ("products_s", Op::Attention, 64),
    ];
    let mut winners = BTreeSet::new();
    for &(name, op, f) in basket {
        let (g, _) = preset(name, 42);
        let d = sage
            .decide(&g, op, f)
            .unwrap_or_else(|e| panic!("{name} {op:?} F{f}: {e:#}"));
        // Count raw variant ids (NOT op-qualified): three ops trivially
        // give three op:variant keys, which would prove nothing.
        winners.insert(d.choice.variant().to_string());
        // Every winning variant must actually be deployable.
        if !d.choice.is_baseline() {
            let entry = sage
                .scheduler
                .select_entry(&sage.manifest, &g, op, f, d.choice.variant());
            assert!(entry.is_ok(), "{name}: winner {} not deployable", d.choice.variant());
        }
    }
    assert!(
        winners.len() >= 3,
        "probe cannot discriminate native kernels; winners: {winners:?}"
    );
}

/// Acceptance: the `run --preset er_s --op spmm --f 64` flow (what the
/// CLI does) completes end-to-end on the native backend with outputs
/// matching the Rust oracle to 1e-4, no artifacts directory involved.
#[test]
fn native_run_flow_matches_oracle() {
    let mut sage =
        AutoSage::new(Path::new("no_artifacts_anywhere"), native_cfg(), None).unwrap();
    let (g, _) = preset("er_s", 42);
    let f = 64;
    let data = probe::synth_operands(Op::Spmm, g.n_rows, f, 42);
    let b = data.dense.get("b").unwrap();
    let out = sage.spmm_auto(&g, b, f).unwrap();
    let want = reference::spmm(&g, b, f);
    let d = reference::max_abs_diff(&out, &want);
    assert!(d < 1e-4, "spmm_auto er_s: max diff {d}");

    // Attention pipeline end-to-end too (er_s has attention buckets).
    let data = probe::synth_operands(Op::Attention, g.n_rows, f, 43);
    let q = data.dense.get("q").unwrap();
    let k = data.dense.get("k").unwrap();
    let v = data.dense.get("v").unwrap();
    let out = sage.attention_auto(&g, q, k, v, f).unwrap();
    let want = reference::csr_attention(&g, q, k, v, f);
    let d = reference::max_abs_diff(&out, &want);
    assert!(d < 1e-4, "attention_auto er_s: max diff {d}");
}

/// `AUTOSAGE_BACKEND=auto` resolves to native when there is no
/// artifacts directory — a clean checkout always works.
#[test]
fn auto_backend_defaults_to_native_without_artifacts() {
    let mut cfg = native_cfg();
    cfg.backend = "auto".to_string();
    let sage = AutoSage::new(Path::new("definitely_missing_artifacts"), cfg, None).unwrap();
    if !autosage::backend::pjrt_compiled() || !Path::new("artifacts/manifest.json").exists() {
        assert_eq!(sage.backend_name(), "native");
    }
    assert!(!sage.manifest.entries.is_empty());
}

/// Cached replay: a second decide on the same key never probes, and the
/// decision survives across backend signatures (keys embed the
/// backend's signature so native/pjrt caches never mix).
#[test]
fn native_decisions_cache_and_replay() {
    let mut sage = AutoSage::new(Path::new("x"), native_cfg(), None).unwrap();
    let (g, _) = preset("products_s", 7);
    let d1 = sage.decide(&g, Op::Spmm, 64).unwrap();
    let d2 = sage.decide(&g, Op::Spmm, 64).unwrap();
    assert_eq!(d1.choice.variant(), d2.choice.variant());
    assert_eq!(d2.probe_wall_ms, 0.0);
    assert!(d1.key.starts_with("native"), "key {} lacks backend sig", d1.key);
}
