//! Integration: the resilience layer end-to-end — seeded chaos against
//! the sharded pool (injected errors/panics/latency), deadline
//! shedding, graceful degradation via edge sampling, dead-shard
//! fast-fail, and quarantine. Native backend, no artifacts needed.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use autosage::config::Config;
use autosage::gen::preset;
use autosage::graph::Csr;
use autosage::obs::metrics::MetricsRegistry;
use autosage::ops::reference;
use autosage::scheduler::Op;
use autosage::server::{run_load, FaultKind, LoadSpec, ServeError, ServerPool, SubmitError};

fn cfg(workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Keep debug-mode probes on 512-row subgraphs and short loops.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 200.0;
    cfg.serve_workers = workers;
    cfg
}

fn chaos_cfg(workers: usize, rate: f64, kinds: &str, seed: usize) -> Config {
    let mut c = cfg(workers);
    c.fault_rate = rate;
    c.fault_kinds = kinds.to_string();
    c.fault_seed = seed;
    c.fault_latency_ms = 2.0;
    c
}

/// 4 shards under mixed error+panic+latency chaos: every non-failed
/// reply matches the oracle, no shard dies, the applied fault set is
/// exactly what the pure `decide` function predicts, and a second
/// same-seed run replays the identical set.
#[test]
fn chaos_mixed_workload_stays_correct_and_replays_identically() {
    let spec = LoadSpec {
        clients: 8,
        requests_per_client: 4,
        f: 64,
        presets: vec!["er_s".into()],
        ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
        seed: 42,
        verify: true,
        max_retries: 0,
        retry_backoff_us: 200,
        approx_frac: 0.0,
    };
    let total = (spec.clients * spec.requests_per_client) as u64;
    let registry = Arc::new(MetricsRegistry::new());
    let pool = Arc::new(
        ServerPool::spawn_observed(
            PathBuf::from("artifacts"),
            chaos_cfg(4, 0.3, "error,panic,latency", 7),
            None,
            Some(Arc::clone(&registry)),
        )
        .unwrap(),
    );
    let report = run_load(Arc::clone(&pool), &spec).unwrap();
    assert_eq!(report.mismatches, 0, "{}", report.text);
    assert!(pool.all_shards_alive(), "chaos must not kill a shard");

    // The applied fault multiset is exactly the pure prediction over
    // the id range — placement does not depend on interleaving.
    let inj = pool.resilience().injector.as_ref().expect("chaos is on");
    let predicted: Vec<(u64, FaultKind)> =
        (0..total).filter_map(|id| inj.decide(id).map(|k| (id, k))).collect();
    assert!(!predicted.is_empty(), "rate 0.3 over {total} ids placed no faults");
    assert_eq!(inj.log_snapshot(), predicted);
    assert_eq!(report.faults_injected, predicted.len() as u64, "{}", report.text);

    // Failures split cleanly: injected panics → panic, injected errors
    // → execute, latency alone fails nothing; nothing organic failed.
    let panics = inj.injected_of(FaultKind::Panic) as usize;
    let errors = inj.injected_of(FaultKind::Error) as usize;
    assert_eq!(report.errors_by_kind.panic, panics, "{}", report.text);
    assert_eq!(report.errors_by_kind.execute, errors, "{}", report.text);
    assert_eq!(report.errors, panics + errors, "{}", report.text);
    assert_eq!(report.injected_errors, report.errors, "{}", report.text);
    assert_eq!(report.quarantined, panics, "every injected panic quarantines");
    assert_eq!(
        registry
            .counter("autosage_faults_injected_total")
            .load(std::sync::atomic::Ordering::Relaxed),
        predicted.len() as u64
    );

    // The pool still serves cleanly after the chaos run (fresh request
    // ids keep drawing from the same seeded stream, so pick a clean id
    // implicitly: just require an eventually-ok reply is NOT guaranteed
    // per id — assert the call path works and errors stay typed).
    let (g, _) = preset("er_s", 42);
    let b = vec![0.5f32; g.n_rows * 64];
    let resp = pool.call(Op::Spmm, g, 64, vec![("b".into(), b)]).unwrap();
    if let Err(e) = &resp.result {
        assert!(e.injected(), "post-chaos failures must be injected ones: {e}");
    }

    // Same seed, fresh pool: the applied fault set replays identically.
    let pool2 = Arc::new(
        ServerPool::spawn(
            PathBuf::from("artifacts"),
            chaos_cfg(4, 0.3, "error,panic,latency", 7),
        )
        .unwrap(),
    );
    let report2 = run_load(Arc::clone(&pool2), &spec).unwrap();
    assert_eq!(report2.mismatches, 0, "{}", report2.text);
    let inj2 = pool2.resilience().injector.as_ref().unwrap();
    assert_eq!(
        inj2.log_snapshot(),
        predicted,
        "same-seed chaos must inject the identical (id, kind) set"
    );
}

/// A slow head-of-line request (injected latency) burns queued
/// requests past their deadline: they are shed with a typed
/// `DeadlineExceeded`, not executed.
#[test]
fn deadline_sheds_requests_that_outwait_their_budget() {
    let mut c = chaos_cfg(1, 1.0, "latency", 3);
    c.fault_latency_ms = 50.0;
    c.deadline_ms = 10.0;
    c.serve_batch_max = 1;
    c.serve_queue_depth = 32;
    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c).unwrap());
    let (g, _) = preset("er_s", 5);
    let f = 64;
    let b = vec![0.25f32; g.n_rows * f];
    let rxs: Vec<_> = (0..6)
        .map(|_| {
            pool.submit(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
                .unwrap()
        })
        .collect();
    let mut shed = 0u64;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        match resp.result {
            Err(ServeError::DeadlineExceeded { waited_ms, deadline_ms }) => {
                assert_eq!(deadline_ms, 10.0);
                assert!(waited_ms > deadline_ms, "shed implies the wait exceeded it");
                shed += 1;
            }
            Ok(out) => {
                // Every 50ms latency fault applies, so at most the
                // head-of-line requests can finish inside 10ms of queue
                // wait; correctness still holds for them.
                assert!(!out.is_empty());
            }
            Err(e) => panic!("only deadline sheds expected here, got {e}"),
        }
    }
    assert!(shed > 0, "a 50ms head-of-line stall must shed 10ms-deadline requests");
    assert_eq!(pool.metrics().total_shed(), shed);
    assert!(pool.all_shards_alive());
}

/// Queue-depth overload degrades SpMM to the edge-sampled graph; every
/// degraded reply stays within its advertised error bound.
#[test]
fn overload_degrades_spmm_within_the_advertised_bound() {
    // A 40×40 graph with one heavy hub row (degree 32, mixed-sign
    // weights) and light tail rows the sampler must leave untouched.
    let n = 40usize;
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    rows.push(
        (0..32u32)
            .map(|c| (c, ((c as i32 % 13) - 6) as f32 * 0.21))
            .collect(),
    );
    for r in 1..n {
        rows.push(vec![
            (r as u32 % n as u32, 0.4),
            ((r as u32 + 3) % n as u32, -0.7),
        ]);
    }
    rows.iter_mut().for_each(|r| r.sort_by_key(|&(c, _)| c));
    let g = Csr::from_rows(n, rows);

    let mut c = cfg(1);
    c.serve_batch_max = 1;
    c.serve_queue_depth = 64;
    c.degrade_watermark = 0.01; // depth ≥ 1 already counts as overload
    c.degrade_keep_frac = 0.5;
    c.degrade_min_deg = 4;
    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), c).unwrap());

    let f = 32;
    let b: Vec<f32> = (0..n * f).map(|i| ((i % 11) as f32 - 5.0) * 0.13).collect();
    let max_b = b.iter().fold(0.0f32, |m, x| m.max(x.abs())) as f64;
    let oracle = reference::spmm(&g, &b, f);

    let rxs: Vec<_> = (0..10)
        .map(|_| {
            pool.submit(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
                .unwrap()
        })
        .collect();
    let mut degraded = 0u64;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        let out = resp.result.expect("degradation must not fail requests");
        let diff = reference::max_abs_diff(&out, &oracle) as f64;
        match resp.degraded {
            Some(mass) => {
                degraded += 1;
                assert!(mass > 0.0, "degraded reply must carry a nonzero bound");
                assert!(
                    diff <= mass * max_b + 2e-3,
                    "degraded error {diff} exceeds bound {} (mass {mass})",
                    mass * max_b
                );
            }
            None => assert!(diff < 2e-3, "full-graph reply must match the oracle"),
        }
    }
    assert!(degraded > 0, "a 10-deep burst over watermark 0.01 must degrade");
    assert_eq!(pool.metrics().total_degraded(), degraded);
    assert_eq!(pool.resilience().degrade.len(), 1, "one graph → one sample");
}

/// A stopped shard is visible at submit time: `Closed` immediately,
/// no hanging on a dead queue.
#[test]
fn dead_shard_fails_submissions_fast_with_closed() {
    let pool = Arc::new(ServerPool::spawn(PathBuf::from("artifacts"), cfg(1)).unwrap());
    assert!(pool.all_shards_alive());
    pool.debug_stop_shard(0);
    let deadline = Instant::now() + Duration::from_secs(5);
    while pool.shard_alive(0) {
        assert!(Instant::now() < deadline, "worker must exit on the stop sentinel");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(!pool.all_shards_alive());
    let (g, _) = preset("er_s", 21);
    let b = vec![0.1f32; g.n_rows * 64];
    assert_eq!(
        pool.try_submit(Op::Spmm, g.clone(), 64, vec![("b".into(), b.clone())])
            .err(),
        Some(SubmitError::Closed)
    );
    assert_eq!(
        pool.submit(Op::Spmm, g, 64, vec![("b".into(), b)]).err(),
        Some(SubmitError::Closed)
    );
}

/// Injected panics are caught by supervision: each poisoning request is
/// quarantined with a typed reply and the shard keeps serving.
#[test]
fn injected_panics_quarantine_and_shard_survives() {
    let pool = Arc::new(
        ServerPool::spawn(PathBuf::from("artifacts"), chaos_cfg(1, 1.0, "panic", 11))
            .unwrap(),
    );
    let (g, _) = preset("er_s", 23);
    let f = 64;
    let b = vec![0.3f32; g.n_rows * f];
    for _ in 0..3 {
        let resp = pool
            .call(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
            .unwrap();
        match resp.result {
            Err(ServeError::Panic { injected, ref msg }) => {
                assert!(injected);
                assert!(msg.contains("injected"), "{msg}");
            }
            other => panic!("rate-1.0 panic injection must panic every request: {other:?}"),
        }
        assert_eq!(resp.injected_fault, Some("panic"));
        assert!(pool.shard_alive(0), "supervision must keep the shard alive");
    }
    assert_eq!(pool.metrics().total_panics(), 3);
    assert_eq!(pool.resilience().quarantine.len(), 3);
    for e in pool.resilience().quarantine.snapshot() {
        assert!(e.injected);
        assert_eq!(e.op, "spmm");
        assert_eq!(e.f, f);
        assert!(!e.sig.is_empty());
    }
}
