//! Integration: the coordinator's request-queue service — worker thread
//! owns the execution backend, requests flow over channels, schedule
//! cache amortizes probes across requests. Runs on the native backend,
//! so no artifacts are needed.

use std::path::PathBuf;

use autosage::config::Config;
use autosage::coordinator::ServiceHandle;
use autosage::gen::preset;
use autosage::ops::reference;
use autosage::scheduler::Op;

fn service() -> ServiceHandle {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    // Keep debug-mode probes on 512-row subgraphs and short loops.
    cfg.probe_full_max_rows = 512;
    cfg.probe_iters = 3;
    cfg.probe_cap_ms = 300.0;
    ServiceHandle::spawn(PathBuf::from("artifacts"), cfg)
}

#[test]
fn serves_spmm_and_caches_schedule() {
    let svc = service();
    let (g, _) = preset("er_s", 21);
    let f = 64;
    let b: Vec<f32> = (0..g.n_rows * f).map(|i| (i % 13) as f32 * 0.1).collect();

    let r1 = svc
        .call(Op::Spmm, g.clone(), f, vec![("b".into(), b.clone())])
        .unwrap();
    let out1 = r1.result.unwrap();
    assert!(!r1.from_cache, "first request must probe");
    let want = reference::spmm(&g, &b, f);
    assert!(reference::max_abs_diff(&out1, &want) < 2e-3);

    let r2 = svc
        .call(Op::Spmm, g.clone(), f, vec![("b".into(), b)])
        .unwrap();
    assert!(r2.from_cache, "second request must replay from cache");
    assert_eq!(r2.variant, r1.variant);
}

#[test]
fn serves_attention_and_missing_operand_is_error() {
    let svc = service();
    let (g, _) = preset("er_s", 22);
    let f = 64;
    let n = g.n_rows * f;
    let q: Vec<f32> = (0..n).map(|i| ((i * 7 % 23) as f32) * 0.05 - 0.5).collect();
    let resp = svc
        .call(
            Op::Attention,
            g.clone(),
            f,
            vec![
                ("q".into(), q.clone()),
                ("k".into(), q.clone()),
                ("v".into(), q.clone()),
            ],
        )
        .unwrap();
    let out = resp.result.unwrap();
    let want = reference::csr_attention(&g, &q, &q, &q, f);
    assert!(reference::max_abs_diff(&out, &want) < 2e-3);

    // Missing operand -> error response, service stays alive.
    let resp = svc
        .call(Op::Spmm, g.clone(), f, vec![])
        .unwrap();
    assert!(resp.result.is_err());
    let b = vec![0.0f32; n];
    let resp = svc.call(Op::Spmm, g, f, vec![("b".into(), b)]).unwrap();
    assert!(resp.result.is_ok(), "service must survive a bad request");
}

#[test]
fn pipelined_requests_all_complete() {
    let svc = service();
    let (g, _) = preset("er_s", 23);
    let f = 32;
    // Submit several requests before reading any response.
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            let b: Vec<f32> =
                (0..g.n_rows * f).map(|j| ((i + j) % 11) as f32 * 0.1).collect();
            svc.submit(Op::Spmm, g.clone(), f, vec![("b".into(), b)])
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.result.unwrap().len(), g.n_rows * f);
    }
}
