//! Property tests: every `NativeBackend` kernel variant equals the
//! pure-Rust oracle (`ops::reference`) across randomized graphs, plus
//! the adversarial structures the bucketer must survive: empty rows,
//! a single hub, and max-degree-exactly-at-bucket-boundary.
//!
//! Runs from a clean checkout — the native backend synthesizes its own
//! manifest, no artifacts directory involved.

use std::path::Path;

use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::graph::Csr;
use autosage::ops::reference;
use autosage::util::rng::Rng;

const TOL: f32 = 1e-4;

fn native_sage() -> AutoSage {
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    AutoSage::new(Path::new("ignored_for_native"), cfg, None).unwrap()
}

/// Random CSR: `n` rows, degrees uniform in [0, max_deg].
fn arb_graph(rng: &mut Rng, n: usize, max_deg: usize) -> Csr {
    let rows = (0..n)
        .map(|_| {
            let d = rng.below(max_deg + 1);
            rng.sample_distinct(n, d)
                .into_iter()
                .map(|c| (c as u32, rng.next_f32() - 0.5))
                .collect()
        })
        .collect();
    Csr::from_rows(n, rows)
}

fn dense(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() - 0.5).collect()
}

/// The structural edge cases every variant must handle:
/// * graph with empty rows scattered through it,
/// * a single hub row among degree-1 rows,
/// * max degree == 16, the micro bucket's exact ELL width boundary.
fn edge_case_graphs(rng: &mut Rng) -> Vec<(&'static str, Csr)> {
    // Every third row empty.
    let sparse_rows: Vec<Vec<(u32, f32)>> = (0..60)
        .map(|i| {
            if i % 3 == 0 {
                vec![]
            } else {
                vec![((i as u32 + 1) % 60, rng.next_f32() - 0.5)]
            }
        })
        .collect();
    // One hub of degree 16 (== micro w_plain AND micro w_hub), others degree 1.
    let mut hub_rows: Vec<Vec<(u32, f32)>> = (0..50)
        .map(|i| vec![((i as u32 + 7) % 50, rng.next_f32() - 0.5)])
        .collect();
    hub_rows[11] = (0..16).map(|c| (c as u32, rng.next_f32() - 0.5)).collect();
    // All rows at exactly the micro bucket boundary (deg 16 == w).
    let boundary_rows: Vec<Vec<(u32, f32)>> = (0..40)
        .map(|i| {
            (0..16)
                .map(|k| (((i + k * 3) % 40) as u32, rng.next_f32() - 0.5))
                .collect()
        })
        .collect();
    vec![
        ("empty_rows", Csr::from_rows(60, sparse_rows)),
        ("single_hub", Csr::from_rows(50, hub_rows)),
        ("deg_at_boundary", Csr::from_rows(40, boundary_rows)),
    ]
}

const SPMM_VARIANTS: &[&str] = &[
    "baseline",
    "ell_gather",
    "ell_r8_f32",
    "ell_r32_f32",
    "hub_gather",
    "hub_r8_f32",
];

#[test]
fn prop_spmm_all_variants_match_oracle() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0x5A6E);
    let f = 32;
    for case in 0..12 {
        let n = 40 + rng.below(80);
        let g = arb_graph(&mut rng, n, 12);
        let b = dense(&mut rng, g.n_rows * f);
        let want = reference::spmm(&g, &b, f);
        for variant in SPMM_VARIANTS {
            let got = sage
                .spmm_with(&g, &b, f, variant)
                .unwrap_or_else(|e| panic!("case {case} {variant}: {e:#}"));
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "case {case} spmm {variant}: max diff {d}");
        }
    }
}

#[test]
fn prop_spmm_wide_lane_matches_oracle() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0x1234);
    let f = 128; // F % 128 == 0 -> the vec path is legal
    for case in 0..6 {
        let n = 30 + rng.below(60);
        let g = arb_graph(&mut rng, n, 10);
        let b = dense(&mut rng, g.n_rows * f);
        let want = reference::spmm(&g, &b, f);
        for variant in ["ell_r8_f128", "hub_r8_f128", "ell_gather", "baseline"] {
            let got = sage.spmm_with(&g, &b, f, variant).unwrap();
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "case {case} spmm {variant}: max diff {d}");
        }
    }
}

#[test]
fn spmm_edge_cases_all_variants() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0xED6E);
    let f = 32;
    for (name, g) in edge_case_graphs(&mut rng) {
        let b = dense(&mut rng, g.n_rows * f);
        let want = reference::spmm(&g, &b, f);
        for variant in SPMM_VARIANTS {
            let got = sage
                .spmm_with(&g, &b, f, variant)
                .unwrap_or_else(|e| panic!("{name} {variant}: {e:#}"));
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "{name} spmm {variant}: max diff {d}");
        }
    }
}

#[test]
fn prop_sddmm_variants_match_oracle() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0xDD);
    let f = 32;
    for case in 0..10 {
        let n = 40 + rng.below(60);
        let g = arb_graph(&mut rng, n, 12);
        let x = dense(&mut rng, g.n_rows * f);
        let y = dense(&mut rng, g.n_rows * f);
        let want = reference::sddmm(&g, &x, &y, f);
        for variant in ["baseline", "ell_r8_f32"] {
            let got = sage.sddmm_with(&g, &x, &y, f, variant).unwrap();
            assert_eq!(got.len(), g.nnz(), "case {case}");
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "case {case} sddmm {variant}: max diff {d}");
        }
    }
    // Wide-lane SDDMM at F = 128.
    let f = 128;
    let g = arb_graph(&mut rng, 50, 10);
    let x = dense(&mut rng, g.n_rows * f);
    let y = dense(&mut rng, g.n_rows * f);
    let want = reference::sddmm(&g, &x, &y, f);
    let got = sage.sddmm_with(&g, &x, &y, f, "ell_r8_f128").unwrap();
    let d = reference::max_abs_diff(&got, &want);
    assert!(d < 5e-4, "sddmm ell_r8_f128: max diff {d}");
}

#[test]
fn prop_softmax_matches_oracle_including_empty_rows() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0x50F);
    for case in 0..10 {
        let n = 30 + rng.below(80);
        let g = arb_graph(&mut rng, n, 10);
        let scores = dense(&mut rng, g.nnz());
        let want = reference::softmax_rows(&g, &scores);
        for variant in ["baseline", "ell_r8"] {
            let got = sage.softmax_with(&g, &scores, variant).unwrap();
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "case {case} softmax {variant}: max diff {d}");
        }
        // Row sums are 1 for non-empty rows (sanity on the oracle too).
        let got = sage.softmax_with(&g, &scores, "baseline").unwrap();
        for i in 0..g.n_rows {
            let (a, b) = (g.rowptr[i], g.rowptr[i + 1]);
            if a == b {
                continue;
            }
            let s: f32 = got[a..b].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "case {case} row {i} sums to {s}");
        }
    }
}

#[test]
fn prop_attention_variants_match_oracle() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0xA77);
    let f = 32;
    for case in 0..8 {
        let n = 30 + rng.below(60);
        let g = arb_graph(&mut rng, n, 10);
        let q = dense(&mut rng, g.n_rows * f);
        let k = dense(&mut rng, g.n_rows * f);
        let v = dense(&mut rng, g.n_rows * f);
        let want = reference::csr_attention(&g, &q, &k, &v, f);
        for variant in ["baseline", "fused_gather", "fused_r8_f32"] {
            let got = sage
                .attention_with(&g, &q, &k, &v, f, variant)
                .unwrap_or_else(|e| panic!("case {case} {variant}: {e:#}"));
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "case {case} attention {variant}: max diff {d}");
        }
    }
}

#[test]
fn attention_edge_cases() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0xA778);
    let f = 16;
    for (name, g) in edge_case_graphs(&mut rng) {
        let q = dense(&mut rng, g.n_rows * f);
        let k = dense(&mut rng, g.n_rows * f);
        let v = dense(&mut rng, g.n_rows * f);
        let want = reference::csr_attention(&g, &q, &k, &v, f);
        for variant in ["baseline", "fused_gather"] {
            let got = sage.attention_with(&g, &q, &k, &v, f, variant).unwrap();
            let d = reference::max_abs_diff(&got, &want);
            assert!(d < TOL, "{name} attention {variant}: max diff {d}");
            assert!(got.iter().all(|x| x.is_finite()), "{name}: non-finite output");
        }
    }
}

#[test]
fn auto_path_runs_native_end_to_end() {
    // The full pipeline (estimate -> probe -> guardrail -> execute) over
    // the native backend, matching the oracle regardless of which
    // variant wins.
    let mut cfg = Config::default();
    cfg.backend = "native".to_string();
    cfg.cache_path = String::new();
    cfg.probe_iters = 2;
    cfg.probe_cap_ms = 100.0;
    let mut sage = AutoSage::new(Path::new("x"), cfg, None).unwrap();
    let mut rng = Rng::new(0xE2E);
    let g = arb_graph(&mut rng, 120, 10);
    let f = 32;
    let b = dense(&mut rng, g.n_rows * f);
    let got = sage.spmm_auto(&g, &b, f).unwrap();
    let want = reference::spmm(&g, &b, f);
    assert!(reference::max_abs_diff(&got, &want) < TOL);

    let q = dense(&mut rng, g.n_rows * f);
    let got = sage.attention_auto(&g, &q, &q, &q, f).unwrap();
    let want = reference::csr_attention(&g, &q, &q, &q, f);
    assert!(reference::max_abs_diff(&got, &want) < TOL);
}

#[test]
fn linear_relu_matches_oracle() {
    let mut sage = native_sage();
    let mut rng = Rng::new(0x6C);
    let (n, f_in, f_out) = (100, 16, 16);
    let h = dense(&mut rng, n * f_in);
    let w = dense(&mut rng, f_in * f_out);
    let bias = dense(&mut rng, f_out);
    let got = sage.linear_relu(&h, n, f_in, &w, f_out, &bias).unwrap();
    // Oracle: gcn_layer over an identity-free graph is just the dense
    // transform; compute it directly.
    let mut want = vec![0.0f32; n * f_out];
    for i in 0..n {
        for o in 0..f_out {
            let mut acc = bias[o];
            for k in 0..f_in {
                acc += h[i * f_in + k] * w[k * f_out + o];
            }
            want[i * f_out + o] = acc.max(0.0);
        }
    }
    assert!(reference::max_abs_diff(&got, &want) < TOL);
}
