//! Streaming edge-list (`.txt` / `.csv`) loader.
//!
//! One edge per line — `src dst [weight]` — separated by whitespace,
//! commas, or semicolons. Ids are 0-based node ids in one shared id
//! space (the node count is `max id + 1`, squared by normalization).
//! Comment lines (`#`, `%`, `//`) and a leading non-numeric CSV header
//! are skipped. Missing weights default to 1.0.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::normalize::{normalize, NormOptions};
use super::{CsrGraph, GraphFormat, GraphMeta};

/// Load an edge-list file from disk.
pub fn load_edgelist(path: &Path) -> Result<CsrGraph> {
    let file = File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_edgelist(BufReader::new(file), &path.display().to_string())
}

/// Parse edge-list text from any buffered reader.
pub fn parse_edgelist<R: BufRead>(reader: R, source: &str) -> Result<CsrGraph> {
    let mut entries: Vec<(u32, u32, f32)> = Vec::new();
    let mut max_id = 0u32;
    let mut lineno = 0usize;
    let mut content_lines = 0usize;
    for line in reader.lines() {
        lineno += 1;
        let line = line.with_context(|| format!("reading {source}"))?;
        let t = line.trim();
        if t.is_empty()
            || t.starts_with('#')
            || t.starts_with('%')
            || t.starts_with("//")
        {
            continue;
        }
        content_lines += 1;
        let fields: Vec<&str> = t
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|f| !f.is_empty())
            .collect();
        if fields.len() < 2 || fields.len() > 3 {
            return Err(anyhow!(
                "{source}:{lineno}: expected `src dst [weight]`, got {t:?}"
            ));
        }
        let src: u32 = match fields[0].parse() {
            Ok(v) => v,
            // Only the FIRST content line may be a CSV header
            // ("src,dst,w"); any later unparsable line is an error, not
            // a silent skip.
            Err(_) if content_lines == 1 => continue,
            Err(_) => {
                return Err(anyhow!(
                    "{source}:{lineno}: bad node id {:?}",
                    fields[0]
                ))
            }
        };
        let dst: u32 = fields[1].parse().map_err(|_| {
            anyhow!("{source}:{lineno}: bad node id {:?}", fields[1])
        })?;
        let w: f32 = match fields.get(2) {
            None => 1.0,
            Some(f) => f.parse().map_err(|_| {
                anyhow!("{source}:{lineno}: bad weight {:?}", f)
            })?,
        };
        max_id = max_id.max(src).max(dst);
        entries.push((src, dst, w));
    }
    let n = if entries.is_empty() {
        0
    } else {
        max_id as usize + 1
    };
    let opts = NormOptions {
        make_square: true,
        ..NormOptions::default()
    };
    let (csr, norm) = normalize(n, n, entries, opts)
        .with_context(|| format!("normalizing {source}"))?;
    Ok(CsrGraph {
        csr,
        meta: GraphMeta {
            source: source.to_string(),
            format: GraphFormat::EdgeList,
            norm,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<CsrGraph> {
        parse_edgelist(text.as_bytes(), "<test>")
    }

    #[test]
    fn whitespace_and_weights() {
        let g = parse("0 1 2.5\n1\t2\n2 0 0.5\n").unwrap();
        assert_eq!(g.csr.n_rows, 3);
        assert_eq!(g.csr.nnz(), 3);
        assert_eq!(g.csr.row(1), (&[2u32][..], &[1.0f32][..])); // default w
        assert_eq!(g.meta.format, GraphFormat::EdgeList);
    }

    #[test]
    fn csv_with_header_and_comments() {
        let g = parse("# graph\nsrc,dst,w\n0,3,1.0\n3,0,2.0\n% tail\n").unwrap();
        assert_eq!(g.csr.n_rows, 4); // squared to max id + 1
        assert_eq!(g.csr.n_cols, 4);
        assert_eq!(g.csr.nnz(), 2);
    }

    #[test]
    fn duplicate_edges_merge() {
        let g = parse("0 1 1.0\n0 1 2.0\n").unwrap();
        assert_eq!(g.csr.nnz(), 1);
        assert_eq!(g.csr.row(0).1, &[3.0]);
        assert_eq!(g.meta.norm.dups_merged, 1);
    }

    #[test]
    fn empty_file_is_empty_graph() {
        let g = parse("# nothing\n").unwrap();
        assert_eq!(g.csr.n_rows, 0);
        assert_eq!(g.csr.nnz(), 0);
    }

    #[test]
    fn rejects_garbage_rows() {
        assert!(parse("0 1\nnope nope\n").is_err()); // header only valid first
        assert!(parse("0\n").is_err());
        assert!(parse("0 1 2 3\n").is_err());
    }

    #[test]
    fn non_numeric_file_errors_instead_of_parsing_empty() {
        // Only the first content line is header-eligible; a name-based
        // edge list must fail loudly, not load as an empty graph.
        assert!(parse("alice bob\ncarol dave\n").is_err());
        assert!(parse("# c\nsrc dst\nalice bob\n").is_err());
    }
}
