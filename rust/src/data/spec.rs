//! Graph-spec resolution: one string names a graph everywhere a preset
//! was accepted before.
//!
//! * `"reddit_s"` (any name in [`preset_names`]) — a synthetic preset,
//!   generated from the spec seed.
//! * `"file:PATH"` — a loaded dataset; the format is picked from the
//!   extension (`.asg` snapshot, `.mtx` Matrix Market, anything else is
//!   parsed as an edge list). Seeds are ignored for files.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::gen::{preset, preset_names};
use crate::graph::Csr;

use super::CsrGraph;

/// A parsed graph spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphSpec {
    Preset(String),
    File(PathBuf),
}

impl GraphSpec {
    pub fn parse(s: &str) -> Result<GraphSpec> {
        if let Some(p) = s.strip_prefix("file:") {
            if p.is_empty() {
                return Err(anyhow!("empty path in graph spec {s:?}"));
            }
            return Ok(GraphSpec::File(PathBuf::from(p)));
        }
        if preset_names().contains(&s) {
            return Ok(GraphSpec::Preset(s.to_string()));
        }
        Err(anyhow!(
            "unknown graph spec {s:?}: use a preset ({}) or file:PATH",
            preset_names().join("|")
        ))
    }

    /// Resolve to a graph + a human-readable label.
    pub fn load(&self, seed: u64) -> Result<(Csr, String)> {
        match self {
            GraphSpec::Preset(name) => {
                let (g, spec) = preset(name, seed);
                Ok((g, format!("{name} ({})", spec.paper_name)))
            }
            GraphSpec::File(path) => {
                let loaded = CsrGraph::load(path)?;
                let label = format!(
                    "{} [{}]",
                    file_stem(path),
                    loaded.meta.format.as_str()
                );
                Ok((loaded.csr, label))
            }
        }
    }
}

fn file_stem(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string())
}

/// One-shot convenience: parse + load.
pub fn load_graph_spec(s: &str, seed: u64) -> Result<(Csr, String)> {
    GraphSpec::parse(s)?.load(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_presets_and_files() {
        assert_eq!(
            GraphSpec::parse("er_s").unwrap(),
            GraphSpec::Preset("er_s".into())
        );
        assert_eq!(
            GraphSpec::parse("file:/tmp/x.asg").unwrap(),
            GraphSpec::File(PathBuf::from("/tmp/x.asg"))
        );
        assert!(GraphSpec::parse("no_such_preset").is_err());
        assert!(GraphSpec::parse("file:").is_err());
    }

    #[test]
    fn preset_specs_load_seeded() {
        let (a, label) = load_graph_spec("er_s", 7).unwrap();
        let (b, _) = load_graph_spec("er_s", 7).unwrap();
        assert_eq!(a, b);
        assert!(label.contains("er_s"), "{label}");
        let (c, _) = load_graph_spec("er_s", 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn missing_file_is_a_clean_error() {
        let err = load_graph_spec("file:/nonexistent/g.asg", 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("/nonexistent/g.asg"), "{msg}");
    }
}
