//! Deterministic normalization of raw edge triplets into [`Csr`].
//!
//! Every loader funnels through [`normalize`] so all ingestion paths
//! agree on one canonical form: rows sorted by column, duplicate edges
//! merged by summing their values (in sorted order, so the sum order is
//! deterministic), self-loops counted and optionally dropped, and
//! symmetric sources mirrored before the sort. The [`NormReport`]
//! records what the pass did — it is part of the graph's provenance
//! (`autosage data inspect`).

use anyhow::{anyhow, Result};

use crate::graph::Csr;

/// Normalization switches. Loaders pick the policy that matches their
/// format's semantics; the defaults are the least surprising ones for
/// an explicit-dimension source (Matrix Market `general`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NormOptions {
    /// Mirror every off-diagonal `(i, j)` as `(j, i)` before building
    /// (Matrix Market `symmetric` stores one triangle only).
    pub symmetrize: bool,
    /// Drop `(i, i)` entries instead of keeping them as ordinary
    /// nonzeros (kernels treat self-loops as normal edges, so the
    /// default keeps them).
    pub drop_self_loops: bool,
    /// Grow the node space to `max(n_rows, n_cols)` on both axes —
    /// edge lists describe one node id space, not a rectangular matrix.
    pub make_square: bool,
}

/// What one normalization pass observed and did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NormReport {
    /// Entries as read from the source (after symmetric mirroring).
    pub n_raw: usize,
    /// Duplicate `(i, j)` entries merged into their predecessor (sum).
    pub dups_merged: usize,
    /// Self-loop entries observed in the source.
    pub self_loops: usize,
    /// Self-loops removed (0 unless `drop_self_loops`).
    pub self_loops_dropped: usize,
}

/// Build a canonical CSR from raw `(row, col, val)` triplets.
///
/// Deterministic: the output depends only on the entry multiset and the
/// options, never on source order (entries are sorted before merging).
pub fn normalize(
    n_rows: usize,
    n_cols: usize,
    mut entries: Vec<(u32, u32, f32)>,
    opts: NormOptions,
) -> Result<(Csr, NormReport)> {
    let (n_rows, n_cols) = if opts.make_square {
        let n = n_rows.max(n_cols);
        (n, n)
    } else {
        (n_rows, n_cols)
    };
    if opts.symmetrize {
        let mirrored: Vec<(u32, u32, f32)> = entries
            .iter()
            .filter(|(r, c, _)| r != c)
            .map(|&(r, c, v)| (c, r, v))
            .collect();
        entries.extend(mirrored);
    }
    let mut report = NormReport {
        n_raw: entries.len(),
        ..NormReport::default()
    };
    for &(r, c, _) in &entries {
        if r as usize >= n_rows {
            return Err(anyhow!("row id {r} out of range (n_rows {n_rows})"));
        }
        if c as usize >= n_cols {
            return Err(anyhow!("col id {c} out of range (n_cols {n_cols})"));
        }
        if r == c {
            report.self_loops += 1;
        }
    }
    if opts.drop_self_loops {
        let before = entries.len();
        entries.retain(|(r, c, _)| r != c);
        report.self_loops_dropped = before - entries.len();
    }
    // Sort by (row, col); merging adjacent duplicates in sorted order
    // makes the value sum deterministic regardless of source order.
    entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
    let mut rowptr = vec![0usize; n_rows + 1];
    let mut colind: Vec<u32> = Vec::with_capacity(entries.len());
    let mut val: Vec<f32> = Vec::with_capacity(entries.len());
    let mut rows_seen: Vec<usize> = Vec::with_capacity(entries.len());
    for &(r, c, v) in &entries {
        if let (Some(&lr), Some(&lc)) = (rows_seen.last(), colind.last()) {
            if lr == r as usize && lc == c {
                *val.last_mut().expect("val tracks colind") += v;
                report.dups_merged += 1;
                continue;
            }
        }
        rows_seen.push(r as usize);
        colind.push(c);
        val.push(v);
        rowptr[r as usize + 1] += 1;
    }
    for i in 0..n_rows {
        rowptr[i + 1] += rowptr[i];
    }
    let g = Csr {
        n_rows,
        n_cols,
        rowptr,
        colind,
        val,
    };
    g.validate().map_err(|e| anyhow!("normalized CSR invalid: {e}"))?;
    Ok((g, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_rows_and_merges_duplicates() {
        let entries = vec![(1, 3, 1.0), (0, 2, 2.0), (1, 3, 0.5), (1, 0, 4.0)];
        let (g, rep) = normalize(2, 4, entries, NormOptions::default()).unwrap();
        assert_eq!(g.nnz(), 3);
        assert_eq!(rep.dups_merged, 1);
        let (cols, vals) = g.row(1);
        assert_eq!(cols, &[0, 3]);
        assert_eq!(vals, &[4.0, 1.5]);
    }

    #[test]
    fn order_independent() {
        let a = vec![(0, 1, 1.0), (2, 0, 2.0), (1, 1, 3.0)];
        let mut b = a.clone();
        b.reverse();
        let (ga, _) = normalize(3, 3, a, NormOptions::default()).unwrap();
        let (gb, _) = normalize(3, 3, b, NormOptions::default()).unwrap();
        assert_eq!(ga, gb);
    }

    #[test]
    fn self_loop_policy() {
        let entries = vec![(0, 0, 1.0), (1, 0, 2.0), (1, 1, 3.0)];
        let keep = NormOptions::default();
        let (g, rep) = normalize(2, 2, entries.clone(), keep).unwrap();
        assert_eq!(g.nnz(), 3);
        assert_eq!(rep.self_loops, 2);
        assert_eq!(rep.self_loops_dropped, 0);

        let drop = NormOptions {
            drop_self_loops: true,
            ..NormOptions::default()
        };
        let (g, rep) = normalize(2, 2, entries, drop).unwrap();
        assert_eq!(g.nnz(), 1);
        assert_eq!(rep.self_loops_dropped, 2);
    }

    #[test]
    fn symmetrize_mirrors_off_diagonal_only() {
        let entries = vec![(0, 1, 1.0), (1, 1, 5.0)];
        let opts = NormOptions {
            symmetrize: true,
            ..NormOptions::default()
        };
        let (g, rep) = normalize(2, 2, entries, opts).unwrap();
        assert_eq!(rep.n_raw, 3); // (0,1) mirrored, diagonal not
        assert_eq!(g.nnz(), 3);
        assert_eq!(g.row(1).0, &[0, 1]);
    }

    #[test]
    fn make_square_grows_both_axes() {
        let entries = vec![(0, 4, 1.0)];
        let opts = NormOptions {
            make_square: true,
            ..NormOptions::default()
        };
        let (g, _) = normalize(1, 5, entries, opts).unwrap();
        assert_eq!((g.n_rows, g.n_cols), (5, 5));
    }

    #[test]
    fn rejects_out_of_range_ids() {
        assert!(normalize(2, 2, vec![(2, 0, 1.0)], NormOptions::default()).is_err());
        assert!(normalize(2, 2, vec![(0, 2, 1.0)], NormOptions::default()).is_err());
    }

    #[test]
    fn empty_input_is_valid() {
        let (g, rep) = normalize(3, 3, vec![], NormOptions::default()).unwrap();
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.n_rows, 3);
        assert_eq!(rep.n_raw, 0);
    }
}
