//! Streaming Matrix Market (`.mtx`) loader.
//!
//! Supports the coordinate format with `real`/`double`/`integer`/
//! `pattern` fields and `general`/`symmetric` symmetry — the subset the
//! paper's evaluation graphs (SuiteSparse exports of Reddit-like
//! matrices) actually use. The file is read line-by-line through a
//! `BufRead`, never materialized as one string; entries funnel through
//! [`normalize`](super::normalize::normalize) (symmetric sources are
//! mirrored there).

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::normalize::{normalize, NormOptions};
use super::{CsrGraph, GraphFormat, GraphMeta};

/// Load a `.mtx` file from disk.
pub fn load_mtx(path: &Path) -> Result<CsrGraph> {
    let file = File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_mtx(BufReader::new(file), &path.display().to_string())
}

/// Parse Matrix Market text from any buffered reader.
pub fn parse_mtx<R: BufRead>(reader: R, source: &str) -> Result<CsrGraph> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow!("{source}: empty file"))?
        .with_context(|| format!("reading {source}"))?;
    let toks: Vec<String> = header
        .split_whitespace()
        .map(|t| t.to_ascii_lowercase())
        .collect();
    if toks.len() < 5 || !toks[0].starts_with("%%matrixmarket") {
        return Err(anyhow!(
            "{source}: not a MatrixMarket header: {header:?}"
        ));
    }
    if toks[1] != "matrix" || toks[2] != "coordinate" {
        return Err(anyhow!(
            "{source}: only `matrix coordinate` is supported, got `{} {}`",
            toks[1],
            toks[2]
        ));
    }
    let pattern = match toks[3].as_str() {
        "real" | "double" | "integer" => false,
        "pattern" => true,
        other => {
            return Err(anyhow!("{source}: unsupported field type {other:?}"))
        }
    };
    let symmetric = match toks[4].as_str() {
        "general" => false,
        "symmetric" => true,
        other => {
            return Err(anyhow!("{source}: unsupported symmetry {other:?}"))
        }
    };

    // Size line: first non-comment, non-blank line after the header.
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut entries: Vec<(u32, u32, f32)> = Vec::new();
    let mut lineno = 1usize;
    for line in lines {
        lineno += 1;
        let line = line.with_context(|| format!("reading {source}"))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let fields: Vec<&str> = t.split_whitespace().collect();
        match dims {
            None => {
                if fields.len() != 3 {
                    return Err(anyhow!(
                        "{source}:{lineno}: size line needs `rows cols nnz`, got {t:?}"
                    ));
                }
                let d: Vec<usize> = fields
                    .iter()
                    .map(|f| {
                        f.parse().map_err(|_| {
                            anyhow!("{source}:{lineno}: bad size value {f:?}")
                        })
                    })
                    .collect::<Result<_>>()?;
                dims = Some((d[0], d[1], d[2]));
                // Untrusted header: cap the pre-allocation.
                entries.reserve(d[2].min(1 << 24));
            }
            Some((n_rows, n_cols, _)) => {
                let want = if pattern { 2 } else { 3 };
                if fields.len() < want {
                    return Err(anyhow!(
                        "{source}:{lineno}: entry needs {want} fields, got {t:?}"
                    ));
                }
                let i: usize = fields[0].parse().map_err(|_| {
                    anyhow!("{source}:{lineno}: bad row id {:?}", fields[0])
                })?;
                let j: usize = fields[1].parse().map_err(|_| {
                    anyhow!("{source}:{lineno}: bad col id {:?}", fields[1])
                })?;
                // Matrix Market is 1-based.
                if i == 0 || j == 0 || i > n_rows || j > n_cols {
                    return Err(anyhow!(
                        "{source}:{lineno}: entry ({i}, {j}) outside {n_rows}x{n_cols}"
                    ));
                }
                let v: f32 = if pattern {
                    1.0
                } else {
                    fields[2].parse().map_err(|_| {
                        anyhow!("{source}:{lineno}: bad value {:?}", fields[2])
                    })?
                };
                entries.push(((i - 1) as u32, (j - 1) as u32, v));
            }
        }
    }
    let (n_rows, n_cols, nnz_decl) =
        dims.ok_or_else(|| anyhow!("{source}: missing size line"))?;
    if entries.len() != nnz_decl {
        return Err(anyhow!(
            "{source}: header declares {nnz_decl} entries, file has {}",
            entries.len()
        ));
    }
    let opts = NormOptions {
        symmetrize: symmetric,
        ..NormOptions::default()
    };
    let (csr, norm) = normalize(n_rows, n_cols, entries, opts)
        .with_context(|| format!("normalizing {source}"))?;
    Ok(CsrGraph {
        csr,
        meta: GraphMeta {
            source: source.to_string(),
            format: GraphFormat::MatrixMarket,
            norm,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<CsrGraph> {
        parse_mtx(text.as_bytes(), "<test>")
    }

    #[test]
    fn parses_general_real() {
        let g = parse(
            "%%MatrixMarket matrix coordinate real general\n\
             % a comment\n\
             3 3 4\n\
             1 2 1.5\n\
             2 1 2.0\n\
             3 3 -1.0\n\
             1 1 0.5\n",
        )
        .unwrap();
        assert_eq!(g.csr.n_rows, 3);
        assert_eq!(g.csr.nnz(), 4);
        assert_eq!(g.csr.row(0).0, &[0, 1]); // sorted by column
        assert_eq!(g.meta.norm.self_loops, 2);
        assert_eq!(g.meta.format, GraphFormat::MatrixMarket);
    }

    #[test]
    fn pattern_entries_get_unit_values() {
        let g = parse(
            "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 2\n\
             1 2\n\
             2 1\n",
        )
        .unwrap();
        assert_eq!(g.csr.val, vec![1.0, 1.0]);
    }

    #[test]
    fn symmetric_mirrors_lower_triangle() {
        let g = parse(
            "%%MatrixMarket matrix coordinate real symmetric\n\
             3 3 3\n\
             2 1 5.0\n\
             3 1 6.0\n\
             2 2 7.0\n",
        )
        .unwrap();
        // (1,0) and (2,0) mirrored; diagonal (1,1) not.
        assert_eq!(g.csr.nnz(), 5);
        assert_eq!(g.csr.row(0).0, &[1, 2]);
        assert_eq!(g.csr.row(0).1, &[5.0, 6.0]);
    }

    #[test]
    fn rejects_bad_headers_and_bounds() {
        assert!(parse("1 2 3\n").is_err());
        assert!(parse("%%MatrixMarket matrix array real general\n2 2\n").is_err());
        assert!(parse(
            "%%MatrixMarket matrix coordinate complex general\n1 1 0\n"
        )
        .is_err());
        assert!(parse(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n"
        )
        .is_err());
        // 0-based ids are invalid in 1-based MatrixMarket.
        assert!(parse(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_nnz_mismatch() {
        assert!(parse(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n"
        )
        .is_err());
    }
}
