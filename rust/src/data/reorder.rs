//! Degree-aware row reordering (the preprocessing lever from cache-first
//! edge sampling: *where* a row sits changes tile fill and locality even
//! though per-row work is fixed).
//!
//! All passes are pure **row** permutations — columns are untouched — so
//! kernel results are bit-for-bit permutation-invariant: row `i` of the
//! reordered output is row `perm[i]` of the original, with identical
//! slot order and therefore identical f32 summation order. The
//! [`Reordered`] handle carries the composed permutation and its
//! inverse, plus helpers to (un)permute row-indexed dense operands and
//! per-edge outputs, so callers can always map results back to original
//! node ids.
//!
//! Each run emits a [`ReorderReport`] of layout metrics before/after
//! (bandwidth, head-block density, per-tile ELL fill) — the quantities
//! that feed `scheduler::features` and `graph::signature::layout_digest`.

use std::fmt;

use anyhow::{anyhow, Result};

use crate::graph::Csr;

/// One composable reordering pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReorderPass {
    /// Stable sort rows by descending degree: hubs pack to the top,
    /// giving the hub-split variants one dense head block.
    HubPack,
    /// Stable counting sort by log2-degree bucket (descending): rows
    /// with similar widths become neighbors — evening out per-tile ELL
    /// widths — while original order inside each bucket preserves
    /// whatever locality the source ids had.
    SegmentSort,
    /// Reverse row order. Useless for performance; invaluable for
    /// testing composition and un-permutation.
    Reverse,
}

impl ReorderPass {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReorderPass::HubPack => "hub-pack",
            ReorderPass::SegmentSort => "segment-sort",
            ReorderPass::Reverse => "reverse",
        }
    }

    pub fn parse(s: &str) -> Option<ReorderPass> {
        match s.trim() {
            "hub-pack" | "hubpack" => Some(ReorderPass::HubPack),
            "segment-sort" | "segsort" => Some(ReorderPass::SegmentSort),
            "reverse" => Some(ReorderPass::Reverse),
            _ => None,
        }
    }
}

/// Parse a comma-separated pass list (`"hub-pack,segment-sort"`).
pub fn parse_passes(spec: &str) -> Result<Vec<ReorderPass>> {
    let mut passes = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        passes.push(ReorderPass::parse(tok).ok_or_else(|| {
            anyhow!(
                "unknown reorder pass {tok:?} (valid: hub-pack, segment-sort, reverse)"
            )
        })?);
    }
    if passes.is_empty() {
        return Err(anyhow!("empty reorder pass list {spec:?}"));
    }
    Ok(passes)
}

pub use crate::graph::csr::METRIC_TILE_ROWS;

/// Layout-sensitive metrics of one CSR row order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayoutMetrics {
    /// Mean |row - col| over stored edges, normalized by the node span
    /// (0 = diagonal, → 1 = anti-diagonal scatter).
    pub bandwidth: f64,
    /// Fraction of nnz owned by the first ceil(1%) of rows — the
    /// "hub-block density" a packed layout maximizes.
    pub head_nnz_frac: f64,
    /// nnz / padded slots when rows are tiled in groups of
    /// [`METRIC_TILE_ROWS`] with per-tile width = tile max degree
    /// (1.0 = no padding waste).
    pub tile_fill: f64,
}

impl LayoutMetrics {
    pub fn measure(g: &Csr) -> LayoutMetrics {
        LayoutMetrics {
            bandwidth: g.bandwidth_frac(),
            head_nnz_frac: g.head_nnz_frac(),
            tile_fill: g.tile_fill(METRIC_TILE_ROWS),
        }
    }
}

/// Before/after layout metrics for one reorder run.
#[derive(Debug, Clone, PartialEq)]
pub struct ReorderReport {
    pub passes: Vec<ReorderPass>,
    pub before: LayoutMetrics,
    pub after: LayoutMetrics,
}

impl fmt::Display for ReorderReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let names: Vec<&str> = self.passes.iter().map(|p| p.as_str()).collect();
        writeln!(f, "reorder [{}]:", names.join(","))?;
        let row = |f: &mut fmt::Formatter<'_>, name: &str, b: f64, a: f64| {
            writeln!(
                f,
                "  {name:<14} {b:>8.4} -> {a:>8.4}  ({:+.4})",
                a - b
            )
        };
        row(f, "bandwidth", self.before.bandwidth, self.after.bandwidth)?;
        row(
            f,
            "head-nnz-frac",
            self.before.head_nnz_frac,
            self.after.head_nnz_frac,
        )?;
        row(f, "tile-fill", self.before.tile_fill, self.after.tile_fill)
    }
}

/// A reordered graph plus the bookkeeping to undo it.
#[derive(Debug, Clone, PartialEq)]
pub struct Reordered {
    /// The row-permuted graph.
    pub graph: Csr,
    /// `perm[new_row] = original_row` (composed over all passes).
    pub perm: Vec<u32>,
    pub report: ReorderReport,
}

/// Permute rows of `g`: row `i` of the result is row `perm[i]` of `g`.
/// Columns (and per-row slot order) are untouched.
pub fn permute_rows(g: &Csr, perm: &[usize]) -> Csr {
    debug_assert_eq!(perm.len(), g.n_rows);
    let mut rowptr = Vec::with_capacity(g.n_rows + 1);
    let mut colind = Vec::with_capacity(g.nnz());
    let mut val = Vec::with_capacity(g.nnz());
    rowptr.push(0);
    for &old in perm {
        let (cols, vals) = g.row(old);
        colind.extend_from_slice(cols);
        val.extend_from_slice(vals);
        rowptr.push(colind.len());
    }
    Csr {
        n_rows: g.n_rows,
        n_cols: g.n_cols,
        rowptr,
        colind,
        val,
    }
}

fn pass_perm(g: &Csr, pass: ReorderPass) -> Vec<usize> {
    let n = g.n_rows;
    let mut idx: Vec<usize> = (0..n).collect();
    match pass {
        ReorderPass::HubPack => {
            let degs = g.degrees();
            idx.sort_by_key(|&i| (std::cmp::Reverse(degs[i]), i));
        }
        ReorderPass::SegmentSort => {
            let degs = g.degrees();
            // log2 bucket: 0 for empty rows, else floor(log2(d)) + 1.
            let bucket = |d: usize| -> u32 {
                if d == 0 {
                    0
                } else {
                    usize::BITS - d.leading_zeros()
                }
            };
            idx.sort_by_key(|&i| (std::cmp::Reverse(bucket(degs[i])), i));
        }
        ReorderPass::Reverse => idx.reverse(),
    }
    idx
}

/// Run `passes` left-to-right over `g`, composing their permutations.
pub fn reorder(g: &Csr, passes: &[ReorderPass]) -> Reordered {
    let before = LayoutMetrics::measure(g);
    let mut perm: Vec<usize> = (0..g.n_rows).collect();
    let mut cur = g.clone();
    for &pass in passes {
        let p = pass_perm(&cur, pass);
        cur = permute_rows(&cur, &p);
        perm = p.iter().map(|&np| perm[np]).collect();
    }
    let after = LayoutMetrics::measure(&cur);
    Reordered {
        graph: cur,
        perm: perm.into_iter().map(|v| v as u32).collect(),
        report: ReorderReport {
            passes: passes.to_vec(),
            before,
            after,
        },
    }
}

/// Rebuild a [`Reordered`] handle from a snapshot that stored its
/// permutation (`data::asg`): `graph` is the permuted graph as loaded,
/// `perm[new] = original`. Metrics are measured on the permuted graph
/// for both sides (the original is not available), passes are empty.
pub fn from_stored_perm(graph: Csr, perm: Vec<u32>) -> Result<Reordered> {
    if perm.len() != graph.n_rows {
        return Err(anyhow!(
            "stored perm length {} != n_rows {}",
            perm.len(),
            graph.n_rows
        ));
    }
    let m = LayoutMetrics::measure(&graph);
    Ok(Reordered {
        graph,
        perm,
        report: ReorderReport {
            passes: vec![],
            before: m,
            after: m,
        },
    })
}

impl Reordered {
    /// `inv[original_row] = new_row`.
    pub fn inverse(&self) -> Vec<u32> {
        let mut inv = vec![0u32; self.perm.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            inv[old as usize] = new as u32;
        }
        inv
    }

    /// Permute a row-indexed dense operand (`f` values per row) into the
    /// reordered row space: row `i` of the result is row `perm[i]`.
    pub fn permute_rowwise(&self, x: &[f32], f: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.perm.len() * f);
        let mut out = Vec::with_capacity(x.len());
        for &old in &self.perm {
            let o = old as usize * f;
            out.extend_from_slice(&x[o..o + f]);
        }
        out
    }

    /// Undo [`permute_rowwise`] on a row-indexed output.
    pub fn unpermute_rowwise(&self, y: &[f32], f: usize) -> Vec<f32> {
        debug_assert_eq!(y.len(), self.perm.len() * f);
        let mut out = vec![0.0f32; y.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            out[old as usize * f..old as usize * f + f]
                .copy_from_slice(&y[new * f..new * f + f]);
        }
        out
    }

    /// Map per-edge values (CSR slot order of the *reordered* graph)
    /// back to the original graph's slot order.
    pub fn unpermute_edges(&self, vals: &[f32]) -> Vec<f32> {
        debug_assert_eq!(vals.len(), self.graph.nnz());
        let inv = self.inverse();
        let mut out = Vec::with_capacity(vals.len());
        // `inv` is walked in original-row order, so segments append in
        // the original slot order.
        for &new in &inv {
            let new = new as usize;
            let (a, b) = (self.graph.rowptr[new], self.graph.rowptr[new + 1]);
            out.extend_from_slice(&vals[a..b]);
        }
        out
    }

    /// Reconstruct the original graph (bit-for-bit) by applying the
    /// inverse permutation.
    pub fn restore_graph(&self) -> Csr {
        let inv: Vec<usize> =
            self.inverse().into_iter().map(|v| v as usize).collect();
        permute_rows(&self.graph, &inv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::hub_skew;
    use crate::graph::signature::graph_signature;

    fn skewed() -> Csr {
        hub_skew(256, 3, 0.1, 24, 7)
    }

    #[test]
    fn parse_pass_lists() {
        assert_eq!(
            parse_passes("hub-pack,segment-sort").unwrap(),
            vec![ReorderPass::HubPack, ReorderPass::SegmentSort]
        );
        assert_eq!(
            parse_passes(" segsort , reverse ").unwrap(),
            vec![ReorderPass::SegmentSort, ReorderPass::Reverse]
        );
        assert!(parse_passes("nope").is_err());
        assert!(parse_passes("").is_err());
        for p in [ReorderPass::HubPack, ReorderPass::SegmentSort, ReorderPass::Reverse] {
            assert_eq!(ReorderPass::parse(p.as_str()), Some(p));
        }
    }

    #[test]
    fn hub_pack_sorts_degrees_descending() {
        let g = skewed();
        let r = reorder(&g, &[ReorderPass::HubPack]);
        let degs = r.graph.degrees();
        for w in degs.windows(2) {
            assert!(w[0] >= w[1], "degrees not descending: {:?}", w);
        }
        // Head-block density must improve on a skewed graph.
        assert!(
            r.report.after.head_nnz_frac > r.report.before.head_nnz_frac,
            "{:?}",
            r.report
        );
    }

    #[test]
    fn segment_sort_improves_tile_fill_on_skew() {
        let g = skewed();
        let r = reorder(&g, &[ReorderPass::SegmentSort]);
        assert!(
            r.report.after.tile_fill > r.report.before.tile_fill,
            "tile fill {:.3} -> {:.3}",
            r.report.before.tile_fill,
            r.report.after.tile_fill
        );
        // Stable within buckets: empty/low rows keep relative order.
        let degs = g.degrees();
        let picked: Vec<usize> = r
            .perm
            .iter()
            .map(|&o| degs[o as usize])
            .collect();
        let bucket = |d: usize| if d == 0 { 0 } else { usize::BITS - d.leading_zeros() };
        for w in picked.windows(2) {
            assert!(bucket(w[0]) >= bucket(w[1]));
        }
    }

    #[test]
    fn restore_is_bit_exact_and_signature_stable() {
        let g = skewed();
        for passes in [
            vec![ReorderPass::HubPack],
            vec![ReorderPass::SegmentSort],
            vec![ReorderPass::HubPack, ReorderPass::SegmentSort],
            vec![ReorderPass::Reverse, ReorderPass::HubPack, ReorderPass::Reverse],
        ] {
            let r = reorder(&g, &passes);
            assert_eq!(r.restore_graph(), g, "{passes:?}");
            assert_eq!(
                graph_signature(&r.restore_graph()),
                graph_signature(&g),
                "{passes:?}"
            );
        }
        // A real permutation must change the signature.
        let r = reorder(&g, &[ReorderPass::Reverse]);
        assert_ne!(graph_signature(&r.graph), graph_signature(&g));
    }

    #[test]
    fn rowwise_permute_roundtrip() {
        let g = skewed();
        let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::Reverse]);
        let f = 3;
        let x: Vec<f32> = (0..g.n_rows * f).map(|i| i as f32).collect();
        let px = r.permute_rowwise(&x, f);
        assert_eq!(r.unpermute_rowwise(&px, f), x);
        // Row new of px holds row perm[new] of x.
        let new0_old = r.perm[0] as usize;
        assert_eq!(&px[..f], &x[new0_old * f..new0_old * f + f]);
    }

    #[test]
    fn edge_unpermute_matches_slot_order() {
        let g = skewed();
        let r = reorder(&g, &[ReorderPass::SegmentSort]);
        // Edge values of the reordered graph, mapped back, must equal
        // the original value array exactly (columns untouched per row).
        assert_eq!(r.unpermute_edges(&r.graph.val), g.val);
    }

    #[test]
    fn composition_matches_sequential_application() {
        let g = skewed();
        let r2 = reorder(&g, &[ReorderPass::HubPack, ReorderPass::Reverse]);
        let step1 = reorder(&g, &[ReorderPass::HubPack]);
        let step2 = reorder(&step1.graph, &[ReorderPass::Reverse]);
        assert_eq!(r2.graph, step2.graph);
        // Composed perm maps straight to the original graph.
        let via: Vec<u32> = step2
            .perm
            .iter()
            .map(|&m| step1.perm[m as usize])
            .collect();
        assert_eq!(r2.perm, via);
    }

    #[test]
    fn stored_perm_rejects_bad_length() {
        let g = skewed();
        assert!(from_stored_perm(g.clone(), vec![0, 1]).is_err());
        let r = reorder(&g, &[ReorderPass::HubPack]);
        let again = from_stored_perm(r.graph.clone(), r.perm.clone()).unwrap();
        assert_eq!(again.restore_graph(), g);
    }

    #[test]
    fn empty_and_tiny_graphs_survive() {
        let empty = Csr::from_rows(0, vec![]);
        let r = reorder(&empty, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
        assert_eq!(r.graph.n_rows, 0);
        assert_eq!(r.restore_graph(), empty);
        let one = Csr::from_rows(1, vec![vec![(0, 1.0)]]);
        let r = reorder(&one, &[ReorderPass::Reverse]);
        assert_eq!(r.restore_graph(), one);
    }

    #[test]
    fn report_renders_deltas() {
        let g = skewed();
        let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
        let text = format!("{}", r.report);
        assert!(text.contains("hub-pack,segment-sort"), "{text}");
        assert!(text.contains("tile-fill"), "{text}");
        assert!(text.contains("bandwidth"), "{text}");
    }
}
