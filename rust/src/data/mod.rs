//! Dataset ingestion & degree-aware reordering — the layer that puts
//! *real* graphs in front of the scheduler instead of only `gen/`
//! synthetics (the paper evaluates on Reddit/OGBN-Products; DA-SpMM
//! shows input dynamics dominate kernel choice, so the inputs must be
//! real).
//!
//! Pipeline: **load** (`.mtx` Matrix Market, `.txt`/`.csv` edge lists,
//! `.asg` binary snapshots) → **normalize** (sorted rows, merged
//! duplicates, self-loop policy — one canonical [`Csr`] whatever the
//! source) → **reorder** (composable degree-aware row permutations with
//! a [`ReorderReport`](reorder::ReorderReport) of layout deltas) →
//! **snapshot** (`.asg` with the permutation stored, checksummed,
//! written crash-safely).
//!
//! [`spec`] makes any of it addressable by one string (`"reddit_s"` or
//! `"file:graph.asg"`) everywhere presets were accepted before: the
//! CLI, the bench runner, the serve-bench load generator, the facade.

pub mod asg;
pub mod edgelist;
pub mod mtx;
pub mod normalize;
pub mod reorder;
pub mod sample;
pub mod spec;

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::graph::Csr;

pub use asg::{read_asg, read_asg_generational, write_asg, write_asg_generational, AsgSnapshot};
pub use normalize::{normalize, NormOptions, NormReport};
pub use reorder::{parse_passes, reorder, ReorderPass, ReorderReport, Reordered};
pub use sample::{sample_edges, SampleReport, SampleSpec, SampledGraph};
pub use spec::{load_graph_spec, GraphSpec};

/// Source format of a loaded graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphFormat {
    MatrixMarket,
    EdgeList,
    AsgSnapshot,
}

impl GraphFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            GraphFormat::MatrixMarket => "mtx",
            GraphFormat::EdgeList => "edgelist",
            GraphFormat::AsgSnapshot => "asg",
        }
    }

    /// Pick a format from a file extension. Unknown extensions parse as
    /// edge lists (the loosest format).
    pub fn from_path(path: &Path) -> GraphFormat {
        match path
            .extension()
            .map(|e| e.to_string_lossy().to_ascii_lowercase())
            .as_deref()
        {
            Some("asg") => GraphFormat::AsgSnapshot,
            Some("mtx") | Some("mm") => GraphFormat::MatrixMarket,
            _ => GraphFormat::EdgeList,
        }
    }
}

/// Provenance of a loaded graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphMeta {
    /// Where the graph came from (path or `<test>` tag).
    pub source: String,
    pub format: GraphFormat,
    /// What normalization observed/did (zeroed for `.asg` snapshots,
    /// which are normalized by construction).
    pub norm: NormReport,
}

/// A canonical CSR graph plus its ingestion provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrGraph {
    pub csr: Csr,
    pub meta: GraphMeta,
}

impl CsrGraph {
    /// Load any supported on-disk format, dispatching on the extension.
    pub fn load(path: &Path) -> Result<CsrGraph> {
        Ok(Self::load_with_perm(path)?.0)
    }

    /// Like [`CsrGraph::load`], also surfacing the stored row
    /// permutation of reordered `.asg` snapshots (one read — large
    /// snapshots must not be read and checksummed twice).
    pub fn load_with_perm(path: &Path) -> Result<(CsrGraph, Option<Vec<u32>>)> {
        match GraphFormat::from_path(path) {
            GraphFormat::MatrixMarket => Ok((mtx::load_mtx(path)?, None)),
            GraphFormat::EdgeList => Ok((edgelist::load_edgelist(path)?, None)),
            GraphFormat::AsgSnapshot => {
                let snap = read_asg(path)?;
                Ok((
                    CsrGraph {
                        csr: snap.csr,
                        meta: GraphMeta {
                            source: path.display().to_string(),
                            format: GraphFormat::AsgSnapshot,
                            norm: NormReport::default(),
                        },
                    },
                    snap.perm,
                ))
            }
        }
    }
}

/// Convert any loadable graph file to an `.asg` snapshot (an
/// already-reordered snapshot keeps its stored permutation). Returns
/// the loaded graph for inspection/logging.
pub fn convert_to_asg(input: &Path, output: &Path) -> Result<CsrGraph> {
    if GraphFormat::from_path(output) != GraphFormat::AsgSnapshot {
        return Err(anyhow!(
            "convert target {} must end in .asg",
            output.display()
        ));
    }
    let (loaded, perm) = CsrGraph::load_with_perm(input)?;
    write_asg(output, &loaded.csr, perm.as_deref())?;
    Ok(loaded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_dispatch_by_extension() {
        assert_eq!(
            GraphFormat::from_path(Path::new("a/b.asg")),
            GraphFormat::AsgSnapshot
        );
        assert_eq!(
            GraphFormat::from_path(Path::new("g.MTX")),
            GraphFormat::MatrixMarket
        );
        assert_eq!(
            GraphFormat::from_path(Path::new("edges.csv")),
            GraphFormat::EdgeList
        );
        assert_eq!(
            GraphFormat::from_path(Path::new("noext")),
            GraphFormat::EdgeList
        );
    }

    #[test]
    fn convert_rejects_non_asg_target() {
        let err =
            convert_to_asg(Path::new("/tmp/x.mtx"), Path::new("/tmp/y.mtx"))
                .unwrap_err();
        assert!(format!("{err:#}").contains(".asg"), "{err:#}");
    }
}
