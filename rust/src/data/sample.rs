//! Degree-aware edge sampling with a per-graph error estimate — the
//! approximation that backs graceful degradation in the serve pool.
//!
//! AES-SpMM and cache-first edge sampling (PAPERS.md) trade bounded
//! accuracy for large SpMM speedups by dropping edges from hub rows.
//! This pass keeps every edge of low-degree rows (degree ≤
//! `min_keep_deg`) and, for hub rows, the `keep_frac` largest-|value|
//! edges, so the dropped mass per row is as small as the budget allows.
//!
//! The pass is deterministic (pure function of the input graph and the
//! spec — ties break by slot order) and emits the quantity the serving
//! layer needs to *bound* the approximation: `max_row_dropped_mass`,
//! the largest Σ|v| dropped from any single row. For SpMM `Y = A·B`
//! every output element satisfies
//!
//! ```text
//! |Y_full[i][j] − Y_sampled[i][j]| = |Σ_dropped v_e · B[col_e][j]|
//!                                  ≤ max_row_dropped_mass · max|B|
//! ```
//!
//! so a degraded reply can carry a hard per-element error estimate
//! without knowing `B` in advance.

use std::fmt;

use crate::graph::Csr;

/// Edge-sampling parameters (serving defaults come from
/// `AUTOSAGE_DEGRADE_{KEEP,MIN_DEG}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleSpec {
    /// Fraction of a hub row's edges to keep, in (0, 1].
    pub keep_frac: f64,
    /// Rows with at most this many edges are untouched; hub rows never
    /// keep fewer than this many edges either.
    pub min_keep_deg: usize,
}

impl Default for SampleSpec {
    fn default() -> Self {
        SampleSpec { keep_frac: 0.5, min_keep_deg: 8 }
    }
}

/// What the sampling pass did and how wrong the result can be.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SampleReport {
    /// Hub rows that actually lost edges.
    pub rows_sampled: usize,
    pub edges_kept: usize,
    pub edges_dropped: usize,
    /// max over rows of Σ|v| dropped from that row — the per-element
    /// SpMM error bound is this times max|B|.
    pub max_row_dropped_mass: f64,
    /// Σ|v| dropped over Σ|v| total (0 when the graph has no mass).
    pub dropped_mass_frac: f64,
}

impl fmt::Display for SampleReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "sampled {} rows: kept {} / dropped {} edges, \
             max row dropped mass {:.4}, dropped mass frac {:.4}",
            self.rows_sampled,
            self.edges_kept,
            self.edges_dropped,
            self.max_row_dropped_mass,
            self.dropped_mass_frac
        )
    }
}

/// An edge-sampled graph plus its error estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct SampledGraph {
    pub graph: Csr,
    pub report: SampleReport,
}

/// Deterministically drop low-|value| edges from hub rows.
///
/// Rows with degree ≤ `spec.min_keep_deg` are copied verbatim. A hub
/// row of degree `d` keeps `max(min_keep_deg, ceil(d · keep_frac))`
/// edges, chosen by largest |value| (ties broken by slot order so the
/// output is a pure function of the input); kept edges stay in their
/// original column order, so the result is a valid sorted CSR.
pub fn sample_edges(g: &Csr, spec: &SampleSpec) -> SampledGraph {
    assert!(
        spec.keep_frac > 0.0 && spec.keep_frac <= 1.0,
        "keep_frac out of (0,1]: {}",
        spec.keep_frac
    );
    let min_keep = spec.min_keep_deg.max(1);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(g.n_rows);
    let mut report = SampleReport::default();
    let mut total_mass = 0.0f64;
    for i in 0..g.n_rows {
        let (cols, vals) = g.row(i);
        let deg = cols.len();
        let row_mass: f64 = vals.iter().map(|v| v.abs() as f64).sum();
        total_mass += row_mass;
        let keep = if deg <= min_keep {
            deg
        } else {
            min_keep.max(((deg as f64) * spec.keep_frac).ceil() as usize)
        };
        if keep >= deg {
            report.edges_kept += deg;
            rows.push(cols.iter().copied().zip(vals.iter().copied()).collect());
            continue;
        }
        // Rank slots by |value| descending, slot ascending on ties.
        let mut slots: Vec<usize> = (0..deg).collect();
        slots.sort_by(|&a, &b| {
            vals[b]
                .abs()
                .partial_cmp(&vals[a].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut kept_slots = slots[..keep].to_vec();
        kept_slots.sort_unstable(); // back to column order
        let dropped_mass: f64 = slots[keep..]
            .iter()
            .map(|&s| vals[s].abs() as f64)
            .sum();
        report.rows_sampled += 1;
        report.edges_kept += keep;
        report.edges_dropped += deg - keep;
        report.max_row_dropped_mass = report.max_row_dropped_mass.max(dropped_mass);
        rows.push(kept_slots.iter().map(|&s| (cols[s], vals[s])).collect());
    }
    if total_mass > 0.0 {
        let dropped: f64 = total_mass
            - rows
                .iter()
                .flat_map(|r| r.iter())
                .map(|&(_, v)| v.abs() as f64)
                .sum::<f64>();
        report.dropped_mass_frac = (dropped / total_mass).max(0.0);
    }
    SampledGraph { graph: Csr::from_rows(g.n_cols, rows), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::reference;
    use crate::util::rng::Rng;

    /// One hub row (degree 32) over a tail of degree-2 rows.
    fn hub_graph() -> Csr {
        let mut rng = Rng::new(7);
        let n = 40;
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
        let mut hub: Vec<(u32, f32)> = (0..32u32)
            .map(|c| (c, rng.next_f32() * 2.0 - 1.0))
            .collect();
        hub.sort_by_key(|&(c, _)| c);
        rows.push(hub);
        for i in 1..n {
            rows.push(vec![
                ((i as u32) % 40, 0.5),
                (((i as u32) + 3) % 40, -0.25),
            ]);
        }
        Csr::from_rows(40, rows)
    }

    #[test]
    fn sampling_is_deterministic() {
        let g = hub_graph();
        let spec = SampleSpec { keep_frac: 0.25, min_keep_deg: 4 };
        let a = sample_edges(&g, &spec);
        let b = sample_edges(&g, &spec);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.report, b.report);
        assert!(a.report.edges_dropped > 0);
    }

    #[test]
    fn low_degree_graph_is_untouched() {
        let g = Csr::from_rows(
            8,
            vec![vec![(0, 1.0), (3, 2.0)], vec![(1, -1.0)], vec![]],
        );
        let s = sample_edges(&g, &SampleSpec::default());
        assert_eq!(s.graph, g);
        assert_eq!(s.report.rows_sampled, 0);
        assert_eq!(s.report.edges_dropped, 0);
        assert_eq!(s.report.max_row_dropped_mass, 0.0);
    }

    #[test]
    fn kept_plus_dropped_is_nnz_and_columns_stay_sorted() {
        let g = hub_graph();
        let s = sample_edges(&g, &SampleSpec { keep_frac: 0.5, min_keep_deg: 4 });
        assert_eq!(s.report.edges_kept + s.report.edges_dropped, g.nnz());
        assert_eq!(s.graph.nnz(), s.report.edges_kept);
        for i in 0..s.graph.n_rows {
            let (cols, _) = s.graph.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i} unsorted");
        }
    }

    #[test]
    fn spmm_error_stays_within_reported_bound() {
        let g = hub_graph();
        let s = sample_edges(&g, &SampleSpec { keep_frac: 0.25, min_keep_deg: 4 });
        assert!(s.report.edges_dropped > 0);
        let f = 16;
        let mut rng = Rng::new(11);
        let b: Vec<f32> = (0..g.n_cols * f)
            .map(|_| rng.next_f32() * 2.0 - 1.0)
            .collect();
        let max_b = b.iter().fold(0.0f32, |m, v| m.max(v.abs())) as f64;
        let full = reference::spmm(&g, &b, f);
        let approx = reference::spmm(&s.graph, &b, f);
        let bound = s.report.max_row_dropped_mass * max_b + 1e-5;
        for (i, (&yf, &ya)) in full.iter().zip(approx.iter()).enumerate() {
            let err = (yf - ya).abs() as f64;
            assert!(err <= bound, "elem {i}: err {err} > bound {bound}");
        }
    }

    #[test]
    fn empty_graph_is_fine() {
        let g = Csr::from_rows(4, vec![vec![], vec![], vec![]]);
        let s = sample_edges(&g, &SampleSpec::default());
        assert_eq!(s.graph.nnz(), 0);
        assert_eq!(s.report.dropped_mass_frac, 0.0);
    }
}
