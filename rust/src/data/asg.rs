//! `.asg` — the compact binary CSR snapshot format.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8 B   b"ASGSNAP1"
//! version  u32   ASG_VERSION (load rejects anything else)
//! flags    u32   bit 0: a row permutation follows the payload
//! n_rows   u64
//! n_cols   u64
//! nnz      u64
//! rowptr   (n_rows + 1) x u64
//! colind   nnz x u32
//! val      nnz x f32 (IEEE-754 bits)
//! perm     n_rows x u32          (only when flags bit 0 is set;
//!                                 perm[new_row] = original row id)
//! checksum u64   FNV-1a over every preceding byte
//! ```
//!
//! Writes go through a sibling temp file + rename (the schedule-cache
//! crash-safety pattern); loads verify magic, version, exact length,
//! and checksum before handing out a validated [`Csr`]. The optional
//! permutation is what lets a reordered snapshot be un-permuted back to
//! original row ids (`data::reorder`).

use std::fs;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::graph::signature::Fnv1a;
use crate::graph::Csr;
use crate::util::iofault::{self, CorruptArtifact};

pub const ASG_MAGIC: &[u8; 8] = b"ASGSNAP1";
pub const ASG_VERSION: u32 = 1;
const FLAG_PERM: u32 = 1;

/// A loaded snapshot: the graph plus, for reordered snapshots, the row
/// permutation back to the original id space (`perm[new] = old`).
#[derive(Debug, Clone, PartialEq)]
pub struct AsgSnapshot {
    pub csr: Csr,
    pub perm: Option<Vec<u32>>,
}

/// Serialize `g` (and optionally a row permutation) to `path`,
/// crash-safely (temp file + rename).
pub fn write_asg(path: &Path, g: &Csr, perm: Option<&[u32]>) -> Result<()> {
    g.validate()
        .map_err(|e| anyhow!("refusing to snapshot invalid CSR: {e}"))?;
    if let Some(p) = perm {
        if p.len() != g.n_rows {
            return Err(anyhow!(
                "perm length {} != n_rows {}",
                p.len(),
                g.n_rows
            ));
        }
    }
    let nnz = g.nnz();
    let mut buf: Vec<u8> = Vec::with_capacity(
        8 + 4 + 4
            + 24
            + 8 * (g.n_rows + 1)
            + 4 * nnz
            + 4 * nnz
            + perm.map_or(0, |p| 4 * p.len())
            + 8,
    );
    buf.extend_from_slice(ASG_MAGIC);
    buf.extend_from_slice(&ASG_VERSION.to_le_bytes());
    let flags: u32 = if perm.is_some() { FLAG_PERM } else { 0 };
    buf.extend_from_slice(&flags.to_le_bytes());
    buf.extend_from_slice(&(g.n_rows as u64).to_le_bytes());
    buf.extend_from_slice(&(g.n_cols as u64).to_le_bytes());
    buf.extend_from_slice(&(nnz as u64).to_le_bytes());
    for &p in &g.rowptr {
        buf.extend_from_slice(&(p as u64).to_le_bytes());
    }
    for &c in &g.colind {
        buf.extend_from_slice(&c.to_le_bytes());
    }
    for &v in &g.val {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    if let Some(p) = perm {
        for &r in p {
            buf.extend_from_slice(&r.to_le_bytes());
        }
    }
    let mut h = Fnv1a::new();
    h.write(&buf);
    buf.extend_from_slice(&h.finish().to_le_bytes());

    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        fs::create_dir_all(dir).ok();
    }
    iofault::write_atomic("data.asg.write", path, &buf)
        .with_context(|| format!("writing snapshot {}", path.display()))
}

/// Path of the previous-generation sibling (`graph.asg` -> `graph.asg.prev`).
pub fn prev_path(path: &Path) -> std::path::PathBuf {
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "snapshot.asg".to_string());
    path.with_file_name(format!("{file_name}.prev"))
}

/// [`write_asg`] with two-generation retention: the existing snapshot
/// is first rotated to `<path>.prev`, then the new one is written
/// atomically, so a reader can fall back one generation on corruption.
pub fn write_asg_generational(
    path: &Path,
    g: &Csr,
    perm: Option<&[u32]>,
) -> Result<()> {
    if path.exists() {
        iofault::rename("data.asg.rotate", path, &prev_path(path))
            .with_context(|| format!("rotating previous snapshot {}", path.display()))?;
    }
    write_asg(path, g, perm)
}

/// Load a snapshot, falling back to `<path>.prev` when the current
/// generation is corrupt. Returns the snapshot plus a flag that is
/// `true` when the previous generation stood in. When both generations
/// are unreadable the error downcasts to [`CorruptArtifact`].
pub fn read_asg_generational(path: &Path) -> Result<(AsgSnapshot, bool)> {
    match read_asg(path) {
        Ok(s) => Ok((s, false)),
        Err(primary) => {
            let prev = prev_path(path);
            if prev.exists() {
                if let Ok(s) = read_asg(&prev) {
                    iofault::recovery().generation_fallbacks.fetch_add(
                        1,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    return Ok((s, true));
                }
            }
            Err(anyhow::Error::new(CorruptArtifact {
                path: path.to_path_buf(),
                detail: format!("{primary:#}"),
            }))
        }
    }
}

fn rd_u32(buf: &[u8], off: &mut usize) -> u32 {
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().expect("4 bytes"));
    *off += 4;
    v
}

fn rd_u64(buf: &[u8], off: &mut usize) -> u64 {
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().expect("8 bytes"));
    *off += 8;
    v
}

/// Load and fully verify a snapshot from `path`.
pub fn read_asg(path: &Path) -> Result<AsgSnapshot> {
    let buf = iofault::read_file("data.asg.read", path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    let name = path.display();
    if buf.len() < 8 + 4 + 4 + 24 + 8 + 8 {
        return Err(anyhow!("{name}: truncated snapshot ({} bytes)", buf.len()));
    }
    if &buf[..8] != ASG_MAGIC {
        return Err(anyhow!("{name}: not an .asg snapshot (bad magic)"));
    }
    let mut off = 8usize;
    let version = rd_u32(&buf, &mut off);
    if version != ASG_VERSION {
        return Err(anyhow!(
            "{name}: unsupported snapshot version {version} (expected {ASG_VERSION})"
        ));
    }
    let flags = rd_u32(&buf, &mut off);
    let n_rows = rd_u64(&buf, &mut off) as usize;
    let n_cols = rd_u64(&buf, &mut off) as usize;
    let nnz = rd_u64(&buf, &mut off) as usize;
    let has_perm = flags & FLAG_PERM != 0;
    // u128 math: header fields are untrusted, so the size formula must
    // not overflow before the length check rejects the file.
    let expect = off as u128
        + 8 * (n_rows as u128 + 1)
        + 4 * nnz as u128
        + 4 * nnz as u128
        + if has_perm { 4 * n_rows as u128 } else { 0 }
        + 8;
    if buf.len() as u128 != expect {
        return Err(anyhow!(
            "{name}: length {} != expected {expect} for {n_rows} rows / {nnz} nnz",
            buf.len()
        ));
    }
    let mut h = Fnv1a::new();
    h.write(&buf[..buf.len() - 8]);
    let mut coff = buf.len() - 8;
    let stored = rd_u64(&buf, &mut coff);
    if h.finish() != stored {
        return Err(anyhow!(
            "{name}: checksum mismatch (file corrupt or truncated mid-write)"
        ));
    }
    let mut rowptr = Vec::with_capacity(n_rows + 1);
    for _ in 0..n_rows + 1 {
        rowptr.push(rd_u64(&buf, &mut off) as usize);
    }
    let mut colind = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        colind.push(rd_u32(&buf, &mut off));
    }
    let mut val = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        val.push(f32::from_bits(rd_u32(&buf, &mut off)));
    }
    let perm = if has_perm {
        let mut p = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            p.push(rd_u32(&buf, &mut off));
        }
        // A permutation must be a bijection on 0..n_rows.
        let mut seen = vec![false; n_rows];
        for &r in &p {
            if r as usize >= n_rows || seen[r as usize] {
                return Err(anyhow!("{name}: stored perm is not a permutation"));
            }
            seen[r as usize] = true;
        }
        Some(p)
    } else {
        None
    };
    let csr = Csr { n_rows, n_cols, rowptr, colind, val };
    csr.validate()
        .map_err(|e| anyhow!("{name}: invalid CSR payload: {e}"))?;
    Ok(AsgSnapshot { csr, perm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("autosage_asg_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Csr {
        Csr::from_rows(
            4,
            vec![
                vec![(1, 1.5), (3, -2.0)],
                vec![],
                vec![(0, 0.25)],
                vec![(2, 7.0), (0, 1.0)],
            ],
        )
    }

    #[test]
    fn roundtrip_bit_exact() {
        let path = tmpfile("roundtrip.asg");
        let g = sample();
        write_asg(&path, &g, None).unwrap();
        let snap = read_asg(&path).unwrap();
        assert_eq!(snap.csr, g);
        assert_eq!(snap.perm, None);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_with_perm() {
        let path = tmpfile("perm.asg");
        let g = sample();
        let perm = vec![3u32, 0, 2, 1];
        write_asg(&path, &g, Some(&perm)).unwrap();
        let snap = read_asg(&path).unwrap();
        assert_eq!(snap.perm, Some(perm));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn write_is_atomic_and_leaves_no_temp() {
        let path = tmpfile("atomic.asg");
        write_asg(&path, &sample(), None).unwrap();
        assert!(path.exists());
        assert!(!path.with_file_name("atomic.asg.tmp").exists());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn detects_corruption() {
        let path = tmpfile("corrupt.asg");
        write_asg(&path, &sample(), None).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let err = read_asg(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("invalid"),
            "{msg}"
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn detects_truncation_and_bad_magic() {
        let path = tmpfile("trunc.asg");
        write_asg(&path, &sample(), None).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read_asg(&path).is_err());
        fs::write(&path, vec![b'X'; 64]).unwrap();
        let err = read_asg(&path).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn rejects_future_version() {
        let path = tmpfile("futver.asg");
        write_asg(&path, &sample(), None).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        bytes[8] = 99; // version field
        // Re-stamp the checksum so only the version is wrong.
        let mut h = Fnv1a::new();
        let n = bytes.len();
        h.write(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&h.finish().to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        let err = read_asg(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn rejects_bogus_perm() {
        let path = tmpfile("badperm.asg");
        let g = sample();
        assert!(write_asg(&path, &g, Some(&[0u32, 1][..])).is_err()); // wrong len
        // write_asg only length-checks the perm; bijectivity is the
        // loader's job (it must distrust any file it is handed).
        write_asg(&path, &g, Some(&[0u32, 0, 2, 3][..])).unwrap();
        let err = read_asg(&path).unwrap_err();
        assert!(format!("{err:#}").contains("permutation"), "{err:#}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn generational_snapshot_falls_back_then_refuses() {
        let path = tmpfile("gen.asg");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(prev_path(&path));
        let g1 = sample();
        let g2 = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 2.0)]]);

        write_asg_generational(&path, &g1, None).unwrap();
        assert!(!prev_path(&path).exists());
        write_asg_generational(&path, &g2, None).unwrap();
        assert_eq!(read_asg(&prev_path(&path)).unwrap().csr, g1);
        let (snap, fell_back) = read_asg_generational(&path).unwrap();
        assert_eq!(snap.csr, g2);
        assert!(!fell_back);

        // Corrupt current generation -> previous stands in.
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let (snap, fell_back) = read_asg_generational(&path).unwrap();
        assert_eq!(snap.csr, g1);
        assert!(fell_back);

        // Both corrupt -> typed refusal, downcastable.
        fs::write(prev_path(&path), b"junk").unwrap();
        let err = read_asg_generational(&path).unwrap_err();
        assert!(
            err.downcast_ref::<CorruptArtifact>().is_some(),
            "expected CorruptArtifact, got {err:#}"
        );
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(prev_path(&path));
    }

    #[test]
    fn empty_graph_roundtrips() {
        let path = tmpfile("empty.asg");
        let g = Csr::from_rows(0, vec![]);
        write_asg(&path, &g, None).unwrap();
        let snap = read_asg(&path).unwrap();
        assert_eq!(snap.csr.n_rows, 0);
        assert_eq!(snap.csr.nnz(), 0);
        let _ = fs::remove_file(&path);
    }
}
