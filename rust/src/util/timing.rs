//! Wall-clock timing loop used by the micro-probe and the bench harness:
//! warm-up, then `iters` timed repetitions bounded by a wall-time cap —
//! the paper's protocol (§6: medians over 10–15 iterations after warm-up,
//! probe loops with a wall-time cap).

use std::time::Instant;

use super::stats::TimingSummary;

/// Run `f` `warmup` times untimed, then up to `iters` timed runs, stopping
/// early once the *timed* phase exceeds `cap_ms` (at least one timed run
/// always happens). Returns a median-based summary.
pub fn time_fn<F: FnMut()>(
    mut f: F,
    warmup: usize,
    iters: usize,
    cap_ms: f64,
) -> TimingSummary {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        if start.elapsed().as_secs_f64() * 1e3 > cap_ms {
            break;
        }
    }
    TimingSummary::from_ms(&samples)
}

/// Stopwatch for one-off phase measurements.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_iters_under_cap() {
        let mut n = 0;
        let s = time_fn(|| n += 1, 2, 5, 1e9);
        assert_eq!(n, 7); // 2 warmup + 5 timed
        assert_eq!(s.n, 5);
    }

    #[test]
    fn cap_stops_early_but_keeps_one() {
        let mut n = 0;
        let s = time_fn(
            || {
                n += 1;
                std::thread::sleep(std::time::Duration::from_millis(3));
            },
            0,
            1000,
            1.0,
        );
        assert!(s.n >= 1);
        assert!(s.n < 1000);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.ms() >= 1.0);
    }
}
