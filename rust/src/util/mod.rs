//! Substrates built from scratch (no third-party crates are available in
//! this offline environment beyond `xla`/`anyhow`): JSON, deterministic
//! PRNG, descriptive statistics, CSV, typed env toggles, SHA-256
//! fingerprinting, and wall timing.

pub mod csv;
pub mod envcfg;
pub mod iofault;
pub mod json;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod timing;
