//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component (generators, probe sampling, property
//! tests) goes through this so runs replay bit-identically from a seed —
//! a requirement for the paper's "deterministic replay" claim.

/// xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (stable sub-seeding for components).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Stateless per-stream derivation: the same `(seed, stream)` pair
    /// always yields the same generator, independent of how many draws
    /// any other stream made. This is what makes generator rows and
    /// serve-bench load mixes reproducible under one `--seed` — stream
    /// `i` never shifts because stream `i-1` consumed a different
    /// number of values.
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        let mut a = seed;
        let mut b = stream.wrapping_add(0x9E3779B97F4A7C15);
        Rng::new(splitmix64(&mut a) ^ splitmix64(&mut b))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson via inversion (small lambda) or normal approx (large).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-like heavy-tail sample in [1, cap]: `floor(x_min * u^(-1/a))`.
    /// This is a discrete Pareto — the standard heavy-tail degree model.
    pub fn pareto_deg(&mut self, x_min: f64, alpha: f64, cap: usize) -> usize {
        let u = self.next_f64().max(1e-12);
        let v = x_min * u.powf(-1.0 / alpha);
        (v as usize).clamp(1, cap)
    }

    /// Sample `k` distinct values from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn pareto_capped_and_heavy() {
        let mut r = Rng::new(13);
        let degs: Vec<usize> =
            (0..50_000).map(|_| r.pareto_deg(2.0, 1.6, 256)).collect();
        assert!(degs.iter().all(|&d| (1..=256).contains(&d)));
        // Heavy tail: some mass well above the median.
        assert!(degs.iter().filter(|&&d| d >= 64).count() > 100);
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_distinct(100, 40);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 40);
        assert!(s.iter().all(|&v| v < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn for_stream_is_stateless_and_decorrelated() {
        // Same (seed, stream) → identical sequence, no shared state.
        let mut r1 = Rng::for_stream(42, 3);
        let mut r2 = Rng::for_stream(42, 3);
        let a: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        assert_eq!(a, b);
        // Different stream or seed → different sequence.
        assert_ne!(a[0], Rng::for_stream(42, 4).next_u64());
        assert_ne!(a[0], Rng::for_stream(43, 3).next_u64());
    }
}
