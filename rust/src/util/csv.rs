//! CSV writer (RFC-4180 quoting) for telemetry and bench outputs.
//!
//! Each CSV the system writes gets a `.meta.json` sidecar (see
//! [`crate::telemetry`]) with device/toolchain info and env toggles —
//! the paper's reproducibility scheme (§10).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table; rows are validated against the header width.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Convenience: anything Display-able.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| v.to_string()).collect());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())
    }
}

fn write_record(out: &mut String, fields: &[String]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            let escaped = f.replace('"', "\"\"");
            let _ = write!(out, "\"{escaped}\"");
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

/// Minimal CSV reader for replaying our own files (tests/tools).
pub fn parse(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut field = String::new();
    let mut row = Vec::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    chars.next();
                    field.push('"');
                }
                '"' => in_quotes = false,
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => row.push(std::mem::take(&mut field)),
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {}
                c => field.push(c),
            }
        }
    }
    if !field.is_empty() || !row.is_empty() {
        row.push(field);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_roundtrip() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(vec!["1".into(), "x".into()]);
        t.push(vec!["2".into(), "y,z".into()]);
        let parsed = parse(&t.to_string());
        assert_eq!(parsed[0], vec!["a", "b"]);
        assert_eq!(parsed[2], vec!["2", "y,z"]);
    }

    #[test]
    fn quotes_escaped() {
        let mut t = CsvTable::new(&["v"]);
        t.push(vec!["say \"hi\"\nbye".into()]);
        let parsed = parse(&t.to_string());
        assert_eq!(parsed[1][0], "say \"hi\"\nbye");
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn push_display() {
        let mut t = CsvTable::new(&["f", "ms"]);
        t.push_display(&[&64, &1.25]);
        assert_eq!(t.rows()[0], vec!["64", "1.25"]);
    }
}
