//! Seeded I/O fault injection + salvage bookkeeping for durable state.
//!
//! PR 9's `FaultInjector` made *requests* survive a hostile runtime;
//! this module makes *artifacts* survive a hostile disk. Every durable
//! read/write site (schedule cache, `.asgm` models, `.asg` snapshots,
//! trace/audit/quarantine JSONL, manifests, `metrics.prom`) funnels
//! through the wrappers here, which consult one process-global
//! [`IoFaultInjector`].
//!
//! Determinism contract (mirrors `server::resilience::FaultInjector`):
//! the decision for operation `idx` at `site` is a **pure function** of
//! `(AUTOSAGE_IO_FAULT_SEED, site, idx)` — per-site operation counters
//! isolate sites from each other, so thread interleaving across sites
//! never shifts a decision. Two runs with the same seed and the same
//! per-site operation counts inject the identical fault set; the sorted
//! [`IoFaultInjector::log_snapshot`] is the cross-run witness
//! (`recovery.json` in serve-bench `--out` dirs, `cmp`-compared by the
//! CI `crash-smoke` job).
//!
//! Fault kinds and how each is absorbed:
//! * `torn_write`  — only a prefix reaches the tmp file; the atomic
//!   rename never happens and the write retries (bounded).
//! * `enospc`      — the write fails before any byte lands; retried.
//! * `failed_rename` — the tmp file is left behind, the destination is
//!   untouched; the whole write-then-rename retries.
//! * `short_read`  — the reader sees a truncated byte stream; salvage
//!   recovery (valid-prefix JSONL, per-entry cache quarantine,
//!   checksum-gated generational fallback) absorbs it.
//! * `bit_flip`    — the write/read *silently* succeeds with one byte
//!   corrupted; checksums and per-line/per-entry validation catch it
//!   downstream, never the caller's happy path.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::Rng;

/// Bounded retry budget at write sites: an injected transient fault
/// consumes one attempt (and one op index), so a deterministic fault on
/// attempt k is followed by a *different* decision on attempt k+1.
pub const WRITE_RETRIES: usize = 4;

/// Log cap, mirroring `FaultInjector`.
const LOG_CAP: usize = 65_536;

/// What kind of I/O fault to inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IoFaultKind {
    TornWrite,
    ShortRead,
    FailedRename,
    Enospc,
    BitFlip,
}

impl IoFaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            IoFaultKind::TornWrite => "torn_write",
            IoFaultKind::ShortRead => "short_read",
            IoFaultKind::FailedRename => "failed_rename",
            IoFaultKind::Enospc => "enospc",
            IoFaultKind::BitFlip => "bit_flip",
        }
    }

    pub fn parse(s: &str) -> Option<IoFaultKind> {
        match s.trim() {
            "torn_write" => Some(IoFaultKind::TornWrite),
            "short_read" => Some(IoFaultKind::ShortRead),
            "failed_rename" => Some(IoFaultKind::FailedRename),
            "enospc" => Some(IoFaultKind::Enospc),
            "bit_flip" => Some(IoFaultKind::BitFlip),
            _ => None,
        }
    }

    pub const ALL: [IoFaultKind; 5] = [
        IoFaultKind::TornWrite,
        IoFaultKind::ShortRead,
        IoFaultKind::FailedRename,
        IoFaultKind::Enospc,
        IoFaultKind::BitFlip,
    ];

    fn index(&self) -> usize {
        match self {
            IoFaultKind::TornWrite => 0,
            IoFaultKind::ShortRead => 1,
            IoFaultKind::FailedRename => 2,
            IoFaultKind::Enospc => 3,
            IoFaultKind::BitFlip => 4,
        }
    }
}

/// Parse `AUTOSAGE_IO_FAULT_KINDS` (comma-separated, deduplicated,
/// order-preserving). Unknown names are an error, mirroring
/// `resilience::parse_kinds`.
pub fn parse_io_kinds(csv: &str) -> Result<Vec<IoFaultKind>, String> {
    let mut out = Vec::new();
    for part in csv.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let k = IoFaultKind::parse(p).ok_or_else(|| {
            format!(
                "unknown io fault kind {p:?} \
                 (torn_write|short_read|failed_rename|enospc|bit_flip)"
            )
        })?;
        if !out.contains(&k) {
            out.push(k);
        }
    }
    Ok(out)
}

/// The class of filesystem operation a site performs; only a subset of
/// fault kinds is physically meaningful for each.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Write,
    Read,
    Rename,
}

fn applicable(kind: IoFaultKind, class: OpClass) -> bool {
    match class {
        OpClass::Write => matches!(
            kind,
            IoFaultKind::TornWrite | IoFaultKind::Enospc | IoFaultKind::BitFlip
        ),
        OpClass::Read => {
            matches!(kind, IoFaultKind::ShortRead | IoFaultKind::BitFlip)
        }
        OpClass::Rename => matches!(kind, IoFaultKind::FailedRename),
    }
}

/// FNV-1a over the site name — the per-site stream tag mixed into the
/// injector seed (same hash family the artifact checksums use).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic, seeded I/O fault injector.
pub struct IoFaultInjector {
    seed: u64,
    rate: f64,
    kinds: Vec<IoFaultKind>,
    /// Per-site operation counters: site → next op index.
    ops: Mutex<BTreeMap<&'static str, u64>>,
    /// Injected-fault counters, indexed by `IoFaultKind::index`.
    injected: [AtomicU64; 5],
    /// Applied-fault log: (site, op index, kind), capped at `LOG_CAP`.
    log: Mutex<Vec<(&'static str, u64, IoFaultKind)>>,
}

impl IoFaultInjector {
    pub fn new(seed: u64, rate: f64, kinds: Vec<IoFaultKind>) -> IoFaultInjector {
        let kinds = if kinds.is_empty() {
            IoFaultKind::ALL.to_vec()
        } else {
            kinds
        };
        IoFaultInjector {
            seed,
            rate: rate.clamp(0.0, 1.0),
            kinds,
            ops: Mutex::new(BTreeMap::new()),
            injected: Default::default(),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Pure decision for operation `idx` at `site`: a function of
    /// `(seed, site, idx)` only. No state is touched.
    pub fn decide_at(
        &self,
        site: &str,
        idx: u64,
        class: OpClass,
    ) -> Option<IoFaultKind> {
        let mut rng = Rng::for_stream(self.seed ^ fnv1a64(site.as_bytes()), idx);
        if rng.next_f64() >= self.rate {
            return None;
        }
        let usable: Vec<IoFaultKind> = self
            .kinds
            .iter()
            .copied()
            .filter(|&k| applicable(k, class))
            .collect();
        if usable.is_empty() {
            return None;
        }
        Some(usable[rng.below(usable.len())])
    }

    /// Allocate the next op index for `site` and decide; an injected
    /// fault is counted and logged.
    fn next(&self, site: &'static str, class: OpClass) -> Option<IoFaultKind> {
        let idx = {
            let mut ops = self.ops.lock().unwrap_or_else(|p| p.into_inner());
            let c = ops.entry(site).or_insert(0);
            let idx = *c;
            *c += 1;
            idx
        };
        let kind = self.decide_at(site, idx, class)?;
        self.injected[kind.index()].fetch_add(1, Ordering::Relaxed);
        let mut log = self.log.lock().unwrap_or_else(|p| p.into_inner());
        if log.len() < LOG_CAP {
            log.push((site, idx, kind));
        }
        Some(kind)
    }

    pub fn injected_total(&self) -> u64 {
        self.injected.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn injected_of(&self, kind: IoFaultKind) -> u64 {
        self.injected[kind.index()].load(Ordering::Relaxed)
    }

    /// Sorted applied-fault log — the determinism witness: two runs
    /// with the same seed and the same per-site op counts produce
    /// byte-identical snapshots regardless of thread interleaving.
    pub fn log_snapshot(&self) -> Vec<(String, u64, IoFaultKind)> {
        let mut v: Vec<(String, u64, IoFaultKind)> = self
            .log
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .map(|(s, i, k)| (s.to_string(), *i, *k))
            .collect();
        v.sort();
        v
    }
}

/// Process-global injector slot. `None` (the default) means every
/// wrapper below is a plain passthrough to `std::fs`.
static GLOBAL: Mutex<Option<Arc<IoFaultInjector>>> = Mutex::new(None);

/// Install (or clear, with `None`) the process-global injector.
/// Production installs from `AUTOSAGE_IO_FAULT_*`; tests that install
/// one must serialize on a shared lock and uninstall when done.
pub fn install(inj: Option<Arc<IoFaultInjector>>) {
    *GLOBAL.lock().unwrap_or_else(|p| p.into_inner()) = inj;
}

/// The currently-installed global injector, if any.
pub fn installed() -> Option<Arc<IoFaultInjector>> {
    GLOBAL.lock().unwrap_or_else(|p| p.into_inner()).clone()
}

fn decide(site: &'static str, class: OpClass) -> Option<IoFaultKind> {
    installed().and_then(|i| i.next(site, class))
}

// ---- global recovery counters -----------------------------------------

/// Process-wide salvage/recovery counters, incremented by the wrappers
/// here and by the salvage-aware readers (schedule cache, JSONL
/// streams, generational model/snapshot loads). Exported as the
/// `autosage_salvage_*` / `autosage_io_*` metric series.
#[derive(Default)]
pub struct RecoveryStats {
    /// Write attempts retried after an injected (or real) transient
    /// write/rename failure that a later attempt absorbed.
    pub write_retries: AtomicU64,
    /// JSONL tail lines dropped by valid-prefix salvage.
    pub jsonl_lines_dropped: AtomicU64,
    /// Individually-corrupt schedule-cache entries quarantined on load.
    pub cache_entries_quarantined: AtomicU64,
    /// Whole cache files too corrupt to parse, moved aside and reset.
    pub cache_files_reset: AtomicU64,
    /// Corrupt current-generation artifacts recovered from `.prev`.
    pub generation_fallbacks: AtomicU64,
    /// Size-capped log rotations performed.
    pub rotations: AtomicU64,
}

impl RecoveryStats {
    /// Sum of all salvage events (the `autosage_salvage_total` series).
    pub fn salvage_total(&self) -> u64 {
        self.jsonl_lines_dropped.load(Ordering::Relaxed)
            + self.cache_entries_quarantined.load(Ordering::Relaxed)
            + self.cache_files_reset.load(Ordering::Relaxed)
            + self.generation_fallbacks.load(Ordering::Relaxed)
    }

    /// `(name, value)` pairs in a fixed order (deterministic exports).
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("write_retries", self.write_retries.load(Ordering::Relaxed)),
            (
                "jsonl_lines_dropped",
                self.jsonl_lines_dropped.load(Ordering::Relaxed),
            ),
            (
                "cache_entries_quarantined",
                self.cache_entries_quarantined.load(Ordering::Relaxed),
            ),
            (
                "cache_files_reset",
                self.cache_files_reset.load(Ordering::Relaxed),
            ),
            (
                "generation_fallbacks",
                self.generation_fallbacks.load(Ordering::Relaxed),
            ),
            ("rotations", self.rotations.load(Ordering::Relaxed)),
        ]
    }
}

static RECOVERY: RecoveryStats = RecoveryStats {
    write_retries: AtomicU64::new(0),
    jsonl_lines_dropped: AtomicU64::new(0),
    cache_entries_quarantined: AtomicU64::new(0),
    cache_files_reset: AtomicU64::new(0),
    generation_fallbacks: AtomicU64::new(0),
    rotations: AtomicU64::new(0),
};

/// The process-wide recovery counters.
pub fn recovery() -> &'static RecoveryStats {
    &RECOVERY
}

// ---- typed corrupt-artifact error -------------------------------------

/// Terminal corruption: the artifact at `path` is unreadable AND no
/// previous generation could stand in. Loaders attach this (via
/// `anyhow::Error::new`) so callers can downcast and distinguish
/// "corrupt → refuse" from ordinary I/O errors or staleness.
#[derive(Debug)]
pub struct CorruptArtifact {
    pub path: PathBuf,
    pub detail: String,
}

impl std::fmt::Display for CorruptArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "corrupt artifact {}: {} (no recoverable generation)",
            self.path.display(),
            self.detail
        )
    }
}

impl std::error::Error for CorruptArtifact {}

// ---- wrapped filesystem operations ------------------------------------

fn injected_err(kind: IoFaultKind, site: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::Other,
        format!("injected {} at {site}", kind.as_str()),
    )
}

/// Flip one bit near the middle of the buffer (deterministic position,
/// so same-seed runs corrupt identically).
fn bit_flipped(bytes: &[u8]) -> Vec<u8> {
    let mut v = bytes.to_vec();
    if !v.is_empty() {
        let i = v.len() / 2;
        v[i] ^= 0x01;
    }
    v
}

/// Fault-wrapped whole-file write (truncate semantics), retried up to
/// [`WRITE_RETRIES`] times. `torn_write` leaves a prefix behind and
/// retries; `enospc` fails before any byte lands and retries;
/// `bit_flip` silently succeeds with one corrupted byte (salvage on
/// read is the only defense). Only returns `Err` when the retry budget
/// is exhausted or the real filesystem fails.
pub fn write_file(site: &'static str, path: &Path, bytes: &[u8]) -> io::Result<()> {
    let mut last: Option<io::Error> = None;
    for attempt in 0..WRITE_RETRIES {
        if attempt > 0 {
            recovery().write_retries.fetch_add(1, Ordering::Relaxed);
        }
        match decide(site, OpClass::Write) {
            None => return std::fs::write(path, bytes),
            Some(IoFaultKind::BitFlip) => {
                return std::fs::write(path, bit_flipped(bytes));
            }
            Some(k @ IoFaultKind::TornWrite) => {
                let _ = std::fs::write(path, &bytes[..bytes.len() / 2]);
                last = Some(injected_err(k, site));
            }
            Some(k @ IoFaultKind::Enospc) => {
                last = Some(injected_err(k, site));
            }
            Some(_) => unreachable!("non-write kind for OpClass::Write"),
        }
    }
    Err(last.unwrap_or_else(|| injected_err(IoFaultKind::Enospc, site)))
}

/// Fault-wrapped append (used by the incremental trace flush). Only
/// `enospc` (retryable, nothing written) and `bit_flip` (silent
/// corruption, salvage on read) apply: a torn *append* cannot be
/// retried without duplicating the written prefix.
pub fn append_file(
    site: &'static str,
    path: &Path,
    bytes: &[u8],
    truncate: bool,
) -> io::Result<()> {
    use std::io::Write;
    let mut payload: Option<Vec<u8>> = None;
    let mut last: Option<io::Error> = None;
    let mut ok = false;
    for attempt in 0..WRITE_RETRIES {
        if attempt > 0 {
            recovery().write_retries.fetch_add(1, Ordering::Relaxed);
        }
        match decide(site, OpClass::Write) {
            None => {
                ok = true;
                break;
            }
            Some(IoFaultKind::BitFlip) => {
                payload = Some(bit_flipped(bytes));
                ok = true;
                break;
            }
            Some(k) => last = Some(injected_err(k, site)),
        }
    }
    if !ok {
        return Err(last.unwrap_or_else(|| injected_err(IoFaultKind::Enospc, site)));
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(!truncate)
        .write(true)
        .truncate(truncate)
        .open(path)?;
    f.write_all(payload.as_deref().unwrap_or(bytes))
}

/// Fault-wrapped rename. A `failed_rename` leaves the source (the tmp
/// file) behind and the destination untouched — exactly a crash between
/// write and rename.
pub fn rename(site: &'static str, from: &Path, to: &Path) -> io::Result<()> {
    if let Some(k) = decide(site, OpClass::Rename) {
        return Err(injected_err(k, site));
    }
    std::fs::rename(from, to)
}

/// Fault-wrapped atomic write: tmp file + rename, the whole pair
/// retried up to [`WRITE_RETRIES`] times. This is THE write path for
/// every durable artifact (schedule cache, `.asgm`, `.asg`, manifests).
pub fn write_atomic(site: &'static str, path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = path.with_extension(match path.extension() {
        Some(e) => format!("{}.tmp", e.to_string_lossy()),
        None => "tmp".to_string(),
    });
    let mut last: Option<io::Error> = None;
    for attempt in 0..WRITE_RETRIES {
        if attempt > 0 {
            recovery().write_retries.fetch_add(1, Ordering::Relaxed);
        }
        let written = match decide(site, OpClass::Write) {
            None => std::fs::write(&tmp, bytes).map(|_| ()),
            Some(IoFaultKind::BitFlip) => std::fs::write(&tmp, bit_flipped(bytes)),
            Some(k @ IoFaultKind::TornWrite) => {
                let _ = std::fs::write(&tmp, &bytes[..bytes.len() / 2]);
                Err(injected_err(k, site))
            }
            Some(k @ IoFaultKind::Enospc) => Err(injected_err(k, site)),
            Some(_) => unreachable!("non-write kind for OpClass::Write"),
        };
        match written.and_then(|_| rename(site, &tmp, path)) {
            Ok(()) => return Ok(()),
            Err(e) => last = Some(e),
        }
    }
    let _ = std::fs::remove_file(&tmp);
    Err(last.unwrap_or_else(|| injected_err(IoFaultKind::Enospc, site)))
}

/// Fault-wrapped whole-file read. `short_read` truncates the byte
/// stream; `bit_flip` corrupts one byte — both *silently*, so readers
/// must validate (checksums, per-line parses) and salvage.
pub fn read_file(site: &'static str, path: &Path) -> io::Result<Vec<u8>> {
    let data = std::fs::read(path)?;
    Ok(match decide(site, OpClass::Read) {
        None => data,
        Some(IoFaultKind::ShortRead) => data[..data.len() / 2].to_vec(),
        Some(IoFaultKind::BitFlip) => bit_flipped(&data),
        Some(_) => unreachable!("non-read kind for OpClass::Read"),
    })
}

/// [`read_file`] decoded as UTF-8 (lossy — injected truncation/flips
/// may split a code point; the JSON layer rejects what the decoder
/// mangles).
pub fn read_to_string(site: &'static str, path: &Path) -> io::Result<String> {
    Ok(String::from_utf8_lossy(&read_file(site, path)?).into_owned())
}

// ---- salvage + rotation helpers ---------------------------------------

/// Valid-prefix JSONL salvage: returns the leading run of lines that
/// parse as JSON and the count of dropped tail lines (first unparseable
/// line onward — a torn/short write corrupts the *tail*, never the
/// middle). Pure; callers account drops via
/// `recovery().jsonl_lines_dropped`.
pub fn salvage_jsonl(text: &str) -> (Vec<&str>, usize) {
    let lines: Vec<&str> = text.lines().collect();
    let mut kept = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        if crate::util::json::Json::parse(line).is_ok() {
            kept.push(*line);
        } else {
            return (kept, lines.len() - i);
        }
    }
    (kept, 0)
}

/// Size-capped rotation: when `path` holds at least `cap_bytes`, rename
/// it to `<path>.1` (replacing any previous rotation) so the live file
/// restarts empty. Returns whether a rotation happened; rotations count
/// in `recovery().rotations`. `cap_bytes == 0` disables rotation.
pub fn rotate_if_large(path: &Path, cap_bytes: u64) -> io::Result<bool> {
    if cap_bytes == 0 {
        return Ok(false);
    }
    match std::fs::metadata(path) {
        Ok(m) if m.len() >= cap_bytes => {
            let mut rotated = path.as_os_str().to_os_string();
            rotated.push(".1");
            std::fs::rename(path, PathBuf::from(rotated))?;
            recovery().rotations.fetch_add(1, Ordering::Relaxed);
            Ok(true)
        }
        _ => Ok(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_and_seeded() {
        let a = IoFaultInjector::new(7, 0.5, vec![]);
        let b = IoFaultInjector::new(7, 0.5, vec![]);
        for idx in 0..200 {
            assert_eq!(
                a.decide_at("site.x", idx, OpClass::Write),
                b.decide_at("site.x", idx, OpClass::Write),
                "same (seed, site, idx) must decide identically"
            );
        }
        let decisions_a: Vec<_> =
            (0..200).map(|i| a.decide_at("site.x", i, OpClass::Write)).collect();
        let c = IoFaultInjector::new(8, 0.5, vec![]);
        let decisions_c: Vec<_> =
            (0..200).map(|i| c.decide_at("site.x", i, OpClass::Write)).collect();
        assert_ne!(decisions_a, decisions_c, "different seed, different set");
        let other_site: Vec<_> =
            (0..200).map(|i| a.decide_at("site.y", i, OpClass::Write)).collect();
        assert_ne!(decisions_a, other_site, "sites are independent streams");
    }

    #[test]
    fn decisions_respect_op_class() {
        let inj = IoFaultInjector::new(3, 1.0, vec![]);
        for idx in 0..100 {
            if let Some(k) = inj.decide_at("s", idx, OpClass::Write) {
                assert!(applicable(k, OpClass::Write), "{k:?} not a write fault");
            }
            if let Some(k) = inj.decide_at("s", idx, OpClass::Read) {
                assert!(applicable(k, OpClass::Read), "{k:?} not a read fault");
            }
            assert_eq!(
                inj.decide_at("s", idx, OpClass::Rename),
                Some(IoFaultKind::FailedRename)
            );
        }
    }

    #[test]
    fn rate_zero_never_fires_rate_one_always() {
        let off = IoFaultInjector::new(1, 0.0, vec![]);
        let on = IoFaultInjector::new(1, 1.0, vec![]);
        for idx in 0..100 {
            assert_eq!(off.decide_at("s", idx, OpClass::Write), None);
            assert!(on.decide_at("s", idx, OpClass::Write).is_some());
        }
    }

    #[test]
    fn kind_filter_restricts_the_menu() {
        let inj = IoFaultInjector::new(5, 1.0, vec![IoFaultKind::Enospc]);
        for idx in 0..50 {
            assert_eq!(
                inj.decide_at("s", idx, OpClass::Write),
                Some(IoFaultKind::Enospc)
            );
            // Enospc is not a read fault: reads see nothing.
            assert_eq!(inj.decide_at("s", idx, OpClass::Read), None);
        }
    }

    #[test]
    fn parse_kinds_round_trip_and_dedup() {
        for k in IoFaultKind::ALL {
            assert_eq!(IoFaultKind::parse(k.as_str()), Some(k));
        }
        let v = parse_io_kinds("bit_flip, enospc ,bit_flip,").unwrap();
        assert_eq!(v, vec![IoFaultKind::BitFlip, IoFaultKind::Enospc]);
        assert!(parse_io_kinds("nope").is_err());
        assert!(parse_io_kinds("").unwrap().is_empty());
    }

    #[test]
    fn log_snapshot_is_sorted_and_counted() {
        let inj = IoFaultInjector::new(11, 1.0, vec![IoFaultKind::Enospc]);
        inj.next("b.site", OpClass::Write);
        inj.next("a.site", OpClass::Write);
        inj.next("a.site", OpClass::Write);
        assert_eq!(inj.injected_total(), 3);
        assert_eq!(inj.injected_of(IoFaultKind::Enospc), 3);
        let log = inj.log_snapshot();
        assert_eq!(
            log,
            vec![
                ("a.site".to_string(), 0, IoFaultKind::Enospc),
                ("a.site".to_string(), 1, IoFaultKind::Enospc),
                ("b.site".to_string(), 0, IoFaultKind::Enospc),
            ]
        );
    }

    #[test]
    fn salvage_jsonl_recovers_valid_prefix() {
        let text = "{\"a\":1}\n{\"b\":2}\n{\"c\":tr\n{\"d\":4}\n";
        let (kept, dropped) = salvage_jsonl(text);
        assert_eq!(kept, vec!["{\"a\":1}", "{\"b\":2}"]);
        assert_eq!(dropped, 2, "corrupt line AND everything after it drop");
        let (kept, dropped) = salvage_jsonl("{\"a\":1}\n{\"b\":2}\n");
        assert_eq!((kept.len(), dropped), (2, 0));
        let (kept, dropped) = salvage_jsonl("");
        assert_eq!((kept.len(), dropped), (0, 0));
        // A torn final line (no closing brace) is the classic case.
        let (kept, dropped) = salvage_jsonl("{\"a\":1}\n{\"b\":");
        assert_eq!((kept.len(), dropped), (1, 1));
    }

    #[test]
    fn rotate_if_large_renames_and_counts() {
        let dir = std::env::temp_dir().join("autosage_iofault_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("rot-{}.jsonl", std::process::id()));
        std::fs::write(&p, "0123456789").unwrap();
        assert!(!rotate_if_large(&p, 0).unwrap(), "cap 0 disables rotation");
        assert!(!rotate_if_large(&p, 1000).unwrap(), "below cap: no-op");
        let before = recovery().rotations.load(Ordering::Relaxed);
        assert!(rotate_if_large(&p, 10).unwrap());
        assert!(!p.exists());
        let mut rotated = p.as_os_str().to_os_string();
        rotated.push(".1");
        let rotated = PathBuf::from(rotated);
        assert_eq!(std::fs::read_to_string(&rotated).unwrap(), "0123456789");
        assert!(recovery().rotations.load(Ordering::Relaxed) > before);
        let _ = std::fs::remove_file(&rotated);
    }

    // NOTE: tests for the global install() + wrapper behavior live in
    // `tests/durability.rs` behind one shared lock — the injector slot
    // is process-global and unit tests run concurrently.

    #[test]
    fn write_atomic_passthrough_without_injector() {
        let dir = std::env::temp_dir().join("autosage_iofault_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("atomic-{}.json", std::process::id()));
        write_atomic("test.site", &p, b"{\"ok\":true}").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"ok\":true}");
        assert_eq!(read_file("test.site", &p).unwrap(), b"{\"ok\":true}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_artifact_displays_path_and_detail() {
        let e = CorruptArtifact {
            path: PathBuf::from("/x/model.asgm"),
            detail: "checksum mismatch".to_string(),
        };
        let msg = format!("{e}");
        assert!(msg.contains("model.asgm"));
        assert!(msg.contains("checksum mismatch"));
        let any = anyhow::Error::new(e);
        assert!(any.downcast_ref::<CorruptArtifact>().is_some());
    }
}
