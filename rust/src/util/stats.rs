//! Descriptive statistics used by feature extraction (degree quantiles,
//! skew) and by the timing harness (median-of-n, the paper's protocol).

/// Quantile of a sorted slice with linear interpolation (type-7, the
/// numpy default — keeps our feature values comparable to the paper's).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Quantile of an unsorted slice (copies + sorts).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Gini coefficient of a non-negative distribution — our degree-skew
/// feature (0 = perfectly balanced rows, →1 = extreme hub skew).
pub fn gini(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    let total: f64 = v.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let weighted: f64 = v
        .iter()
        .enumerate()
        .map(|(i, x)| (i as f64 + 1.0) * x)
        .sum();
    (2.0 * weighted) / (n * total) - (n + 1.0) / n
}

/// Coefficient of variation (std/mean) — secondary skew feature.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / m
}

/// Summary of repeated timing measurements (milliseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct TimingSummary {
    pub n: usize,
    pub median_ms: f64,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub p90_ms: f64,
}

impl TimingSummary {
    pub fn from_ms(samples: &[f64]) -> TimingSummary {
        assert!(!samples.is_empty());
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        TimingSummary {
            n: v.len(),
            median_ms: quantile_sorted(&v, 0.5),
            mean_ms: mean(&v),
            min_ms: v[0],
            max_ms: v[v.len() - 1],
            p90_ms: quantile_sorted(&v, 0.9),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_match_numpy_type7() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn gini_uniform_zero() {
        assert!(gini(&[5.0; 100]).abs() < 1e-9);
    }

    #[test]
    fn gini_extreme_near_one() {
        let mut xs = vec![0.0; 999];
        xs.push(1000.0);
        assert!(gini(&xs) > 0.99);
    }

    #[test]
    fn gini_monotone_in_skew() {
        let balanced = vec![4.0; 100];
        let mut skewed = vec![1.0; 100];
        for d in skewed.iter_mut().take(10) {
            *d = 300.0;
        }
        assert!(gini(&skewed) > gini(&balanced));
    }

    #[test]
    fn cv_zero_for_constant() {
        assert_eq!(cv(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn timing_summary_basics() {
        let s = TimingSummary::from_ms(&[3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 10.0);
        assert_eq!(s.median_ms, 2.5);
    }

    #[test]
    #[should_panic]
    fn quantile_empty_panics() {
        quantile_sorted(&[], 0.5);
    }
}
