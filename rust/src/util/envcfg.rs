//! Typed environment-toggle parsing for the `AUTOSAGE_*` controls
//! (paper §5: deployment toggles): probe budget, thresholds,
//! vectorization, cache path, replay-only mode.

use std::env;

/// Read an env var through a parser, with a default on absence.
/// Malformed values are an error (silently ignoring a typo'd toggle is
/// exactly the failure mode the paper's telemetry is meant to prevent).
pub fn parse_env<T, F>(name: &str, default: T, parse: F) -> Result<T, String>
where
    F: FnOnce(&str) -> Option<T>,
{
    match env::var(name) {
        Err(_) => Ok(default),
        Ok(raw) => parse(raw.trim())
            .ok_or_else(|| format!("invalid value for {name}: {raw:?}")),
    }
}

pub fn env_f64(name: &str, default: f64) -> Result<f64, String> {
    parse_env(name, default, |s| s.parse().ok())
}

pub fn env_usize(name: &str, default: usize) -> Result<usize, String> {
    parse_env(name, default, |s| s.parse().ok())
}

pub fn env_bool(name: &str, default: bool) -> Result<bool, String> {
    parse_env(name, default, |s| match s.to_ascii_lowercase().as_str() {
        "1" | "true" | "yes" | "on" => Some(true),
        "0" | "false" | "no" | "off" => Some(false),
        _ => None,
    })
}

pub fn env_string(name: &str, default: &str) -> String {
    env::var(name).unwrap_or_else(|_| default.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: env-var tests mutate process state; each test uses a unique
    // variable name to stay independent under parallel test threads.

    #[test]
    fn default_when_absent() {
        assert_eq!(env_f64("AUTOSAGE_TEST_ABSENT_F", 0.95).unwrap(), 0.95);
        assert_eq!(env_usize("AUTOSAGE_TEST_ABSENT_U", 3).unwrap(), 3);
        assert!(env_bool("AUTOSAGE_TEST_ABSENT_B", true).unwrap());
    }

    #[test]
    fn parses_values() {
        env::set_var("AUTOSAGE_TEST_F", "0.98");
        assert_eq!(env_f64("AUTOSAGE_TEST_F", 0.0).unwrap(), 0.98);
        env::set_var("AUTOSAGE_TEST_U", " 512 ");
        assert_eq!(env_usize("AUTOSAGE_TEST_U", 0).unwrap(), 512);
        env::set_var("AUTOSAGE_TEST_B1", "on");
        assert!(env_bool("AUTOSAGE_TEST_B1", false).unwrap());
        env::set_var("AUTOSAGE_TEST_B0", "FALSE");
        assert!(!env_bool("AUTOSAGE_TEST_B0", true).unwrap());
    }

    #[test]
    fn malformed_is_error() {
        env::set_var("AUTOSAGE_TEST_BAD", "not-a-number");
        assert!(env_f64("AUTOSAGE_TEST_BAD", 1.0).is_err());
        assert!(env_bool("AUTOSAGE_TEST_BAD", false).is_err());
    }
}
