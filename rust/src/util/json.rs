//! Minimal JSON: parser + serializer.
//!
//! Used for the artifact manifest, the persistent schedule cache, and the
//! `.meta.json` telemetry sidecars. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII files),
//! and preserves object insertion order (stable cache files / diffs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (i64-exact integers round-trip).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for stable on-disk files.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multibyte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ------------------------------------------------------------ serializer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl Json {
    /// Pretty-print with 2-space indent (cache files are human-inspected).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.pretty_into(&mut s, 0);
        s
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close = "  ".repeat(depth);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&pad);
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&pad);
                    out.push_str(&format!("{}: ", Json::Str(k.clone())));
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push('}');
            }
            other => out.push_str(&other.to_string()),
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aπ""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aπ"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::Arr(vec![Json::from(true), Json::Null])),
        ]);
        let back = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_exact() {
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_i64(), Some(9007199254740991));
        assert_eq!(v.to_string(), "9007199254740991");
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(Json::Null.get("deep").get("deeper"), &Json::Null);
    }
}
