//! AutoSAGE — input-aware scheduling for sparse GNN aggregation
//! (CSR/ELL SpMM, SDDMM and CSR attention) on a Rust + JAX + Pallas
//! AOT stack (PJRT runtime).
//!
//! Reproduction of: *AutoSAGE: Input-Aware CUDA Scheduling for Sparse GNN
//! Aggregation (SpMM/SDDMM) and CSR Attention* (Stanković, 2025), adapted
//! from CUDA to a TPU-style Pallas kernel space (see `DESIGN.md`).
//!
//! Layering:
//! * [`util`] — substrates built from scratch (JSON, RNG, stats, CSV, env).
//! * [`graph`] — CSR/ELL formats, bucketing, signatures.
//! * [`gen`] — synthetic workload generators (paper presets, scaled).
//! * [`runtime`] — PJRT client, artifact manifest, executable cache.
//! * [`ops`] — typed SpMM/SDDMM/softmax/attention ops + Rust oracle.
//! * [`scheduler`] — the paper's contribution: estimate → micro-probe →
//!   guardrail, with a persistent decision cache and replay mode.
//! * [`coordinator`] — the public facade (`AutoSage`) and request queue.
//! * [`bench_kit`] — criterion-replacement harness + table/figure output.

pub mod bench_kit;
pub mod config;
pub mod coordinator;
pub mod gen;
pub mod graph;
pub mod ops;
pub mod runtime;
pub mod scheduler;
pub mod telemetry;
pub mod util;



pub fn cli_placeholder() { println!("autosage"); }
