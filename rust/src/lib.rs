//! AutoSAGE — input-aware scheduling for sparse GNN aggregation
//! (CSR/ELL SpMM, SDDMM and CSR attention) with pluggable execution
//! backends: a pure-Rust parameterized kernel engine (default) and a
//! Rust + JAX + Pallas AOT stack over a PJRT runtime (feature `pjrt`).
//!
//! Reproduction of: *AutoSAGE: Input-Aware CUDA Scheduling for Sparse GNN
//! Aggregation (SpMM/SDDMM) and CSR Attention* (Stanković, 2025), adapted
//! from CUDA to parameterized kernel spaces the scheduler can probe (see
//! `README.md` for the backend architecture).
//!
//! Layering:
//! * [`util`] — substrates built from scratch (JSON, RNG, stats, CSV, env).
//! * [`graph`] — CSR/ELL formats, bucketing, signatures.
//! * [`gen`] — synthetic workload generators (paper presets, scaled).
//! * [`data`] — dataset ingestion (Matrix Market / edge lists / `.asg`
//!   binary snapshots), canonical normalization, degree-aware row
//!   reordering with un-permutation, and the graph-spec grammar
//!   (`"preset"` | `"file:PATH"`) every surface accepts.
//! * [`runtime`] — kernel manifest (parsed from `artifacts/manifest.json`
//!   or synthesized natively), host tensors, and — behind the `pjrt`
//!   feature — the PJRT client for AOT artifacts.
//! * [`backend`] — the `Backend` trait plus its two engines: the native
//!   pure-Rust kernels (ELL row/feature tiles, hub split, COO scatter,
//!   fused attention) and the PJRT device. The scheduler probes and the
//!   coordinator executes only through this trait.
//! * [`ops`] — typed SpMM/SDDMM/softmax/attention ops + Rust oracle.
//! * [`scheduler`] — the paper's contribution: estimate → micro-probe →
//!   guardrail, with a persistent decision cache and replay mode.
//! * [`model`] — the learned scheduler: mines probe + audit telemetry
//!   into a trained per-op decision tree (`autosage train`, `.asgm`
//!   files) that predicts variants for cold keys; the scheduler probes
//!   only when the calibrated confidence is low.
//! * [`coordinator`] — the public facade (`AutoSage`) and request queue.
//! * [`server`] — the concurrent serving subsystem: sharded worker
//!   pool, shared single-flight schedule cache, request coalescing,
//!   bounded queues with backpressure, serving metrics, load generator.
//! * [`obs`] — flight recorder: structured trace spans (JSONL), versioned
//!   run manifests with artifact checksums, and perf-profile comparison
//!   with noise-aware regression gating.
//! * [`bench_kit`] — criterion-replacement harness + table/figure output.

pub mod backend;
pub mod bench_kit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gen;
pub mod graph;
pub mod model;
pub mod obs;
pub mod ops;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod telemetry;
pub mod util;
