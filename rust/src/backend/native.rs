//! The native backend: every manifest variant family implemented in
//! pure Rust, with *real* tiling/mapping parameters.
//!
//! Kernels consume exactly the packed tensors the AOT artifacts take
//! (same `InputSpec` contract, same padded static shapes) and pay for
//! every padded slot — the property the roofline estimate models and
//! the micro-probe measures. The tile knobs are live, not decorative:
//!
//! * ELL row kernels take a row tile `r` and feature tile `ft`; the
//!   feature-tiled loop re-reads the `colind`/`val` slot arrays once per
//!   feature pass (`f / ft` passes), so small `ft` on wide features is
//!   measurably slower — the CPU analog of the paper's tiling tradeoff.
//! * `*_f128` variants run an 8-lane unrolled inner loop (the wide-lane
//!   / "vec4" analog), legal only when `F % 128 == 0` (vec gating).
//! * Hub-split kernels run a narrow light-ELL pass plus a dedicated
//!   hub block, so heavily skewed graphs touch far fewer slots.
//! * The COO scatter/gather baselines are nnz-proportional and
//!   skew-immune, exactly like the vendor paths they stand in for.
//!
//! Because the cost differences are real, `Scheduler::decide` can
//! discriminate between variants by probing them — no artifacts needed.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::manifest::ArtifactEntry;
use crate::runtime::Tensor;
use crate::scheduler::estimate::DeviceModel;
use crate::util::stats::TimingSummary;
use crate::util::timing::{time_fn, Stopwatch};

use super::Backend;

/// Pure-Rust kernel backend. Cheap to construct; "compilation" is
/// kernel resolution plus a warm-up bookkeeping entry.
pub struct NativeBackend {
    /// entry name -> resolve/warm-up ms (mirrors the PJRT compile cache).
    warmed: RefCell<HashMap<String, f64>>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { warmed: RefCell::new(HashMap::new()) }
    }

    /// Dispatch an entry to its kernel and execute it once.
    pub fn execute(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>> {
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: {} inputs supplied, kernel takes {}",
                entry.name,
                inputs.len(),
                entry.inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&entry.inputs) {
            t.check_spec(spec)
                .map_err(|e| anyhow!("{}: {e}", entry.name))?;
        }
        dispatch(entry, inputs)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform_name(&self) -> String {
        "native".to_string()
    }

    fn platform_version(&self) -> String {
        format!("rust-{}", env!("CARGO_PKG_VERSION"))
    }

    fn load(&self, entry: &ArtifactEntry) -> Result<()> {
        if self.warmed.borrow().contains_key(&entry.name) {
            return Ok(());
        }
        let sw = Stopwatch::start();
        classify(entry)?; // resolution = "compilation" for native kernels
        self.warmed.borrow_mut().insert(entry.name.clone(), sw.ms());
        Ok(())
    }

    fn run_f32(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>> {
        self.load(entry)?;
        self.execute(entry, inputs)
    }

    fn time_entry(
        &self,
        entry: &ArtifactEntry,
        inputs: &[Tensor],
        warmup: usize,
        iters: usize,
        cap_ms: f64,
    ) -> Result<TimingSummary> {
        self.load(entry)?;
        // Fail fast on a broken entry before entering the timed loop.
        self.execute(entry, inputs)?;
        Ok(time_fn(
            || {
                let _ = self.execute(entry, inputs);
            },
            warmup,
            iters,
            cap_ms,
        ))
    }

    fn executes_grid_kernels(&self) -> bool {
        true
    }

    fn device_model(&self) -> DeviceModel {
        DeviceModel {
            mem_bw_gbps: 8.0,
            peak_gflops: 8.0,
            // Native tile loops have only loop-control overhead per
            // step, not the interpret-mode panel re-slice of the PJRT
            // CPU testbed.
            step_us: 0.05,
            grid_panel_emulation: false,
        }
    }

    fn total_compile_ms(&self) -> f64 {
        self.warmed.borrow().values().sum()
    }

    fn compiled_count(&self) -> usize {
        self.warmed.borrow().len()
    }
}

// ------------------------------------------------------------ dispatch

/// Kernel family an entry resolves to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    SpmmScatter,
    SpmmEll,
    SpmmHub,
    Sddmm,
    Softmax,
    AttnBaseline,
    AttnFused,
    LinearRelu,
}

fn classify(entry: &ArtifactEntry) -> Result<Kind> {
    let kind = match (entry.op.as_str(), entry.variant.as_str()) {
        ("spmm", "baseline_scatter") => Kind::SpmmScatter,
        ("spmm", "ell_gather") => Kind::SpmmEll,
        ("spmm", v) if v.starts_with("ell_r") => Kind::SpmmEll,
        ("spmm", "hub_gather") => Kind::SpmmHub,
        ("spmm", v) if v.starts_with("hub_r") => Kind::SpmmHub,
        ("sddmm", "baseline_gather") => Kind::Sddmm,
        ("sddmm", v) if v.starts_with("ell_r") => Kind::Sddmm,
        ("softmax", "baseline") => Kind::Softmax,
        ("softmax", v) if v.starts_with("ell_r") => Kind::Softmax,
        ("attention", "baseline") => Kind::AttnBaseline,
        ("attention", "fused_gather") => Kind::AttnFused,
        ("attention", v) if v.starts_with("fused_r") => Kind::AttnFused,
        ("linear_relu", _) => Kind::LinearRelu,
        (op, v) => bail!(
            "native backend cannot execute op={op:?} variant={v:?} ({})",
            entry.name
        ),
    };
    Ok(kind)
}

/// Tile knobs for an entry: row tile, feature tile, wide-lane flag.
/// Gather (grid-free) variants degenerate to one full-size tile.
fn tiles(entry: &ArtifactEntry, n_pad: usize, f: usize) -> (usize, usize, bool) {
    let r = entry.param_usize("r").unwrap_or(n_pad).max(1);
    let ft = entry.param_usize("ft").unwrap_or(f.max(1)).max(1);
    let vec_lanes = entry.variant.contains("f128");
    (r, ft, vec_lanes)
}

fn f32_in<'a>(entry: &ArtifactEntry, inputs: &'a [Tensor], name: &str) -> Result<&'a [f32]> {
    let idx = entry
        .inputs
        .iter()
        .position(|s| s.name == name)
        .ok_or_else(|| anyhow!("{}: kernel needs input {name:?}", entry.name))?;
    match &inputs[idx] {
        Tensor::F32 { data, .. } => Ok(data),
        Tensor::I32 { .. } => bail!("{}: input {name:?} is not f32", entry.name),
    }
}

fn i32_in<'a>(entry: &ArtifactEntry, inputs: &'a [Tensor], name: &str) -> Result<&'a [i32]> {
    let idx = entry
        .inputs
        .iter()
        .position(|s| s.name == name)
        .ok_or_else(|| anyhow!("{}: kernel needs input {name:?}", entry.name))?;
    match &inputs[idx] {
        Tensor::I32 { data, .. } => Ok(data),
        Tensor::F32 { .. } => bail!("{}: input {name:?} is not i32", entry.name),
    }
}

fn dispatch(entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>> {
    let n_pad = entry.require_usize("n_pad")?;
    match classify(entry)? {
        Kind::SpmmScatter => {
            let f = entry.require_usize("f")?;
            Ok(spmm_scatter(
                i32_in(entry, inputs, "row")?,
                i32_in(entry, inputs, "col")?,
                f32_in(entry, inputs, "val")?,
                f32_in(entry, inputs, "b")?,
                n_pad,
                f,
            ))
        }
        Kind::SpmmEll => {
            let f = entry.require_usize("f")?;
            let w = entry.require_usize("w")?;
            let (r, ft, vec) = tiles(entry, n_pad, f);
            Ok(spmm_ell_tiled(
                i32_in(entry, inputs, "colind")?,
                f32_in(entry, inputs, "val")?,
                f32_in(entry, inputs, "b")?,
                n_pad,
                w,
                f,
                r,
                ft,
                vec,
            ))
        }
        Kind::SpmmHub => {
            let f = entry.require_usize("f")?;
            let w_light = entry.require_usize("w_light")?;
            let h_pad = entry.require_usize("h_pad")?;
            let w_hub = entry.require_usize("w_hub")?;
            let (r, ft, vec) = tiles(entry, n_pad, f);
            let b = f32_in(entry, inputs, "b")?;
            let mut out = spmm_ell_tiled(
                i32_in(entry, inputs, "light_colind")?,
                f32_in(entry, inputs, "light_val")?,
                b,
                n_pad,
                w_light,
                f,
                r,
                ft,
                vec,
            );
            hub_block(
                &mut out,
                i32_in(entry, inputs, "hub_rows")?,
                i32_in(entry, inputs, "hub_colind")?,
                f32_in(entry, inputs, "hub_val")?,
                b,
                h_pad,
                w_hub,
                f,
                vec,
            );
            Ok(out)
        }
        Kind::Sddmm => {
            let f = entry.require_usize("f")?;
            let w = entry.require_usize("w")?;
            let (r, ft, vec) = tiles(entry, n_pad, f);
            Ok(sddmm_tiled(
                i32_in(entry, inputs, "colind")?,
                f32_in(entry, inputs, "mask")?,
                f32_in(entry, inputs, "x")?,
                f32_in(entry, inputs, "y")?,
                n_pad,
                w,
                f,
                r,
                ft,
                vec,
            ))
        }
        Kind::Softmax => {
            let w = entry.require_usize("w")?;
            let r = entry.param_usize("r").unwrap_or(n_pad).max(1);
            Ok(softmax_ell(
                f32_in(entry, inputs, "val")?,
                f32_in(entry, inputs, "mask")?,
                n_pad,
                w,
                r,
            ))
        }
        Kind::AttnBaseline => {
            let f = entry.require_usize("f")?;
            let w = entry.require_usize("w")?;
            Ok(attn_baseline(
                i32_in(entry, inputs, "colind")?,
                f32_in(entry, inputs, "mask")?,
                i32_in(entry, inputs, "row")?,
                i32_in(entry, inputs, "col")?,
                f32_in(entry, inputs, "q")?,
                f32_in(entry, inputs, "k")?,
                f32_in(entry, inputs, "v")?,
                n_pad,
                w,
                f,
            ))
        }
        Kind::AttnFused => {
            let f = entry.require_usize("f")?;
            let w = entry.require_usize("w")?;
            let (r, ft, vec) = tiles(entry, n_pad, f);
            Ok(attn_fused(
                i32_in(entry, inputs, "colind")?,
                f32_in(entry, inputs, "mask")?,
                f32_in(entry, inputs, "q")?,
                f32_in(entry, inputs, "k")?,
                f32_in(entry, inputs, "v")?,
                n_pad,
                w,
                f,
                r,
                ft,
                vec,
            ))
        }
        Kind::LinearRelu => {
            let f_in = entry.require_usize("f_in")?;
            let f_out = entry.require_usize("f_out")?;
            Ok(linear_relu(
                f32_in(entry, inputs, "h")?,
                f32_in(entry, inputs, "w")?,
                f32_in(entry, inputs, "bias")?,
                n_pad,
                f_in,
                f_out,
            ))
        }
    }
}

// ------------------------------------------------------------- kernels
//
// All kernels iterate every padded slot (v = 0 contributions), exactly
// like the static-shape artifacts: padding waste is a real, probeable
// cost, and summation order matches the CSR-ordered Rust oracle so
// outputs agree to float round-off.

/// 8-lane unrolled axpy: `dst += v * src` (the wide-lane inner loop).
#[inline]
fn axpy8(dst: &mut [f32], src: &[f32], v: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut sc = src.chunks_exact(8);
    for (d, s) in (&mut dc).zip(&mut sc) {
        d[0] += v * s[0];
        d[1] += v * s[1];
        d[2] += v * s[2];
        d[3] += v * s[3];
        d[4] += v * s[4];
        d[5] += v * s[5];
        d[6] += v * s[6];
        d[7] += v * s[7];
    }
    for (d, s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d += v * *s;
    }
}

/// COO scatter-add SpMM (the vendor baseline): nnz-proportional,
/// skew-immune, read-modify-write on C. The COO contract is unordered,
/// so the kernel cannot hoist per-row output slices the way the ELL
/// kernels do — each edge pays the full indexed scatter, the CPU analog
/// of the atomicAdd path (and what the estimate's 2× write term models).
fn spmm_scatter(row: &[i32], col: &[i32], val: &[f32], b: &[f32], n_pad: usize, f: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_pad * f];
    for e in 0..row.len() {
        let r = row[e] as usize * f;
        let c = col[e] as usize * f;
        let v = val[e];
        for j in 0..f {
            out[r + j] += v * b[c + j];
        }
    }
    out
}

/// Row/feature-tiled ELL SpMM. `r`/`ft` are live tile knobs; the
/// feature-tiled loop re-reads the slot arrays once per feature pass.
/// `ell_gather` is the grid-free limit (`r = n_pad`, `ft = f`).
#[allow(clippy::too_many_arguments)]
fn spmm_ell_tiled(
    colind: &[i32],
    val: &[f32],
    b: &[f32],
    n_pad: usize,
    w: usize,
    f: usize,
    r: usize,
    ft: usize,
    vec_lanes: bool,
) -> Vec<f32> {
    let r = r.min(n_pad.max(1));
    let ft = ft.min(f.max(1));
    let mut out = vec![0.0f32; n_pad * f];
    for i0 in (0..n_pad).step_by(r) {
        let i1 = (i0 + r).min(n_pad);
        for j0 in (0..f).step_by(ft) {
            let j1 = (j0 + ft).min(f);
            for i in i0..i1 {
                let dst = &mut out[i * f + j0..i * f + j1];
                for s in 0..w {
                    let v = val[i * w + s];
                    let c = colind[i * w + s] as usize;
                    let src = &b[c * f + j0..c * f + j1];
                    if vec_lanes {
                        axpy8(dst, src, v);
                    } else {
                        for (d, x) in dst.iter_mut().zip(src) {
                            *d += v * *x;
                        }
                    }
                }
            }
        }
    }
    out
}

/// The hub block of the hub-split kernel: one padded neighbor list per
/// hub row, scatter-added into the output (padded hub slots carry
/// `hub_rows = 0`, `hub_val = 0` and contribute nothing).
#[allow(clippy::too_many_arguments)]
fn hub_block(
    out: &mut [f32],
    hub_rows: &[i32],
    hub_colind: &[i32],
    hub_val: &[f32],
    b: &[f32],
    h_pad: usize,
    w_hub: usize,
    f: usize,
    vec_lanes: bool,
) {
    for k in 0..h_pad {
        let row = hub_rows[k] as usize;
        let dst = &mut out[row * f..(row + 1) * f];
        for s in 0..w_hub {
            let v = hub_val[k * w_hub + s];
            let c = hub_colind[k * w_hub + s] as usize;
            let src = &b[c * f..(c + 1) * f];
            if vec_lanes {
                axpy8(dst, src, v);
            } else {
                for (d, x) in dst.iter_mut().zip(src) {
                    *d += v * *x;
                }
            }
        }
    }
}

/// Row/feature-tiled SDDMM over ELL: per stored slot, `<x_i, y_j>`,
/// masked. Partial dots accumulate per feature tile; the mask is applied
/// in a final pass so padded slots are exactly zero.
#[allow(clippy::too_many_arguments)]
fn sddmm_tiled(
    colind: &[i32],
    mask: &[f32],
    x: &[f32],
    y: &[f32],
    n_pad: usize,
    w: usize,
    f: usize,
    r: usize,
    ft: usize,
    vec_lanes: bool,
) -> Vec<f32> {
    let r = r.min(n_pad.max(1));
    let ft = ft.min(f.max(1));
    let mut out = vec![0.0f32; n_pad * w];
    for i0 in (0..n_pad).step_by(r) {
        let i1 = (i0 + r).min(n_pad);
        for j0 in (0..f).step_by(ft) {
            let j1 = (j0 + ft).min(f);
            for i in i0..i1 {
                let xi = &x[i * f + j0..i * f + j1];
                for s in 0..w {
                    let c = colind[i * w + s] as usize;
                    let yj = &y[c * f + j0..c * f + j1];
                    out[i * w + s] += dot(xi, yj, vec_lanes);
                }
            }
        }
    }
    for (o, m) in out.iter_mut().zip(mask) {
        *o *= *m;
    }
    out
}

/// Inner dot product; 8-lane unrolled on the wide-lane path.
#[inline]
fn dot(a: &[f32], b: &[f32], vec_lanes: bool) -> f32 {
    if vec_lanes {
        let mut acc = [0.0f32; 8];
        let mut ac = a.chunks_exact(8);
        let mut bc = b.chunks_exact(8);
        for (x, y) in (&mut ac).zip(&mut bc) {
            for l in 0..8 {
                acc[l] += x[l] * y[l];
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
            tail += x * y;
        }
        acc.iter().sum::<f32>() + tail
    } else {
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }
}

/// Numerically-stable masked row softmax over ELL `[n_pad, w]` values.
/// Rows with no valid slot produce zeros (mirrors the oracle's skip).
fn softmax_ell(val: &[f32], mask: &[f32], n_pad: usize, w: usize, r: usize) -> Vec<f32> {
    let r = r.min(n_pad.max(1));
    let mut out = vec![0.0f32; n_pad * w];
    for i0 in (0..n_pad).step_by(r) {
        for i in i0..(i0 + r).min(n_pad) {
            let row = &val[i * w..(i + 1) * w];
            let m = &mask[i * w..(i + 1) * w];
            let mut mx = f32::NEG_INFINITY;
            for s in 0..w {
                if m[s] > 0.0 && row[s] > mx {
                    mx = row[s];
                }
            }
            if mx == f32::NEG_INFINITY {
                continue; // empty row
            }
            let dst = &mut out[i * w..(i + 1) * w];
            let mut sum = 0.0f32;
            for s in 0..w {
                if m[s] > 0.0 {
                    let e = (row[s] - mx).exp();
                    dst[s] = e;
                    sum += e;
                }
            }
            let denom = sum.max(1e-30);
            for d in dst.iter_mut() {
                *d /= denom;
            }
        }
    }
    out
}

/// Baseline CSR attention: ELL SDDMM + row softmax, then a COO
/// scatter-add SpMM over the attention weights (the vendor composition).
/// Attention weights are laid out in CSR slot order — the same row-major
/// left-packed order `CooBuffers` uses — so padded COO entries see
/// weight 0 and contribute nothing.
#[allow(clippy::too_many_arguments)]
fn attn_baseline(
    colind: &[i32],
    mask: &[f32],
    row: &[i32],
    col: &[i32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_pad: usize,
    w: usize,
    f: usize,
) -> Vec<f32> {
    let mut attn = vec![0.0f32; row.len()];
    let mut scores = vec![0.0f32; w];
    let mut e_idx = 0usize;
    for i in 0..n_pad {
        let mrow = &mask[i * w..(i + 1) * w];
        let deg = mrow.iter().filter(|&&m| m > 0.0).count();
        if deg == 0 {
            continue;
        }
        let qi = &q[i * f..(i + 1) * f];
        for s in 0..deg {
            // valid slots are left-packed by construction
            let c = colind[i * w + s] as usize;
            scores[s] = dot(qi, &k[c * f..(c + 1) * f], false);
        }
        let mut mx = f32::NEG_INFINITY;
        for &sc in &scores[..deg] {
            if sc > mx {
                mx = sc;
            }
        }
        let mut sum = 0.0f32;
        for s in 0..deg {
            let e = (scores[s] - mx).exp();
            scores[s] = e;
            sum += e;
        }
        let denom = sum.max(1e-30);
        for s in 0..deg {
            attn[e_idx + s] = scores[s] / denom;
        }
        e_idx += deg;
    }
    let mut out = vec![0.0f32; n_pad * f];
    for e in 0..row.len() {
        let aw = attn[e];
        let ri = row[e] as usize;
        let c = col[e] as usize;
        let src = &v[c * f..(c + 1) * f];
        let dst = &mut out[ri * f..(ri + 1) * f];
        for (d, x) in dst.iter_mut().zip(src) {
            *d += aw * *x;
        }
    }
    out
}

/// Fused SDDMM → softmax → SpMM attention over ELL: one pass per row
/// tile, scores kept in registers/stack — the fused-kernel analog. The
/// score stage tiles the feature dimension by `ft`.
#[allow(clippy::too_many_arguments)]
fn attn_fused(
    colind: &[i32],
    mask: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n_pad: usize,
    w: usize,
    f: usize,
    r: usize,
    ft: usize,
    vec_lanes: bool,
) -> Vec<f32> {
    let r = r.min(n_pad.max(1));
    let ft = ft.min(f.max(1));
    let mut out = vec![0.0f32; n_pad * f];
    let mut scores = vec![0.0f32; w];
    for i0 in (0..n_pad).step_by(r) {
        for i in i0..(i0 + r).min(n_pad) {
            let mrow = &mask[i * w..(i + 1) * w];
            let qi = &q[i * f..(i + 1) * f];
            let mut any = false;
            // SDDMM stage, feature-tiled like the grid kernel.
            for s in 0..w {
                if mrow[s] <= 0.0 {
                    scores[s] = 0.0;
                    continue;
                }
                any = true;
                let c = colind[i * w + s] as usize;
                let kc = &k[c * f..(c + 1) * f];
                let mut acc = 0.0f32;
                for j0 in (0..f).step_by(ft) {
                    let j1 = (j0 + ft).min(f);
                    acc += dot(&qi[j0..j1], &kc[j0..j1], vec_lanes);
                }
                scores[s] = acc;
            }
            if !any {
                continue; // empty row -> zeros
            }
            // Row softmax over valid slots.
            let mut mx = f32::NEG_INFINITY;
            for s in 0..w {
                if mrow[s] > 0.0 && scores[s] > mx {
                    mx = scores[s];
                }
            }
            let mut sum = 0.0f32;
            for s in 0..w {
                if mrow[s] > 0.0 {
                    let e = (scores[s] - mx).exp();
                    scores[s] = e;
                    sum += e;
                } else {
                    scores[s] = 0.0;
                }
            }
            let denom = sum.max(1e-30);
            // SpMM stage over the attention weights.
            let dst = &mut out[i * f..(i + 1) * f];
            for s in 0..w {
                if mrow[s] <= 0.0 {
                    continue;
                }
                let aw = scores[s] / denom;
                let c = colind[i * w + s] as usize;
                let src = &v[c * f..(c + 1) * f];
                if vec_lanes {
                    axpy8(dst, src, aw);
                } else {
                    for (d, x) in dst.iter_mut().zip(src) {
                        *d += aw * *x;
                    }
                }
            }
        }
    }
    out
}

/// Dense `relu(H @ W + bias)` (the GCN example's transform).
fn linear_relu(h: &[f32], wmat: &[f32], bias: &[f32], n_pad: usize, f_in: usize, f_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_pad * f_out];
    for i in 0..n_pad {
        let hi = &h[i * f_in..(i + 1) * f_in];
        let dst = &mut out[i * f_out..(i + 1) * f_out];
        for o in 0..f_out {
            let mut acc = bias[o];
            for (kk, &hv) in hi.iter().enumerate() {
                acc += hv * wmat[kk * f_out + o];
            }
            dst[o] = acc.max(0.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::ops::pack::{pack_inputs, unpad_output, OpData};
    use crate::ops::reference;
    use crate::runtime::manifest::Manifest;
    use crate::util::rng::Rng;

    const TOL: f32 = 1e-4;

    fn random_graph(seed: u64, n: usize, max_deg: usize) -> Csr {
        let mut rng = Rng::new(seed);
        let rows = (0..n)
            .map(|_| {
                let d = rng.below(max_deg + 1);
                rng.sample_distinct(n, d)
                    .into_iter()
                    .map(|c| (c as u32, rng.next_f32() - 0.5))
                    .collect()
            })
            .collect();
        Csr::from_rows(n, rows)
    }

    fn dense(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f32() - 0.5).collect()
    }

    fn find_entry<'m>(
        m: &'m Manifest,
        g: &Csr,
        op: &str,
        variant: &str,
        f: Option<usize>,
    ) -> &'m ArtifactEntry {
        m.entries
            .iter()
            .filter(|e| e.op == op && e.variant == variant && !e.is_probe())
            .filter(|e| f.map_or(true, |f| e.param_usize("f") == Some(f)))
            .filter(|e| crate::scheduler::entry_fits(e, g))
            .min_by_key(|e| crate::scheduler::bucket_cost(e))
            .unwrap_or_else(|| panic!("no fitting synthetic entry {op}/{variant}"))
    }

    #[test]
    fn spmm_variants_match_oracle() {
        let m = Manifest::synthetic();
        let be = NativeBackend::new();
        let g = random_graph(11, 100, 10);
        let f = 32;
        let b = dense(1, 100 * f);
        let want = reference::spmm(&g, &b, f);
        for variant in ["baseline_scatter", "ell_gather", "ell_r8_f32", "ell_r32_f32", "hub_gather", "hub_r8_f32"] {
            let e = find_entry(&m, &g, "spmm", variant, Some(f));
            let data = OpData::new().with("b", b.clone());
            let inputs = pack_inputs(e, &g, &data).unwrap();
            let out = be.run_f32(e, &inputs).unwrap();
            let out = unpad_output(out, e.param_usize("n_pad").unwrap(), g.n_rows, f);
            let d = reference::max_abs_diff(&out, &want);
            assert!(d < TOL, "spmm {variant}: max diff {d}");
        }
    }

    #[test]
    fn spmm_wide_lane_matches_oracle() {
        let m = Manifest::synthetic();
        let be = NativeBackend::new();
        let g = random_graph(13, 80, 8);
        let f = 128;
        let b = dense(2, 80 * f);
        let want = reference::spmm(&g, &b, f);
        for variant in ["ell_r8_f128", "hub_r8_f128"] {
            let e = find_entry(&m, &g, "spmm", variant, Some(f));
            let data = OpData::new().with("b", b.clone());
            let inputs = pack_inputs(e, &g, &data).unwrap();
            let out = be.run_f32(e, &inputs).unwrap();
            let out = unpad_output(out, e.param_usize("n_pad").unwrap(), g.n_rows, f);
            let d = reference::max_abs_diff(&out, &want);
            assert!(d < TOL, "spmm {variant}: max diff {d}");
        }
    }

    #[test]
    fn unsupported_variant_is_error() {
        let m = Manifest::synthetic();
        let mut e = m.entries[0].clone();
        e.variant = "warp_shuffle".to_string();
        e.op = "spmm".to_string();
        assert!(classify(&e).is_err());
    }

    #[test]
    fn load_counts_and_signature() {
        let m = Manifest::synthetic();
        let be = NativeBackend::new();
        assert_eq!(be.compiled_count(), 0);
        be.load(&m.entries[0]).unwrap();
        be.load(&m.entries[0]).unwrap();
        assert_eq!(be.compiled_count(), 1);
        assert!(Backend::signature(&be).starts_with("native"));
    }
}
