//! Execution backends: the abstraction that makes the scheduler's
//! decisions portable.
//!
//! The paper's pipeline (estimate → micro-probe → guardrail → cache) is
//! backend-agnostic: it only needs something that can *execute* an
//! `ArtifactEntry`-shaped kernel on packed tensors and report timings
//! and a platform signature. The [`Backend`] trait captures exactly
//! that, with two implementations:
//!
//! * [`NativeBackend`] — every manifest variant family implemented in
//!   pure Rust with real tiling/mapping parameters (ELL row/feature
//!   tiles, hub split, COO scatter, fused attention). It synthesizes
//!   its own manifest, so the whole system runs end-to-end with no
//!   artifacts directory and no PJRT runtime.
//! * `PjrtBackend` (the `runtime::client::Device`, behind the `pjrt`
//!   cargo feature) — compiles and executes AOT HLO artifacts through a
//!   PJRT client, as in the original testbed.
//!
//! Selection: `AUTOSAGE_BACKEND=auto|native|pjrt` (see `config.rs`).
//! `auto` picks PJRT only when the build has the `pjrt` feature *and*
//! an artifacts manifest exists; otherwise native.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::runtime::Tensor;
use crate::scheduler::estimate::DeviceModel;
use crate::util::stats::TimingSummary;

pub use native::NativeBackend;

/// One kernel-execution engine. Object-safe: the coordinator owns a
/// `Box<dyn Backend>` and the scheduler probes through `&dyn Backend`.
pub trait Backend {
    /// Short backend id: `"native"` or `"pjrt"`.
    fn name(&self) -> &'static str;

    fn platform_name(&self) -> String;

    fn platform_version(&self) -> String;

    /// Device signature for cache keys (paper §4.2 `device_sig()`).
    /// Backends with different cost behaviour must never share cached
    /// schedule decisions; the signature includes the backend name.
    fn signature(&self) -> String {
        crate::graph::signature::device_signature(
            &self.platform_name(),
            &self.platform_version(),
        )
    }

    /// Compile / resolve an entry's kernel (lazy, cached per process).
    fn load(&self, entry: &ArtifactEntry) -> Result<()>;

    /// Upload, execute once, fetch the f32 output.
    fn run_f32(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>>;

    /// Upload once, then `warmup` untimed + up to `iters` timed
    /// execute+sync repetitions bounded by `cap_ms` (the probe / bench
    /// protocol, paper §6).
    fn time_entry(
        &self,
        entry: &ArtifactEntry,
        inputs: &[Tensor],
        warmup: usize,
        iters: usize,
        cap_ms: f64,
    ) -> Result<TimingSummary>;

    /// Whether row-tile ("grid") kernels execute at native cost on this
    /// backend. On the PJRT CPU testbed interpret-mode grids are
    /// correctness targets whose per-step emulation cost does not
    /// extrapolate, so they join the candidate space only with
    /// `AUTOSAGE_GRID=1`; the native backend's tiled kernels are real.
    fn executes_grid_kernels(&self) -> bool;

    /// Roofline constants the estimate should use for this backend.
    fn device_model(&self) -> DeviceModel;

    /// Total compile/warm-up time spent so far (telemetry, §8.6).
    fn total_compile_ms(&self) -> f64;

    /// Number of distinct entries compiled/resolved so far.
    fn compiled_count(&self) -> usize;
}

/// Resolved backend choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

/// Is the PJRT backend compiled into this binary?
pub fn pjrt_compiled() -> bool {
    cfg!(feature = "pjrt")
}

/// Resolve an `AUTOSAGE_BACKEND` / `--backend` choice string.
pub fn resolve_kind(choice: &str, artifacts_dir: &Path) -> Result<BackendKind> {
    match choice {
        "native" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt),
        "auto" | "" => {
            if pjrt_compiled() && artifacts_dir.join("manifest.json").exists() {
                Ok(BackendKind::Pjrt)
            } else {
                Ok(BackendKind::Native)
            }
        }
        other => bail!(
            "unknown backend {other:?} (valid: auto, native, pjrt)"
        ),
    }
}

/// Construct the chosen backend together with its manifest: PJRT loads
/// `<artifacts_dir>/manifest.json`; native synthesizes its catalog.
pub fn create(choice: &str, artifacts_dir: &Path) -> Result<(Box<dyn Backend>, Manifest)> {
    match resolve_kind(choice, artifacts_dir)? {
        BackendKind::Native => Ok((
            Box::new(NativeBackend::new()),
            Manifest::synthetic(),
        )),
        BackendKind::Pjrt => create_pjrt(artifacts_dir),
    }
}

#[cfg(feature = "pjrt")]
fn create_pjrt(artifacts_dir: &Path) -> Result<(Box<dyn Backend>, Manifest)> {
    let dev = crate::runtime::Device::cpu()?;
    let manifest = Manifest::load(artifacts_dir)?;
    Ok((Box::new(dev), manifest))
}

#[cfg(not(feature = "pjrt"))]
fn create_pjrt(_artifacts_dir: &Path) -> Result<(Box<dyn Backend>, Manifest)> {
    bail!(
        "backend \"pjrt\" requested but this binary was built without the \
         `pjrt` feature; rebuild with `cargo build --features pjrt` or use \
         AUTOSAGE_BACKEND=native"
    )
}

/// Describe every backend for the CLI (`autosage backends`): name,
/// availability, signature.
pub fn describe_backends(artifacts_dir: &Path) -> Vec<(String, String)> {
    let native = NativeBackend::new();
    vec![
        (
            "native".to_string(),
            format!(
                "available — signature {} (synthetic manifest, {} entries)",
                Backend::signature(&native),
                Manifest::synthetic().entries.len()
            ),
        ),
        ("pjrt".to_string(), describe_pjrt(artifacts_dir)),
    ]
}

#[cfg(feature = "pjrt")]
fn describe_pjrt(artifacts_dir: &Path) -> String {
    let manifest_note = if artifacts_dir.join("manifest.json").exists() {
        format!("artifacts at {}", artifacts_dir.display())
    } else {
        format!(
            "NO artifacts at {} (run `make artifacts`)",
            artifacts_dir.display()
        )
    };
    match crate::runtime::Device::cpu() {
        Ok(dev) => format!(
            "available — signature {} ({manifest_note})",
            dev.signature()
        ),
        Err(e) => format!("compiled but failed to initialize: {e:#}"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn describe_pjrt(_artifacts_dir: &Path) -> String {
    "unavailable (built without the `pjrt` cargo feature)".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn resolve_explicit_kinds() {
        let dir = PathBuf::from("/definitely/not/here");
        assert_eq!(resolve_kind("native", &dir).unwrap(), BackendKind::Native);
        assert_eq!(resolve_kind("pjrt", &dir).unwrap(), BackendKind::Pjrt);
        assert!(resolve_kind("cuda", &dir).is_err());
    }

    #[test]
    fn auto_without_artifacts_is_native() {
        let dir = PathBuf::from("/definitely/not/here");
        assert_eq!(resolve_kind("auto", &dir).unwrap(), BackendKind::Native);
        assert_eq!(resolve_kind("", &dir).unwrap(), BackendKind::Native);
    }

    #[test]
    fn create_native_yields_synthetic_manifest() {
        let dir = PathBuf::from("/definitely/not/here");
        let (backend, manifest) = create("native", &dir).unwrap();
        assert_eq!(backend.name(), "native");
        assert!(!manifest.entries.is_empty());
        assert!(backend.executes_grid_kernels());
    }

    #[test]
    fn describe_lists_both_backends() {
        let dir = PathBuf::from("/definitely/not/here");
        let d = describe_backends(&dir);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].0, "native");
        assert_eq!(d[1].0, "pjrt");
        assert!(d[0].1.contains("available"));
    }

    #[test]
    fn backend_signatures_distinguish_backends() {
        // Cached schedules must never leak across backends with
        // different cost behaviour.
        let native = NativeBackend::new();
        assert!(Backend::signature(&native).starts_with("native"));
    }
}
