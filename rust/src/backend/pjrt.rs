//! `Backend` implementation for the PJRT device (`runtime::client::Device`).
//!
//! This is the original execution path — compile-once AOT HLO artifacts
//! through a PJRT client — refactored behind the [`Backend`] trait so
//! the scheduler, facade, queue and bench harness no longer care which
//! engine runs the kernels. Only compiled with the `pjrt` cargo feature.

use anyhow::Result;

use crate::runtime::manifest::ArtifactEntry;
use crate::runtime::{Device, Tensor};
use crate::scheduler::estimate::DeviceModel;
use crate::util::stats::TimingSummary;
use crate::util::timing::time_fn;

use super::Backend;

impl Backend for Device {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform_name(&self) -> String {
        Device::platform_name(self)
    }

    fn platform_version(&self) -> String {
        Device::platform_version(self)
    }

    fn signature(&self) -> String {
        Device::signature(self)
    }

    fn load(&self, entry: &ArtifactEntry) -> Result<()> {
        Device::load(self, entry).map(|_| ())
    }

    fn run_f32(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>> {
        Device::run_f32(self, entry, inputs)
    }

    /// Upload once, then timed execute+sync iterations — mirrors
    /// CUDA-event kernel timing as closely as the PJRT client allows.
    fn time_entry(
        &self,
        entry: &ArtifactEntry,
        inputs: &[Tensor],
        warmup: usize,
        iters: usize,
        cap_ms: f64,
    ) -> Result<TimingSummary> {
        let exe = Device::load(self, entry)?;
        let bufs = self.upload(entry, inputs)?;
        let mut err: Option<anyhow::Error> = None;
        let summary = time_fn(
            || {
                if err.is_some() {
                    return;
                }
                match self.execute_buffers(&exe, &bufs) {
                    Ok(out) => {
                        if let Err(e) = self.sync(&out) {
                            err = Some(e);
                        }
                    }
                    Err(e) => err = Some(e),
                }
            },
            warmup,
            iters,
            cap_ms,
        );
        match err {
            Some(e) => Err(e),
            None => Ok(summary),
        }
    }

    fn executes_grid_kernels(&self) -> bool {
        // Interpret-mode Pallas grids on the PJRT CPU client are
        // correctness targets, not performance kernels; they join the
        // candidate space only via AUTOSAGE_GRID=1.
        false
    }

    fn device_model(&self) -> DeviceModel {
        DeviceModel::default()
    }

    fn total_compile_ms(&self) -> f64 {
        Device::total_compile_ms(self)
    }

    fn compiled_count(&self) -> usize {
        Device::compiled_count(self)
    }
}
