//! Packing: turn a CSR graph + dense features into the exact input
//! tensors an artifact expects, driven by the artifact's `InputSpec`s.
//!
//! This is where bucketing happens: the graph is padded to the entry's
//! static shapes (ELL width, COO length, hub block), reusing the
//! encoders in [`crate::graph::ell`].

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::ell::{CooBuffers, EllBuffers, HubSplit};
use crate::graph::Csr;
use crate::runtime::manifest::ArtifactEntry;
use crate::runtime::tensor::Tensor;

/// Dense operands for one op invocation, keyed by artifact input name
/// (`b`, `x`, `y`, `q`, `k`, `v`, `h`, `w`, `bias`).
///
/// Features are supplied at the *graph* size; packing pads rows with
/// zeros up to the bucket's `n_pad`.
#[derive(Debug, Clone, Default)]
pub struct OpData {
    pub dense: HashMap<String, Vec<f32>>,
}

impl OpData {
    pub fn new() -> OpData {
        OpData { dense: HashMap::new() }
    }
    pub fn with(mut self, name: &str, data: Vec<f32>) -> OpData {
        self.dense.insert(name.to_string(), data);
        self
    }
}

/// Pad a row-major `[rows, f]` feature matrix with zero rows to `n_pad`.
fn pad_rows(data: &[f32], f: usize, n_pad: usize) -> Result<Vec<f32>> {
    if f == 0 || data.len() % f != 0 {
        bail!("feature matrix length {} not divisible by f={}", data.len(), f);
    }
    let rows = data.len() / f;
    if rows > n_pad {
        bail!("feature rows {rows} exceed bucket n_pad {n_pad}");
    }
    let mut out = vec![0.0f32; n_pad * f];
    out[..data.len()].copy_from_slice(data);
    Ok(out)
}

/// Pack inputs for `entry` from graph `g` and dense operands `data`.
///
/// The returned tensors are in the artifact's declared call order and
/// already shape-checked. The sparse encodings are derived per the
/// entry's variant:
/// * `baseline_scatter`  → COO (row/col/val)
/// * `ell_*` / softmax   → plain ELL at the entry's width
/// * `hub_*`             → hub split at `hub_t = w_light`
/// * attention baseline  → ELL + COO of the same pattern
pub fn pack_inputs(entry: &ArtifactEntry, g: &Csr, data: &OpData) -> Result<Vec<Tensor>> {
    let n_pad = entry
        .param_usize("n_pad")
        .ok_or_else(|| anyhow!("{}: missing n_pad", entry.name))?;

    // Build the sparse encodings this entry needs, lazily.
    let mut ell: Option<EllBuffers> = None;
    let mut coo: Option<CooBuffers> = None;
    let mut hub: Option<HubSplit> = None;

    let need = |name: &str| entry.inputs.iter().any(|i| i.name == name);

    if need("colind") || need("mask") || (need("val") && !need("row")) {
        let w = entry
            .param_usize("w")
            .ok_or_else(|| anyhow!("{}: missing w", entry.name))?;
        ell = Some(
            EllBuffers::from_csr(g, n_pad, w)
                .map_err(|e| anyhow!("{}: {e}", entry.name))?,
        );
    }
    if need("row") {
        let nnz_pad = entry
            .param_usize("nnz_pad")
            .ok_or_else(|| anyhow!("{}: missing nnz_pad", entry.name))?;
        coo = Some(
            CooBuffers::from_csr(g, nnz_pad)
                .map_err(|e| anyhow!("{}: {e}", entry.name))?,
        );
    }
    if need("hub_rows") {
        let w_light = entry
            .param_usize("w_light")
            .ok_or_else(|| anyhow!("{}: missing w_light", entry.name))?;
        let h_pad = entry
            .param_usize("h_pad")
            .ok_or_else(|| anyhow!("{}: missing h_pad", entry.name))?;
        let w_hub = entry
            .param_usize("w_hub")
            .ok_or_else(|| anyhow!("{}: missing w_hub", entry.name))?;
        // Rows that do not fit the light width go to the hub block.
        hub = Some(
            HubSplit::from_csr(g, w_light, n_pad, w_light, h_pad, w_hub)
                .map_err(|e| anyhow!("{}: {e}", entry.name))?,
        );
    }

    // The built encodings are moved (not cloned) into tensors — each
    // field is consumed by exactly one input, and on multi-MB buckets
    // the saved memcpys dominate the pack cost (EXPERIMENTS §Perf L3-2).
    let mut out = Vec::with_capacity(entry.inputs.len());
    for spec in &entry.inputs {
        let t = match spec.name.as_str() {
            "colind" => {
                let e = ell.as_mut().unwrap();
                Tensor::i32(std::mem::take(&mut e.colind), vec![e.n_pad, e.w])
            }
            "mask" => {
                let e = ell.as_mut().unwrap();
                Tensor::f32(std::mem::take(&mut e.mask), vec![e.n_pad, e.w])
            }
            "val" if coo.is_some() => {
                let c = coo.as_mut().unwrap();
                Tensor::f32(std::mem::take(&mut c.val), vec![c.nnz_pad])
            }
            "val" => {
                let e = ell.as_mut().unwrap();
                // softmax consumes externally-supplied ELL values when
                // present in `data` (attention pipeline); else edge vals.
                match data.dense.get("val") {
                    Some(v) if v.len() == e.n_pad * e.w => {
                        Tensor::f32(v.clone(), vec![e.n_pad, e.w])
                    }
                    Some(_) => bail!("{}: supplied val has wrong size", entry.name),
                    None => Tensor::f32(std::mem::take(&mut e.val), vec![e.n_pad, e.w]),
                }
            }
            "row" => {
                let c = coo.as_mut().unwrap();
                Tensor::i32(std::mem::take(&mut c.row), vec![c.nnz_pad])
            }
            "col" => {
                let c = coo.as_mut().unwrap();
                Tensor::i32(std::mem::take(&mut c.col), vec![c.nnz_pad])
            }
            "light_colind" => {
                let h = hub.as_mut().unwrap();
                let (n_pad, w) = (h.light.n_pad, h.light.w);
                Tensor::i32(std::mem::take(&mut h.light.colind), vec![n_pad, w])
            }
            "light_val" => {
                let h = hub.as_mut().unwrap();
                let (n_pad, w) = (h.light.n_pad, h.light.w);
                Tensor::f32(std::mem::take(&mut h.light.val), vec![n_pad, w])
            }
            "hub_rows" => {
                let h = hub.as_mut().unwrap();
                let n = h.hub_rows.len();
                Tensor::i32(std::mem::take(&mut h.hub_rows), vec![n])
            }
            "hub_colind" => {
                let h = hub.as_mut().unwrap();
                let h_pad = entry.param_usize("h_pad").unwrap_or(1);
                let w_hub = h.hub_colind.len() / h_pad.max(1);
                Tensor::i32(std::mem::take(&mut h.hub_colind), vec![h_pad, w_hub])
            }
            "hub_val" => {
                let h = hub.as_mut().unwrap();
                let h_pad = entry.param_usize("h_pad").unwrap_or(1);
                let w_hub = h.hub_val.len() / h_pad.max(1);
                Tensor::f32(std::mem::take(&mut h.hub_val), vec![h_pad, w_hub])
            }
            // Dense operands, padded to the bucket's row count.
            dense_name => {
                let raw = data.dense.get(dense_name).ok_or_else(|| {
                    anyhow!("{}: missing dense operand {dense_name:?}", entry.name)
                })?;
                if spec.shape.len() == 2 && spec.shape[0] == n_pad {
                    let f = spec.shape[1];
                    Tensor::f32(pad_rows(raw, f, n_pad)?, vec![n_pad, f])
                } else {
                    // Exact-shape operands (weights, bias).
                    Tensor::f32(raw.clone(), spec.shape.clone())
                }
            }
        };
        t.check_spec(spec)
            .map_err(|e| anyhow!("{}: {e}", entry.name))?;
        out.push(t);
    }
    Ok(out)
}

/// Slice an artifact's padded `[n_pad, f]` output back to `[n_rows, f]`.
pub fn unpad_output(out: Vec<f32>, n_pad: usize, n_rows: usize, f: usize) -> Vec<f32> {
    assert_eq!(out.len(), n_pad * f);
    let mut v = out;
    v.truncate(n_rows * f);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{InputSpec, Manifest};
    use std::path::Path;

    fn spmm_ell_entry() -> ArtifactEntry {
        let m = Manifest::parse(
            Path::new("/x"),
            r#"{"entries":[{"name":"e","op":"spmm","variant":"ell_r8_f32",
              "params":{"n_pad":8,"w":4,"f":2,"r":8,"ft":2},
              "path":"e.hlo.txt",
              "inputs":[
                {"name":"colind","dtype":"s32","shape":[8,4]},
                {"name":"val","dtype":"f32","shape":[8,4]},
                {"name":"b","dtype":"f32","shape":[8,2]}]}]}"#,
        )
        .unwrap();
        m.entries[0].clone()
    }

    fn tiny_graph() -> Csr {
        Csr::from_rows(3, vec![vec![(1, 2.0)], vec![(0, 3.0), (2, 4.0)], vec![]])
    }

    #[test]
    fn pack_ell_spmm() {
        let g = tiny_graph();
        let data = OpData::new().with("b", vec![1.0; 6]); // 3 rows x f=2
        let ts = pack_inputs(&spmm_ell_entry(), &g, &data).unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].shape(), &[8, 4]);
        // b padded from 3 rows to 8
        assert_eq!(ts[2].shape(), &[8, 2]);
        if let Tensor::F32 { data, .. } = &ts[2] {
            assert_eq!(&data[..6], &[1.0; 6]);
            assert!(data[6..].iter().all(|&x| x == 0.0));
        } else {
            panic!()
        }
    }

    #[test]
    fn pack_missing_dense_errors() {
        let g = tiny_graph();
        let err = pack_inputs(&spmm_ell_entry(), &g, &OpData::new());
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("b"));
    }

    #[test]
    fn pack_graph_too_big_errors() {
        let g = Csr::from_rows(
            9,
            (0..9).map(|i| vec![((i as u32 + 1) % 9, 1.0f32)]).collect(),
        );
        let data = OpData::new().with("b", vec![0.0; 18]);
        assert!(pack_inputs(&spmm_ell_entry(), &g, &data).is_err());
    }

    #[test]
    fn unpad_slices() {
        let out = unpad_output(vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0], 3, 2, 2);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_rows_rejects_ragged() {
        assert!(pad_rows(&[1.0, 2.0, 3.0], 2, 4).is_err());
    }
}
