//! Pure-Rust reference implementations over CSR — the Rust-side oracle
//! (mirror of `python/compile/kernels/ref.py`). Every artifact's output
//! is checked against these in the integration tests, which closes the
//! loop: Pallas kernel ≡ jnp ref (pytest) ≡ Rust oracle (cargo test).

use crate::graph::Csr;

/// C = A @ B. `b` is row-major `[n, f]`; returns row-major `[n_rows, f]`.
pub fn spmm(g: &Csr, b: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(b.len() % f, 0);
    let n_b = b.len() / f;
    let mut out = vec![0.0f32; g.n_rows * f];
    for i in 0..g.n_rows {
        let (cols, vals) = g.row(i);
        let dst = &mut out[i * f..(i + 1) * f];
        for (&c, &v) in cols.iter().zip(vals) {
            let c = c as usize;
            assert!(c < n_b, "col {c} out of bounds for B with {n_b} rows");
            let src = &b[c * f..(c + 1) * f];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += v * s;
            }
        }
    }
    out
}

/// SDDMM: for each stored (i, j), `<x_i, y_j>`; returned in CSR slot
/// order (row-major by (row, slot)), matching `CooBuffers` layout.
pub fn sddmm(g: &Csr, x: &[f32], y: &[f32], f: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(g.nnz());
    for i in 0..g.n_rows {
        let (cols, _) = g.row(i);
        let xi = &x[i * f..(i + 1) * f];
        for &c in cols {
            let yj = &y[c as usize * f..(c as usize + 1) * f];
            out.push(xi.iter().zip(yj).map(|(a, b)| a * b).sum());
        }
    }
    out
}

/// Numerically-stable masked row softmax over CSR values (slot order).
pub fn softmax_rows(g: &Csr, scores: &[f32]) -> Vec<f32> {
    assert_eq!(scores.len(), g.nnz());
    let mut out = vec![0.0f32; scores.len()];
    for i in 0..g.n_rows {
        let (a, b) = (g.rowptr[i], g.rowptr[i + 1]);
        if a == b {
            continue;
        }
        let row = &scores[a..b];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (k, &s) in row.iter().enumerate() {
            let e = (s - mx).exp();
            out[a + k] = e;
            sum += e;
        }
        for v in &mut out[a..b] {
            *v /= sum.max(1e-30);
        }
    }
    out
}

/// CSR attention: SDDMM(Q, K) → row-softmax → SpMM(attn, V).
pub fn csr_attention(g: &Csr, q: &[f32], k: &[f32], v: &[f32], f: usize) -> Vec<f32> {
    let scores = sddmm(g, q, k, f);
    let attn = softmax_rows(g, &scores);
    let mut weighted = g.clone();
    weighted.val = attn;
    spmm(&weighted, v, f)
}

/// GCN aggregation layer for the E2E example:
/// `relu((A @ H) W + bias)`, all dense math in Rust for the oracle.
pub fn gcn_layer(
    g: &Csr,
    h: &[f32],
    f_in: usize,
    w: &[f32],
    f_out: usize,
    bias: &[f32],
) -> Vec<f32> {
    let agg = spmm(g, h, f_in); // [n, f_in]
    let mut out = vec![0.0f32; g.n_rows * f_out];
    for i in 0..g.n_rows {
        for o in 0..f_out {
            let mut acc = bias[o];
            for k in 0..f_in {
                acc += agg[i * f_in + k] * w[k * f_out + o];
            }
            out[i * f_out + o] = acc.max(0.0);
        }
    }
    out
}

/// Max |a - b| — the comparison metric used by integration tests.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    fn g() -> Csr {
        // A = [[0,2],[3,0]]
        Csr::from_rows(2, vec![vec![(1, 2.0)], vec![(0, 3.0)]])
    }

    #[test]
    fn spmm_hand_computed() {
        // B = [[1,10],[2,20]]; A@B = [[4,40],[3,30]]
        let b = [1.0, 10.0, 2.0, 20.0];
        assert_eq!(spmm(&g(), &b, 2), vec![4.0, 40.0, 3.0, 30.0]);
    }

    #[test]
    fn sddmm_hand_computed() {
        // x = [[1,0],[0,1]], y = [[2,3],[4,5]]
        // edges: (0,1) -> <x0,y1> = 4 ; (1,0) -> <x1,y0> = 3
        let x = [1.0, 0.0, 0.0, 1.0];
        let y = [2.0, 3.0, 4.0, 5.0];
        assert_eq!(sddmm(&g(), &x, &y, 2), vec![4.0, 3.0]);
    }

    #[test]
    fn softmax_rows_sum_one_and_empty_rows_zero() {
        let g3 = Csr::from_rows(
            3,
            vec![
                vec![(0, 1.0), (1, 1.0), (2, 1.0)],
                vec![],
                vec![(0, 1.0), (2, 1.0)],
            ],
        );
        let scores = [1.0, 2.0, 3.0, -5.0, 5.0];
        let sm = softmax_rows(&g3, &scores);
        let s0: f32 = sm[0..3].iter().sum();
        let s2: f32 = sm[3..5].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6);
        assert!((s2 - 1.0).abs() < 1e-6);
        assert!(sm[2] > sm[1] && sm[1] > sm[0]); // monotone in score
    }

    #[test]
    fn softmax_stable_for_huge_scores() {
        let g1 = Csr::from_rows(2, vec![vec![(0, 1.0), (1, 1.0)]]);
        let sm = softmax_rows(&g1, &[1e30f32, 1e30]);
        assert!(sm.iter().all(|v| v.is_finite()));
        assert!((sm[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn attention_convexity() {
        let g2 = Csr::from_rows(
            4,
            vec![
                vec![(1, 1.0), (2, 1.0)],
                vec![(0, 1.0)],
                vec![(3, 1.0), (0, 1.0)],
                vec![(2, 1.0)],
            ],
        );
        let f = 3;
        let q: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let k: Vec<f32> = (0..12).map(|i| (i as f32).cos()).collect();
        let v: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let out = csr_attention(&g2, &q, &k, &v, f);
        let (lo, hi) = v.iter().fold((f32::MAX, f32::MIN), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
        assert!(out.iter().all(|&x| x >= lo - 1e-4 && x <= hi + 1e-4));
    }

    #[test]
    fn gcn_layer_relu_and_shapes() {
        let h = [1.0, -1.0, 2.0, 0.5];
        let w = [1.0, 0.0, 0.0, -1.0];
        let bias = [0.0, 0.0];
        let out = gcn_layer(&g(), &h, 2, &w, 2, &bias);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|&x| x >= 0.0)); // relu
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }
}
