//! Typed operators over the runtime: SpMM, SDDMM, row-softmax and the
//! CSR attention pipeline, plus the pure-Rust reference oracle used by
//! integration tests and as a CPU comparison point.

pub mod pack;
pub mod reference;

pub use pack::{pack_inputs, OpData};
