//! Legacy service-style request queue, now a thin compatibility
//! wrapper over the concurrent serving pool (`server::ServerPool`).
//!
//! Historically this module owned a single worker thread draining an
//! unbounded mpsc channel. The serving subsystem replaced that with a
//! sharded pool + shared single-flight schedule cache + bounded queues;
//! `ServiceHandle` keeps the old API (spawn → submit/call, one worker,
//! blocking submission) so existing tests and examples keep passing,
//! while routing everything through the pool. Worker panics are
//! surfaced on drop by the pool's shutdown path instead of being
//! silently discarded.

use std::path::PathBuf;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::graph::Csr;
use crate::scheduler::Op;
use crate::server::{ServeResponse, ServerPool};

/// Operator result + the decision that produced it (the pool's richer
/// response type; legacy callers read `result`/`variant`/`from_cache`).
pub type OpResponse = ServeResponse;

/// Handle to the running service: a 1-worker serving pool.
pub struct ServiceHandle {
    pool: Option<ServerPool>,
    init_err: Option<String>,
}

impl ServiceHandle {
    /// Spawn the worker; the backend + manifest are constructed on the
    /// worker thread (PJRT is thread-bound; native doesn't care). The
    /// worker count is pinned to 1 for the legacy single-device shape —
    /// use `server::ServerPool` directly for the sharded pool.
    pub fn spawn(artifacts_dir: PathBuf, mut cfg: Config) -> ServiceHandle {
        cfg.serve_workers = 1;
        match ServerPool::spawn(artifacts_dir, cfg) {
            Ok(pool) => ServiceHandle { pool: Some(pool), init_err: None },
            Err(e) => ServiceHandle {
                pool: None,
                init_err: Some(format!("service init failed: {e:#}")),
            },
        }
    }

    /// Submit a request; returns the receiver for its response. Blocks
    /// for queue room instead of rejecting (legacy unbounded-queue
    /// semantics).
    pub fn submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<mpsc::Receiver<OpResponse>> {
        match &self.pool {
            Some(pool) => pool
                .submit(op, graph, f, operands)
                .map_err(|e| anyhow!("service submit failed: {e}")),
            None => Err(anyhow!(
                "{}",
                self.init_err.as_deref().unwrap_or("service init failed")
            )),
        }
    }

    /// Convenience: submit and wait.
    pub fn call(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<OpResponse> {
        let rx = self.submit(op, graph, f, operands)?;
        rx.recv().map_err(|_| anyhow!("service dropped the request"))
    }
}
