//! Service-style request queue: one worker thread owns the execution
//! backend (PJRT handles are not `Send`; the native backend simply
//! lives where it was built) and drains an mpsc channel of operator
//! requests; callers get results over per-request response channels.
//!
//! This is the deployment shape a GNN-training host integrates with: the
//! aggregation service amortizes probe cost across requests because all
//! requests against the same (graph, F, op) hit the schedule cache after
//! the first.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::graph::Csr;
use crate::scheduler::Op;

use super::facade::AutoSage;

/// One operator request. Dense operands are in the same layout the
/// facade takes (`[n_rows, f]` row-major).
pub struct OpRequest {
    pub op: Op,
    pub graph: Csr,
    pub f: usize,
    pub operands: Vec<(String, Vec<f32>)>,
    pub respond: mpsc::Sender<OpResponse>,
}

/// Operator result + the decision that produced it.
pub struct OpResponse {
    pub result: Result<Vec<f32>>,
    pub variant: String,
    pub from_cache: bool,
}

/// Handle to the running service.
pub struct ServiceHandle {
    tx: mpsc::Sender<OpRequest>,
    join: Option<JoinHandle<()>>,
}

impl ServiceHandle {
    /// Spawn the worker; the backend + manifest are constructed on the
    /// worker thread (PJRT is thread-bound; native doesn't care).
    pub fn spawn(artifacts_dir: PathBuf, cfg: Config) -> ServiceHandle {
        let (tx, rx) = mpsc::channel::<OpRequest>();
        let join = std::thread::spawn(move || {
            let mut sage = match AutoSage::new(&artifacts_dir, cfg, None) {
                Ok(s) => s,
                Err(e) => {
                    // Fail every request with the construction error.
                    for req in rx {
                        let _ = req.respond.send(OpResponse {
                            result: Err(anyhow!("service init failed: {e:#}")),
                            variant: String::new(),
                            from_cache: false,
                        });
                    }
                    return;
                }
            };
            for req in rx {
                let resp = serve_one(&mut sage, &req);
                let _ = req.respond.send(resp);
            }
        });
        ServiceHandle { tx, join: Some(join) }
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<mpsc::Receiver<OpResponse>> {
        let (respond, rx) = mpsc::channel();
        self.tx
            .send(OpRequest { op, graph, f, operands, respond })
            .map_err(|_| anyhow!("service thread terminated"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn call(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<OpResponse> {
        let rx = self.submit(op, graph, f, operands)?;
        rx.recv().map_err(|_| anyhow!("service dropped the request"))
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        // Close the channel, then join the worker.
        let (tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_one(sage: &mut AutoSage, req: &OpRequest) -> OpResponse {
    let get = |name: &str| -> Result<&Vec<f32>> {
        req.operands
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("request missing operand {name:?}"))
    };
    let decision = match sage.decide(&req.graph, req.op, req.f) {
        Ok(d) => d,
        Err(e) => {
            return OpResponse {
                result: Err(e),
                variant: String::new(),
                from_cache: false,
            }
        }
    };
    let variant = decision.choice.variant().to_string();
    let from_cache =
        decision.source == crate::scheduler::DecisionSource::Cache;
    let result = (|| -> Result<Vec<f32>> {
        match req.op {
            Op::Spmm => sage.spmm_with(&req.graph, get("b")?, req.f, &variant),
            Op::Sddmm => {
                sage.sddmm_with(&req.graph, get("x")?, get("y")?, req.f, &variant)
            }
            Op::Softmax => sage.softmax_with(&req.graph, get("val")?, &variant),
            Op::Attention => sage.attention_with(
                &req.graph,
                get("q")?,
                get("k")?,
                get("v")?,
                req.f,
                &variant,
            ),
        }
    })();
    OpResponse { result, variant, from_cache }
}
