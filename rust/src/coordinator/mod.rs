//! The coordinator: AutoSAGE's public facade (the paper's
//! `autosage::spmm_csr` / `sddmm_csr` / `csr_attention_forward`
//! bindings) plus the legacy single-worker service queue, now a
//! compatibility wrapper over the `server` pool.

pub mod facade;
pub mod queue;

pub use facade::AutoSage;
pub use queue::{OpResponse, ServiceHandle};
