//! The coordinator: AutoSAGE's public facade (the paper's
//! `autosage::spmm_csr` / `sddmm_csr` / `csr_attention_forward`
//! bindings) plus a single-device request queue for service-style use.

pub mod facade;
pub mod queue;

pub use facade::AutoSage;
pub use queue::{OpRequest, OpResponse, ServiceHandle};
