//! `AutoSage`: one execution backend + one kernel manifest + the
//! scheduler + telemetry, exposed as typed operators.
//!
//! Every `*_auto` call runs the full paper pipeline: cache lookup →
//! (estimate → micro-probe → guardrail) → execute the chosen kernel.
//! `*_with` variants bypass scheduling for ablations and benches.
//!
//! The backend is chosen by `Config::backend` (`AUTOSAGE_BACKEND`):
//! the pure-Rust `NativeBackend` needs no artifacts; the PJRT backend
//! (feature `pjrt`) loads the AOT catalog from `artifacts_dir`.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::backend::{self, Backend};
use crate::config::Config;
use crate::graph::Csr;
use crate::ops::pack::{pack_inputs, unpad_output, OpData};
use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::scheduler::{probe, Decision, Op, Scheduler};
use crate::telemetry::Telemetry;
use crate::util::stats::TimingSummary;

pub struct AutoSage {
    pub backend: Box<dyn Backend>,
    pub manifest: Manifest,
    pub scheduler: Scheduler,
    pub telemetry: Telemetry,
}

impl AutoSage {
    /// Stand up the system. `artifacts_dir` only matters for the PJRT
    /// backend; the native backend synthesizes its manifest.
    pub fn new(artifacts_dir: &Path, cfg: Config, telemetry_dir: Option<&Path>) -> Result<AutoSage> {
        let (backend, manifest) = backend::create(&cfg.backend, artifacts_dir)?;
        let telemetry = Telemetry::new(telemetry_dir, &backend.signature());
        let mut scheduler = Scheduler::new(cfg)?;
        // The roofline estimate must model the engine that will actually
        // run the kernels (grid-step cost differs radically between
        // interpret-mode PJRT and native tiled loops).
        scheduler.dev_model = backend.device_model();
        Ok(AutoSage { backend, manifest, scheduler, telemetry })
    }

    pub fn config(&self) -> &Config {
        &self.scheduler.cfg
    }

    /// Attach (or detach) a flight recorder: subsequent `decide` calls
    /// emit estimate/probe/guardrail spans and cache hit/miss events.
    pub fn set_recorder(&mut self, r: Option<std::sync::Arc<crate::obs::trace::Recorder>>) {
        self.scheduler.tracer = r;
    }

    /// Set the (trace, parent span) the next `decide` call belongs to.
    pub fn set_trace_ctx(
        &mut self,
        ctx: Option<(crate::obs::trace::TraceId, crate::obs::trace::SpanId)>,
    ) {
        self.scheduler.trace_ctx = ctx;
    }

    /// Attach (or detach) the unified metrics registry: subsequent
    /// `decide` calls count decision outcomes (source, variant, probes,
    /// guardrail fallbacks) into it.
    pub fn set_metrics(
        &mut self,
        m: Option<std::sync::Arc<crate::obs::metrics::MetricsRegistry>>,
    ) {
        self.scheduler.metrics = m;
    }

    /// Attach (or detach) a trained cost model: subsequent `decide`
    /// calls predict cold keys first and probe only below the
    /// confidence threshold. The serve pool loads one model and shares
    /// it read-only across every shard through this setter.
    pub fn set_model(&mut self, m: Option<std::sync::Arc<crate::model::CostModel>>) {
        self.scheduler.model = m;
    }

    /// Whether a trained cost model is attached.
    pub fn has_model(&self) -> bool {
        self.scheduler.model.is_some()
    }

    /// Roofline-predicted execution time in milliseconds of `variant`
    /// on `g` — the "predicted" side of the estimate-accuracy audit
    /// (`audit.jsonl`). `None` when no fitting full-size artifact
    /// exists or the device model cannot score it.
    pub fn estimate_ms(&self, g: &Csr, op: Op, f: usize, variant: &str) -> Option<f64> {
        let entry = self
            .scheduler
            .select_entry(&self.manifest, g, op, f, variant)
            .ok()?;
        let feats = crate::scheduler::InputFeatures::extract(g, f);
        crate::scheduler::estimate::estimate_entry(entry, &feats, &self.scheduler.dev_model)
            .map(|e| e.score * 1e3)
    }

    /// Short id of the active backend ("native" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Device signature of the active backend (cache-key component).
    pub fn backend_signature(&self) -> String {
        self.backend.signature()
    }

    /// Resolve a graph spec — a preset name or `file:PATH` (`.asg`,
    /// `.mtx`, edge list) — through the data subsystem, so facade
    /// callers accept loader-backed graphs everywhere presets work.
    pub fn graph_from_spec(&self, spec: &str, seed: u64) -> Result<Csr> {
        Ok(crate::data::load_graph_spec(spec, seed)?.0)
    }

    /// Schedule an op for a graph (cache → probe → guardrail), with
    /// telemetry. Returns the decision (see paper §4.2).
    pub fn decide(&mut self, g: &Csr, op: Op, f: usize) -> Result<Decision> {
        let (decision, report) =
            self.scheduler
                .decide(self.backend.as_ref(), &self.manifest, g, op, f)?;
        if let Some(rep) = &report {
            self.telemetry.probe_sample(
                op.as_str(),
                f,
                "baseline",
                rep.baseline.timing.median_ms,
            );
            for c in &rep.candidates {
                self.telemetry
                    .probe_sample(op.as_str(), f, &c.variant, c.timing.median_ms);
            }
        }
        self.telemetry.decision(&decision);
        Ok(decision)
    }

    // ------------------------------------------------------------ SpMM

    /// `C = A @ B` with the scheduler choosing the kernel.
    pub fn spmm_auto(&mut self, g: &Csr, b: &[f32], f: usize) -> Result<Vec<f32>> {
        let d = self.decide(g, Op::Spmm, f)?;
        self.spmm_with(g, b, f, d.choice.variant())
    }

    /// `C = A @ B` with an explicit variant ("baseline" for vendor path).
    pub fn spmm_with(&mut self, g: &Csr, b: &[f32], f: usize, variant: &str) -> Result<Vec<f32>> {
        let entry =
            self.scheduler
                .select_entry(&self.manifest, g, Op::Spmm, f, variant)?;
        let data = OpData::new().with("b", b.to_vec());
        let n_pad = entry.require_usize("n_pad")?;
        let out = self.run_entry(entry, g, &data)?;
        Ok(unpad_output(out, n_pad, g.n_rows, f))
    }

    // ----------------------------------------------------------- SDDMM

    /// SDDMM: `out[e] = <x_i, y_j>` for each stored edge e=(i,j), in CSR
    /// slot order.
    pub fn sddmm_auto(&mut self, g: &Csr, x: &[f32], y: &[f32], f: usize) -> Result<Vec<f32>> {
        let d = self.decide(g, Op::Sddmm, f)?;
        self.sddmm_with(g, x, y, f, d.choice.variant())
    }

    pub fn sddmm_with(&mut self, g: &Csr, x: &[f32], y: &[f32], f: usize, variant: &str) -> Result<Vec<f32>> {
        let entry =
            self.scheduler
                .select_entry(&self.manifest, g, Op::Sddmm, f, variant)?;
        let data = OpData::new().with("x", x.to_vec()).with("y", y.to_vec());
        let w = entry.require_usize("w")?;
        let out = self.run_entry(entry, g, &data)?;
        Ok(ell_slots_to_csr(g, w, &out))
    }

    // --------------------------------------------------------- softmax

    /// Numerically-stable row softmax over CSR slot-order scores.
    pub fn softmax_with(&mut self, g: &Csr, scores: &[f32], variant: &str) -> Result<Vec<f32>> {
        let entry =
            self.scheduler
                .select_entry(&self.manifest, g, Op::Softmax, 0, variant)?;
        let w = entry.require_usize("w")?;
        let n_pad = entry.require_usize("n_pad")?;
        let data = OpData::new().with("val", csr_slots_to_ell(g, n_pad, w, scores)?);
        let out = self.run_entry(entry, g, &data)?;
        Ok(ell_slots_to_csr(g, w, &out))
    }

    // ------------------------------------------------------- attention

    /// CSR attention forward (paper §8.7): per-sub-op scheduling is done
    /// by the fused/baseline artifact choice.
    pub fn attention_auto(&mut self, g: &Csr, q: &[f32], k: &[f32], v: &[f32], f: usize) -> Result<Vec<f32>> {
        let d = self.decide(g, Op::Attention, f)?;
        self.attention_with(g, q, k, v, f, d.choice.variant())
    }

    pub fn attention_with(&mut self, g: &Csr, q: &[f32], k: &[f32], v: &[f32], f: usize, variant: &str) -> Result<Vec<f32>> {
        let entry = self.scheduler.select_entry(
            &self.manifest,
            g,
            Op::Attention,
            f,
            variant,
        )?;
        let data = OpData::new()
            .with("q", q.to_vec())
            .with("k", k.to_vec())
            .with("v", v.to_vec());
        let n_pad = entry.require_usize("n_pad")?;
        let out = self.run_entry(entry, g, &data)?;
        Ok(unpad_output(out, n_pad, g.n_rows, f))
    }

    // ----------------------------------------------- dense E2E helper

    /// `relu(H @ W + bias)` via the dense artifact (GCN example).
    pub fn linear_relu(&mut self, h: &[f32], n_rows: usize, f_in: usize, w: &[f32], f_out: usize, bias: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .entries
            .iter()
            .filter(|e| {
                e.op == "linear_relu"
                    && e.param_usize("f_in") == Some(f_in)
                    && e.param_usize("f_out") == Some(f_out)
                    && e.param_usize("n_pad").map_or(false, |n| n >= n_rows)
            })
            .min_by_key(|e| e.param_usize("n_pad").unwrap_or(usize::MAX))
            .ok_or_else(|| anyhow!("no linear_relu artifact {f_in}x{f_out}"))?
            .clone();
        let n_pad = entry.require_usize("n_pad")?;
        let mut hp = h.to_vec();
        hp.resize(n_pad * f_in, 0.0);
        let data = OpData::new()
            .with("h", hp)
            .with("w", w.to_vec())
            .with("bias", bias.to_vec());
        // linear_relu has no sparse inputs; pack against an empty graph.
        let empty = Csr::from_rows(1, vec![vec![]]);
        let inputs = pack_inputs(&entry, &empty, &data)?;
        let out = self.backend.run_f32(&entry, &inputs)?;
        Ok(unpad_output(out, n_pad, n_rows, f_out))
    }

    // ----------------------------------------------------- bench hooks

    /// Median full-graph latency of (op, variant) — the quantity the
    /// paper's tables report per row.
    pub fn time_op(&mut self, g: &Csr, op: Op, f: usize, variant: &str, iters: usize, cap_ms: f64) -> Result<TimingSummary> {
        let entry = self
            .scheduler
            .select_entry(&self.manifest, g, op, f, variant)?;
        let data = probe::synth_operands(op, g.n_rows, f, 0xBE7C);
        probe::time_entry(self.backend.as_ref(), entry, g, &data, 1, iters, cap_ms)
    }

    // ------------------------------------------------------- internals

    fn run_entry(&self, entry: &ArtifactEntry, g: &Csr, data: &OpData) -> Result<Vec<f32>> {
        let inputs = pack_inputs(entry, g, data)?;
        self.backend.run_f32(entry, &inputs)
    }
}

/// Compact an ELL `[n_pad, w]` output to CSR slot order (valid slots are
/// left-packed by construction).
pub fn ell_slots_to_csr(g: &Csr, w: usize, ell: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(g.nnz());
    for i in 0..g.n_rows {
        let deg = g.degree(i);
        out.extend_from_slice(&ell[i * w..i * w + deg]);
    }
    out
}

/// Spread CSR slot-order values into an ELL `[n_pad, w]` buffer.
pub fn csr_slots_to_ell(g: &Csr, n_pad: usize, w: usize, slots: &[f32]) -> Result<Vec<f32>> {
    if slots.len() != g.nnz() {
        return Err(anyhow!(
            "slot vector length {} != nnz {}",
            slots.len(),
            g.nnz()
        ));
    }
    if g.max_degree() > w || g.n_rows > n_pad {
        return Err(anyhow!("graph does not fit ELL bucket ({n_pad}, {w})"));
    }
    let mut out = vec![0.0f32; n_pad * w];
    for i in 0..g.n_rows {
        let (a, b) = (g.rowptr[i], g.rowptr[i + 1]);
        out[i * w..i * w + (b - a)].copy_from_slice(&slots[a..b]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Csr {
        Csr::from_rows(3, vec![vec![(1, 1.0), (2, 2.0)], vec![], vec![(0, 3.0)]])
    }

    #[test]
    fn slot_conversions_roundtrip() {
        let g = g();
        let slots = vec![10.0, 20.0, 30.0];
        let ell = csr_slots_to_ell(&g, 4, 2, &slots).unwrap();
        assert_eq!(ell[0], 10.0);
        assert_eq!(ell[1], 20.0);
        assert_eq!(ell[2 * 2], 30.0);
        let back = ell_slots_to_csr(&g, 2, &ell);
        assert_eq!(back, slots);
    }

    #[test]
    fn slot_conversion_validates() {
        let g = g();
        assert!(csr_slots_to_ell(&g, 4, 2, &[1.0]).is_err()); // wrong nnz
        assert!(csr_slots_to_ell(&g, 4, 1, &[1.0, 2.0, 3.0]).is_err()); // w too small
        assert!(csr_slots_to_ell(&g, 2, 2, &[1.0, 2.0, 3.0]).is_err()); // n_pad small
    }
}
