//! The learned scheduler's cost model (ROADMAP "Learned scheduler";
//! ParamSpMM / DA-SpMM in PAPERS.md): a per-op decision tree trained on
//! telemetry the engine already persists — probe resolutions in the
//! schedule cache and probe-outcome rows in `audit.jsonl` — predicting
//! the kernel variant for cold keys so serving skips the micro-probe
//! when the model is confident.
//!
//! Pipeline: [`dataset`] mines labeled examples over the
//! `InputFeatures::to_vec()` vector, [`tree`] fits a deterministic CART
//! per op, and [`format`] persists the result as a versioned,
//! checksummed, crash-safe `.asgm` file. `Scheduler::decide` consults
//! the model after input validation: confidence at or above
//! `AUTOSAGE_MODEL_CONFIDENCE` skips the probe (the guardrail's oracle
//! safety is untouched — a mispredicted variant still computes the
//! exact answer, it is merely slower); below it the probe runs and the
//! predicted-vs-probed agreement is counted.
//!
//! Confidence is calibrated: the tree's Laplace-smoothed leaf purity is
//! damped by the per-variant roofline calibration error from the audit
//! table, so variants whose cost estimates are known-bad need stronger
//! leaf evidence before the probe is skipped.

pub mod dataset;
pub mod format;
pub mod tree;

use std::collections::BTreeMap;

use crate::obs::report::CalibrationRow;
use crate::scheduler::features::FEATURE_NAMES;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

pub use dataset::{class_summary, examples_from_audit, examples_from_cache, merge_and_cap, Example};
pub use format::{read_model, read_model_generational, write_model, write_model_generational, MODEL_MAGIC, MODEL_VERSION};
pub use tree::{DecisionTree, Prediction, DEFAULT_MAX_DEPTH};

/// Cap on training examples; beyond it a seeded subsample keeps
/// training time bounded on long-lived telemetry.
pub const TRAIN_EXAMPLE_CAP: usize = 50_000;

/// One op's trained classifier plus its calibration damping table.
#[derive(Debug, Clone, PartialEq)]
pub struct OpModel {
    pub tree: DecisionTree,
    /// Per-variant mean relative roofline error from the audit
    /// calibration table (absent variant = no damping).
    pub calib: BTreeMap<String, f64>,
}

/// The trained cost model: per-op trees over the canonical
/// [`FEATURE_NAMES`] vector.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Training seed (stamped into the file header; byte-identical
    /// retraining requires the same seed and telemetry).
    pub seed: u64,
    pub feature_names: Vec<String>,
    pub ops: BTreeMap<String, OpModel>,
}

/// Damp a raw leaf confidence by the variant's known estimate error:
/// full trust while the roofline is within ~100% relative error, down
/// to half trust once it exceeds 200%. Bounded in [0.5, 1.0] so a bad
/// calibration table can force probing but never zero the model out.
fn calib_factor(mean_rel_err: f64) -> f64 {
    1.0 / (1.0 + (mean_rel_err - 1.0).clamp(0.0, 1.0))
}

impl CostModel {
    /// Train per-op trees from labeled examples plus the audit
    /// calibration table. Deterministic: same inputs + seed → the same
    /// model, bit for bit.
    pub fn train(
        examples: &[Example],
        calib: &[CalibrationRow],
        seed: u64,
        max_depth: usize,
    ) -> Result<CostModel> {
        if examples.is_empty() {
            return Err(anyhow!(
                "no labeled examples — run serve-bench/bench with probing \
                 first so the cache and audit stream carry probe outcomes"
            ));
        }
        let mut by_op: BTreeMap<String, Vec<&Example>> = BTreeMap::new();
        for ex in examples {
            by_op.entry(ex.op.clone()).or_default().push(ex);
        }
        let mut ops = BTreeMap::new();
        for (op, exs) in by_op {
            let mut classes: Vec<String> =
                exs.iter().map(|e| e.label.clone()).collect();
            classes.sort();
            classes.dedup();
            let labels: Vec<usize> = exs
                .iter()
                .map(|e| classes.iter().position(|c| *c == e.label).expect("own label"))
                .collect();
            let features: Vec<Vec<f64>> =
                exs.iter().map(|e| e.features.clone()).collect();
            let tree = DecisionTree::train(classes, &features, &labels, max_depth)
                .with_context(|| format!("training op {op}"))?;
            let calib_map: BTreeMap<String, f64> = calib
                .iter()
                .filter(|r| r.op == op)
                .map(|r| (r.variant.clone(), r.mean_rel_err))
                .collect();
            ops.insert(
                op,
                OpModel {
                    tree,
                    calib: calib_map,
                },
            );
        }
        Ok(CostModel {
            seed,
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            ops,
        })
    }

    /// Predict the variant for one op + feature vector. `None` when the
    /// model has no tree for the op. The returned confidence is already
    /// calibration-damped.
    pub fn predict(&self, op: &str, features: &[f64]) -> Option<Prediction> {
        let m = self.ops.get(op)?;
        let mut p = m.tree.predict(features)?;
        let err = m.calib.get(&p.variant).copied().unwrap_or(0.0);
        p.confidence = (p.confidence * calib_factor(err)).clamp(0.0, 1.0);
        Some(p)
    }

    /// Ops this model can predict for.
    pub fn op_names(&self) -> Vec<&str> {
        self.ops.keys().map(String::as_str).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut ops = BTreeMap::new();
        for (op, m) in &self.ops {
            let mut calib = BTreeMap::new();
            for (variant, err) in &m.calib {
                calib.insert(variant.clone(), Json::num(*err));
            }
            ops.insert(
                op.clone(),
                Json::obj(vec![
                    ("calib", Json::Obj(calib)),
                    ("tree", m.tree.to_json()),
                ]),
            );
        }
        Json::obj(vec![
            (
                "feature_names",
                Json::Arr(self.feature_names.iter().map(Json::str).collect()),
            ),
            ("ops", Json::Obj(ops)),
        ])
    }

    /// Parse a payload. Rejects models trained over a different feature
    /// vector: positional feature indexing makes the name list part of
    /// the file contract.
    pub fn from_json(j: &Json) -> Result<CostModel> {
        let feature_names: Vec<String> = j
            .get("feature_names")
            .as_arr()
            .ok_or_else(|| anyhow!("model: missing feature_names"))?
            .iter()
            .filter_map(|n| n.as_str().map(str::to_string))
            .collect();
        if feature_names != FEATURE_NAMES {
            return Err(anyhow!(
                "model was trained over features {feature_names:?} but this \
                 build extracts {FEATURE_NAMES:?} — retrain with `autosage train`"
            ));
        }
        let mut ops = BTreeMap::new();
        let raw = j
            .get("ops")
            .as_obj()
            .ok_or_else(|| anyhow!("model: missing ops"))?;
        for (op, body) in raw {
            let tree = DecisionTree::from_json(body.get("tree"))
                .with_context(|| format!("model op {op}"))?;
            let mut calib = BTreeMap::new();
            if let Some(c) = body.get("calib").as_obj() {
                for (variant, err) in c {
                    if let Some(e) = err.as_f64() {
                        calib.insert(variant.clone(), e);
                    }
                }
            }
            ops.insert(op.clone(), OpModel { tree, calib });
        }
        Ok(CostModel {
            seed: 0, // header-owned; read_model overwrites
            feature_names,
            ops,
        })
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A deterministic 2-op model used across model/ unit tests.
    pub(crate) fn tiny_model(seed: u64) -> CostModel {
        let examples = vec![
            Example {
                op: "spmm".into(),
                features: vec![100.0, 400.0, 64.0, 4.0, 4.0, 4.0, 4.0, 4.0, 0.1, 0.2, 0.0, 0.5, 0.3],
                label: "ell_r8_f32".into(),
            },
            Example {
                op: "spmm".into(),
                features: vec![100.0, 400.0, 64.0, 4.0, 4.0, 4.0, 4.0, 200.0, 0.8, 2.0, 0.0, 0.2, 0.3],
                label: "hub_r8_f32".into(),
            },
            Example {
                op: "attention".into(),
                features: vec![50.0, 100.0, 32.0, 2.0, 2.0, 2.0, 2.0, 2.0, 0.1, 0.1, 0.0, 0.9, 0.1],
                label: "fused".into(),
            },
        ];
        let calib = vec![CalibrationRow {
            op: "spmm".into(),
            variant: "hub_r8_f32".into(),
            buckets: 1,
            n: 4,
            mean_rel_err: 2.5,
            max_rel_err: 3.0,
            sign_bias: 0.1,
        }];
        CostModel::train(&examples, &calib, seed, DEFAULT_MAX_DEPTH).unwrap()
    }

    #[test]
    fn train_predict_and_calibration_damping() {
        let m = tiny_model(42);
        assert_eq!(m.op_names(), ["attention", "spmm"]);
        let light = m
            .predict(
                "spmm",
                &[100.0, 400.0, 64.0, 4.0, 4.0, 4.0, 4.0, 4.0, 0.1, 0.2, 0.0, 0.5, 0.3],
            )
            .unwrap();
        assert_eq!(light.variant, "ell_r8_f32");
        let hub = m
            .predict(
                "spmm",
                &[100.0, 400.0, 64.0, 4.0, 4.0, 4.0, 4.0, 200.0, 0.8, 2.0, 0.0, 0.2, 0.3],
            )
            .unwrap();
        assert_eq!(hub.variant, "hub_r8_f32");
        // hub's roofline is badly calibrated (mean_rel_err 2.5 → factor
        // 0.5), so its confidence is half the undamped twin's.
        assert!(
            (hub.confidence - light.confidence * 0.5).abs() < 1e-9,
            "{} vs {}",
            hub.confidence,
            light.confidence
        );
        assert!(m.predict("sddmm", &[1.0; 13]).is_none());
    }

    #[test]
    fn calib_factor_is_bounded() {
        assert_eq!(calib_factor(0.0), 1.0);
        assert_eq!(calib_factor(1.0), 1.0);
        assert!((calib_factor(1.5) - 1.0 / 1.5).abs() < 1e-12);
        assert_eq!(calib_factor(2.0), 0.5);
        assert_eq!(calib_factor(100.0), 0.5, "damping is bounded at 1/2");
    }

    #[test]
    fn json_round_trip_preserves_model() {
        let m = tiny_model(7);
        let text = m.to_json().to_string();
        let mut back = CostModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        back.seed = m.seed;
        assert_eq!(back, m);
    }

    #[test]
    fn from_json_rejects_foreign_feature_vector() {
        let m = tiny_model(7);
        let text = m
            .to_json()
            .to_string()
            .replace("\"n_rows\"", "\"rows_n\"");
        let err = CostModel::from_json(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("retrain"), "{err:#}");
    }

    #[test]
    fn training_is_deterministic_across_runs() {
        let a = tiny_model(3);
        let b = tiny_model(3);
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
