//! Dependency-free CART decision tree over the numeric
//! `InputFeatures::to_vec()` vector.
//!
//! Training is fully deterministic: features are swept in index order,
//! candidate thresholds are midpoints between consecutive distinct
//! sorted values, and ties break toward (lower impurity, lower feature
//! index, lower threshold) — the same labeled examples always produce
//! the same tree, which is what makes `autosage train --seed` emit
//! byte-identical model files.
//!
//! Leaves store raw class counts rather than a collapsed argmax so
//! prediction can report a Laplace-smoothed purity as its confidence:
//! a 1-example leaf claims (1+1)/(1+k) — honest uncertainty — while a
//! 50/0 leaf claims ~0.98.

use std::collections::BTreeMap;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Default growth limit; deep enough for the ~13-dim feature space,
/// shallow enough that a handful of probes cannot overfit to noise.
pub const DEFAULT_MAX_DEPTH: usize = 6;

/// A predicted variant plus the calibrated confidence in [0, 1].
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub variant: String,
    pub confidence: f64,
}

/// One tree node. Internal nodes split `feature <= threshold` → left;
/// leaves carry per-class example counts (parallel to
/// [`DecisionTree::classes`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
    Leaf {
        counts: Vec<u64>,
    },
}

/// A trained per-op classifier: variant labels + a flat node array
/// (node 0 is the root).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionTree {
    pub classes: Vec<String>,
    pub nodes: Vec<Node>,
}

fn gini(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

struct BestSplit {
    feature: usize,
    threshold: f64,
    impurity: f64,
}

/// Sweep every feature for the lowest weighted-Gini split of `idx`.
/// O(d · n log n); first-encountered best wins, so ties deterministically
/// resolve to the lowest (feature, threshold).
fn best_split(
    features: &[Vec<f64>],
    labels: &[usize],
    idx: &[usize],
    n_classes: usize,
    n_features: usize,
) -> Option<BestSplit> {
    let parent = {
        let mut c = vec![0u64; n_classes];
        for &i in idx {
            c[labels[i]] += 1;
        }
        gini(&c)
    };
    let n = idx.len() as f64;
    let mut best: Option<BestSplit> = None;
    for f in 0..n_features {
        let mut order: Vec<usize> = idx.to_vec();
        order.sort_by(|&a, &b| {
            features[a][f]
                .partial_cmp(&features[b][f])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut left = vec![0u64; n_classes];
        let mut right = vec![0u64; n_classes];
        for &i in &order {
            right[labels[i]] += 1;
        }
        for w in 0..order.len().saturating_sub(1) {
            let i = order[w];
            left[labels[i]] += 1;
            right[labels[i]] -= 1;
            let (a, b) = (features[i][f], features[order[w + 1]][f]);
            if a == b {
                continue; // can't split between equal values
            }
            let n_l = (w + 1) as f64;
            let n_r = n - n_l;
            let impurity = (n_l * gini(&left) + n_r * gini(&right)) / n;
            let improves = match &best {
                None => true,
                Some(bst) => impurity < bst.impurity,
            };
            if impurity + 1e-12 < parent && improves {
                best = Some(BestSplit {
                    feature: f,
                    threshold: (a + b) / 2.0,
                    impurity,
                });
            }
        }
    }
    best
}

impl DecisionTree {
    /// Train on parallel `(feature-vector, class-index)` examples.
    /// `classes` maps class indices back to variant ids.
    pub fn train(
        classes: Vec<String>,
        features: &[Vec<f64>],
        labels: &[usize],
        max_depth: usize,
    ) -> Result<DecisionTree> {
        if features.is_empty() || features.len() != labels.len() {
            return Err(anyhow!(
                "tree training needs matched non-empty features/labels \
                 ({} vs {})",
                features.len(),
                labels.len()
            ));
        }
        if let Some(&bad) = labels.iter().find(|&&l| l >= classes.len()) {
            return Err(anyhow!("label index {bad} out of {} classes", classes.len()));
        }
        let n_features = features[0].len();
        let mut tree = DecisionTree {
            classes,
            nodes: Vec::new(),
        };
        let all: Vec<usize> = (0..features.len()).collect();
        tree.grow(features, labels, &all, n_features, max_depth);
        Ok(tree)
    }

    fn leaf_counts(&self, labels: &[usize], idx: &[usize]) -> Vec<u64> {
        let mut counts = vec![0u64; self.classes.len()];
        for &i in idx {
            counts[labels[i]] += 1;
        }
        counts
    }

    /// Append the subtree for `idx`, returning its root node index.
    fn grow(
        &mut self,
        features: &[Vec<f64>],
        labels: &[usize],
        idx: &[usize],
        n_features: usize,
        depth_left: usize,
    ) -> usize {
        let counts = self.leaf_counts(labels, idx);
        let split = if depth_left == 0 || idx.len() < 2 || gini(&counts) == 0.0 {
            None
        } else {
            best_split(features, labels, idx, self.classes.len(), n_features)
        };
        let slot = self.nodes.len();
        self.nodes.push(Node::Leaf { counts });
        if let Some(s) = split {
            let (l_idx, r_idx): (Vec<usize>, Vec<usize>) = idx
                .iter()
                .partition(|&&i| features[i][s.feature] <= s.threshold);
            if !l_idx.is_empty() && !r_idx.is_empty() {
                let left = self.grow(features, labels, &l_idx, n_features, depth_left - 1);
                let right = self.grow(features, labels, &r_idx, n_features, depth_left - 1);
                self.nodes[slot] = Node::Split {
                    feature: s.feature,
                    threshold: s.threshold,
                    left,
                    right,
                };
            }
        }
        slot
    }

    /// Classify a feature vector: the majority class of the reached
    /// leaf, with Laplace-smoothed purity `(max+1)/(total+k)` as the raw
    /// (pre-calibration) confidence. `None` only for an empty tree.
    pub fn predict(&self, features: &[f64]) -> Option<Prediction> {
        let mut at = 0usize;
        loop {
            match self.nodes.get(at)? {
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    let v = features.get(*feature).copied().unwrap_or(0.0);
                    at = if v <= *threshold { *left } else { *right };
                }
                Node::Leaf { counts } => {
                    let total: u64 = counts.iter().sum();
                    if total == 0 || counts.is_empty() {
                        return None;
                    }
                    // Ties break to the lowest class index (stable).
                    let mut best = 0usize;
                    for (i, &c) in counts.iter().enumerate() {
                        if c > counts[best] {
                            best = i;
                        }
                    }
                    let confidence = (counts[best] as f64 + 1.0)
                        / (total as f64 + self.classes.len() as f64);
                    return Some(Prediction {
                        variant: self.classes.get(best)?.clone(),
                        confidence,
                    });
                }
            }
        }
    }

    /// Maximum split depth (leaf-only tree = 0); model-file sanity stat.
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], at: usize) -> usize {
            match &nodes[at] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + walk(nodes, *left).max(walk(nodes, *right))
                }
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| match n {
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => Json::obj(vec![
                    ("f", Json::num(*feature as f64)),
                    ("t", Json::num(*threshold)),
                    ("l", Json::num(*left as f64)),
                    ("r", Json::num(*right as f64)),
                ]),
                Node::Leaf { counts } => Json::obj(vec![(
                    "c",
                    Json::Arr(counts.iter().map(|&c| Json::num(c as f64)).collect()),
                )]),
            })
            .collect();
        Json::obj(vec![
            (
                "classes",
                Json::Arr(self.classes.iter().map(Json::str).collect()),
            ),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DecisionTree> {
        let classes: Vec<String> = j
            .get("classes")
            .as_arr()
            .ok_or_else(|| anyhow!("tree: missing classes"))?
            .iter()
            .filter_map(|c| c.as_str().map(str::to_string))
            .collect();
        let raw = j
            .get("nodes")
            .as_arr()
            .ok_or_else(|| anyhow!("tree: missing nodes"))?;
        let mut nodes = Vec::with_capacity(raw.len());
        for (i, n) in raw.iter().enumerate() {
            if let Some(counts) = n.get("c").as_arr() {
                let counts: Vec<u64> = counts
                    .iter()
                    .filter_map(|c| c.as_f64().map(|v| v.max(0.0) as u64))
                    .collect();
                if counts.len() != classes.len() {
                    return Err(anyhow!(
                        "tree node {i}: {} counts for {} classes",
                        counts.len(),
                        classes.len()
                    ));
                }
                nodes.push(Node::Leaf { counts });
            } else {
                let geti = |k: &str| -> Result<usize> {
                    n.get(k)
                        .as_usize()
                        .ok_or_else(|| anyhow!("tree node {i}: missing {k}"))
                };
                let (left, right) = (geti("l")?, geti("r")?);
                if left >= raw.len() || right >= raw.len() || left <= i || right <= i {
                    // Children must point forward — this also rules out
                    // cycles, so predict() always terminates.
                    return Err(anyhow!("tree node {i}: bad child indices {left}/{right}"));
                }
                nodes.push(Node::Split {
                    feature: geti("f")?,
                    threshold: n
                        .get("t")
                        .as_f64()
                        .ok_or_else(|| anyhow!("tree node {i}: missing t"))?,
                    left,
                    right,
                });
            }
        }
        if nodes.is_empty() {
            return Err(anyhow!("tree: empty node array"));
        }
        Ok(DecisionTree { classes, nodes })
    }

    /// Per-class training-example counts (root totals).
    pub fn class_counts(&self) -> BTreeMap<String, u64> {
        fn root_counts(nodes: &[Node], at: usize, acc: &mut Vec<u64>) {
            match &nodes[at] {
                Node::Leaf { counts } => {
                    for (a, c) in acc.iter_mut().zip(counts) {
                        *a += c;
                    }
                }
                Node::Split { left, right, .. } => {
                    root_counts(nodes, *left, acc);
                    root_counts(nodes, *right, acc);
                }
            }
        }
        let mut acc = vec![0u64; self.classes.len()];
        if !self.nodes.is_empty() {
            root_counts(&self.nodes, 0, &mut acc);
        }
        self.classes
            .iter()
            .cloned()
            .zip(acc)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_ish() -> (Vec<Vec<f64>>, Vec<usize>) {
        // Separable on feature 1 at ~5: class 0 below, class 1 above.
        let features = vec![
            vec![1.0, 2.0],
            vec![2.0, 3.0],
            vec![1.5, 4.0],
            vec![1.0, 8.0],
            vec![2.0, 9.0],
            vec![1.5, 7.0],
        ];
        let labels = vec![0, 0, 0, 1, 1, 1];
        (features, labels)
    }

    #[test]
    fn learns_a_separable_split_with_high_confidence() {
        let (f, l) = xor_ish();
        let t = DecisionTree::train(vec!["a".into(), "b".into()], &f, &l, 6).unwrap();
        let p = t.predict(&[1.0, 2.5]).unwrap();
        assert_eq!(p.variant, "a");
        assert!(p.confidence > 0.7, "{}", p.confidence);
        let p = t.predict(&[1.0, 8.5]).unwrap();
        assert_eq!(p.variant, "b");
        assert!(t.depth() >= 1);
    }

    #[test]
    fn training_is_deterministic() {
        let (f, l) = xor_ish();
        let classes = vec!["a".to_string(), "b".to_string()];
        let t1 = DecisionTree::train(classes.clone(), &f, &l, 6).unwrap();
        let t2 = DecisionTree::train(classes, &f, &l, 6).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(t1.to_json().to_string(), t2.to_json().to_string());
    }

    #[test]
    fn single_class_is_a_pure_leaf() {
        let t = DecisionTree::train(
            vec!["only".into()],
            &[vec![1.0], vec![2.0]],
            &[0, 0],
            6,
        )
        .unwrap();
        assert_eq!(t.depth(), 0);
        let p = t.predict(&[5.0]).unwrap();
        assert_eq!(p.variant, "only");
        // Laplace: (2+1)/(2+1) = 1.0 for a single-class problem.
        assert!((p.confidence - 1.0).abs() < 1e-9);
    }

    #[test]
    fn small_leaves_report_damped_confidence() {
        // One example per class, not separable by depth 0.
        let t = DecisionTree::train(
            vec!["a".into(), "b".into(), "c".into()],
            &[vec![1.0]],
            &[1],
            6,
        )
        .unwrap();
        let p = t.predict(&[1.0]).unwrap();
        assert_eq!(p.variant, "b");
        // (1+1)/(1+3) = 0.5: one observation is weak evidence.
        assert!((p.confidence - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_round_trip_and_corruption_rejection() {
        let (f, l) = xor_ish();
        let t = DecisionTree::train(vec!["a".into(), "b".into()], &f, &l, 6).unwrap();
        let text = t.to_json().to_string();
        let back = DecisionTree::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
        // Backward child pointer would loop forever — rejected.
        let evil = r#"{"classes":["a"],"nodes":[{"f":0,"t":1,"l":0,"r":0}]}"#;
        assert!(DecisionTree::from_json(&Json::parse(evil).unwrap()).is_err());
        let short = r#"{"classes":["a","b"],"nodes":[{"c":[1]}]}"#;
        assert!(DecisionTree::from_json(&Json::parse(short).unwrap()).is_err());
    }

    #[test]
    fn depth_limit_is_respected() {
        let n = 64;
        let features: Vec<Vec<f64>> = (0..n).map(|i| vec![i as f64]).collect();
        let labels: Vec<usize> = (0..n).map(|i| i % 2).collect();
        let t =
            DecisionTree::train(vec!["a".into(), "b".into()], &features, &labels, 3).unwrap();
        assert!(t.depth() <= 3, "depth {}", t.depth());
    }
}
