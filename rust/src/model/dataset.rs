//! Training-data extraction: mine the telemetry the engine already
//! persists — schedule-cache probe resolutions and `audit.jsonl` probe
//! outcome rows — into labeled `(op, InputFeatures vector) → variant`
//! examples for the cost model.
//!
//! Two sources, same label semantics:
//!
//! * **Schedule cache**: entries that carry a stored feature vector are
//!   probe resolutions (model-predicted entries deliberately store no
//!   features, so the model never trains on its own output). The label
//!   is the cached variant.
//! * **Audit stream**: probe-path rows record every probed candidate
//!   with an outcome. A `"chosen"` row is a positive label; a
//!   `"fallback"` row labels the input `"baseline"` (the guardrail
//!   rejected every candidate — the negative outcome the satellite task
//!   asks us to learn from). `"rejected"` / `"baseline"` rows carry the
//!   losing side; they contribute to calibration, and they gate labels:
//!   a group with only rejected rows yields no example.

use std::collections::BTreeMap;

use crate::obs::metrics::AuditSample;
use crate::scheduler::ScheduleCache;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// One labeled training example.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub op: String,
    pub features: Vec<f64>,
    pub label: String,
}

/// Deduplication key: op + the exact feature vector. `{:?}` on f64 is
/// shortest-roundtrip, so distinct vectors get distinct keys.
fn example_key(op: &str, features: &[f64]) -> String {
    format!("{op}|{features:?}")
}

/// Mine probe-resolved schedule-cache entries (the ones carrying a
/// feature vector) into examples. The cache key's last `|` segment is
/// the op name.
pub fn examples_from_cache(cache: &ScheduleCache) -> Vec<Example> {
    let mut out = Vec::new();
    for (key, choice) in cache.dump() {
        let Some(features) = choice.features else { continue };
        let Some(op) = key.rsplit('|').next().filter(|s| !s.is_empty()) else {
            continue;
        };
        out.push(Example {
            op: op.to_string(),
            features,
            label: choice.variant,
        });
    }
    out
}

/// Mine an `audit.jsonl` body into examples: per (op, feature-vector)
/// group, the `"chosen"` row wins, a `"fallback"` row labels the group
/// `"baseline"`, and groups with neither yield nothing.
///
/// Torn/short tails are salvaged: the valid JSONL prefix trains, the
/// dropped tail is counted in `iofault::recovery()`. Lines that parse
/// as JSON but are not audit samples stay hard errors (schema drift is
/// a bug, not disk damage).
pub fn examples_from_audit(audit_jsonl: &str) -> Result<Vec<Example>> {
    let (lines, dropped) = crate::util::iofault::salvage_jsonl(audit_jsonl);
    if dropped > 0 {
        crate::util::iofault::recovery()
            .jsonl_lines_dropped
            .fetch_add(dropped as u64, std::sync::atomic::Ordering::Relaxed);
    }
    let mut by_key: BTreeMap<String, Example> = BTreeMap::new();
    for (i, line) in lines.into_iter().enumerate() {
        let j = Json::parse(line).with_context(|| format!("audit.jsonl line {}", i + 1))?;
        let s = AuditSample::from_json(&j)
            .with_context(|| format!("audit.jsonl line {}: not an audit sample", i + 1))?;
        let Some(features) = s.features else { continue };
        let label = match s.outcome.as_str() {
            "chosen" => s.variant,
            "fallback" => "baseline".to_string(),
            _ => continue, // rejected/baseline/executed: no label here
        };
        // Later rows win: a re-probe of the same input is fresher.
        by_key.insert(
            example_key(&s.op, &features),
            Example {
                op: s.op,
                features,
                label,
            },
        );
    }
    Ok(by_key.into_values().collect())
}

/// Merge example sources, dedup by (op, feature vector) — later sources
/// win — and cap the set with a seeded subsample. Output order is
/// sorted by key, so the training set (and therefore the trained model
/// file) is a pure function of (telemetry, seed).
pub fn merge_and_cap(sources: Vec<Vec<Example>>, cap: usize, seed: u64) -> Vec<Example> {
    let mut by_key: BTreeMap<String, Example> = BTreeMap::new();
    for source in sources {
        for ex in source {
            by_key.insert(example_key(&ex.op, &ex.features), ex);
        }
    }
    let mut keys: Vec<String> = by_key.keys().cloned().collect();
    if keys.len() > cap {
        let mut rng = Rng::for_stream(seed, 0);
        rng.shuffle(&mut keys);
        keys.truncate(cap);
        keys.sort();
    }
    keys.into_iter()
        .filter_map(|k| by_key.remove(&k))
        .collect()
}

/// Per-op class histogram, for the `autosage train` summary.
pub fn class_summary(examples: &[Example]) -> BTreeMap<String, BTreeMap<String, usize>> {
    let mut out: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
    for ex in examples {
        *out.entry(ex.op.clone())
            .or_default()
            .entry(ex.label.clone())
            .or_default() += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::CachedChoice;

    fn sample_line(op: &str, variant: &str, outcome: &str, features: Option<&[f64]>) -> String {
        let mut s = AuditSample::executed(op, variant, "b", 1.0, 2.0);
        s.outcome = outcome.into();
        s.features = features.map(|f| f.to_vec());
        s.to_json().to_string()
    }

    #[test]
    fn audit_labels_come_from_chosen_and_fallback_rows() {
        let feats_a = [100.0, 400.0];
        let feats_b = [200.0, 800.0];
        let feats_c = [300.0, 900.0];
        let lines = [
            sample_line("spmm", "ell_r8_f32", "chosen", Some(&feats_a)),
            sample_line("spmm", "hub_r8_f32", "rejected", Some(&feats_a)),
            sample_line("spmm", "baseline", "fallback", Some(&feats_b)),
            // Only-rejected group: no ground truth, no example.
            sample_line("spmm", "hub_r8_f32", "rejected", Some(&feats_c)),
            // Executed rows carry no features and never label.
            sample_line("spmm", "ell_r8_f32", "executed", None),
        ]
        .join("\n");
        let mut ex = examples_from_audit(&lines).unwrap();
        ex.sort_by(|a, b| a.features[0].partial_cmp(&b.features[0]).unwrap());
        assert_eq!(ex.len(), 2);
        assert_eq!(ex[0].label, "ell_r8_f32");
        assert_eq!(ex[1].label, "baseline");
    }

    #[test]
    fn audit_with_torn_tail_salvages_the_valid_prefix() {
        let feats = [100.0, 400.0];
        let good = sample_line("spmm", "ell_r8_f32", "chosen", Some(&feats));
        let torn = format!("{good}\n{}", &good[..good.len() / 2]);
        let ex = examples_from_audit(&torn).unwrap();
        assert_eq!(ex.len(), 1, "prefix row survives, torn tail drops");
        assert_eq!(ex[0].label, "ell_r8_f32");
    }

    #[test]
    fn cache_entries_without_features_are_skipped() {
        let mut cache = ScheduleCache::in_memory();
        cache.insert(
            "dev|sig1|F64|spmm".into(),
            CachedChoice {
                variant: "ell_r8_f32".into(),
                t_baseline_ms: 1.0,
                t_star_ms: 0.5,
                alpha: 0.95,
                features: Some(vec![64.0, 256.0]),
            },
        );
        cache.insert(
            "dev|sig2|F64|attention".into(),
            CachedChoice {
                variant: "fused".into(),
                t_baseline_ms: 1.0,
                t_star_ms: 0.5,
                alpha: 0.95,
                features: None, // model-predicted: never a training row
            },
        );
        let ex = examples_from_cache(&cache);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].op, "spmm");
        assert_eq!(ex[0].label, "ell_r8_f32");
    }

    #[test]
    fn merge_dedups_and_caps_deterministically() {
        let a = Example {
            op: "spmm".into(),
            features: vec![1.0],
            label: "old".into(),
        };
        let a2 = Example {
            label: "new".into(),
            ..a.clone()
        };
        let rest: Vec<Example> = (0..20)
            .map(|i| Example {
                op: "spmm".into(),
                features: vec![10.0 + i as f64],
                label: "x".into(),
            })
            .collect();
        let merged = merge_and_cap(vec![vec![a], vec![a2.clone()], rest.clone()], 100, 7);
        assert_eq!(merged.len(), 21);
        assert!(merged.contains(&a2), "later source wins the dup key");
        let capped1 = merge_and_cap(vec![rest.clone()], 8, 7);
        let capped2 = merge_and_cap(vec![rest], 8, 7);
        assert_eq!(capped1.len(), 8);
        assert_eq!(capped1, capped2, "same seed → same subsample");
    }
}
