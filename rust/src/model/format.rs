//! `.asgm` — the versioned, checksummed cost-model file format.
//!
//! Layout (integers little-endian, mirroring the `.asg` snapshot
//! format's crash-safety and verification discipline):
//!
//! ```text
//! magic    8 B   b"ASGMODL1"
//! version  u32   MODEL_VERSION (load rejects anything else)
//! seed     u64   the --seed the model was trained under
//! len      u64   payload byte length
//! payload  len B compact JSON (CostModel::to_json; BTreeMap-backed, so
//!                key order — and therefore the bytes — is canonical)
//! checksum u64   FNV-1a over every preceding byte
//! ```
//!
//! Writes go through a sibling temp file + rename; loads verify magic,
//! version, exact length, and checksum before parsing the payload.
//! Determinism contract: the same telemetry and the same seed produce
//! byte-identical files (verified by an integration test), so model
//! artifacts can be content-compared in CI.

use std::fs;
use std::path::Path;

use crate::graph::signature::Fnv1a;
use crate::model::CostModel;
use crate::util::iofault::{self, CorruptArtifact};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

pub const MODEL_MAGIC: &[u8; 8] = b"ASGMODL1";
pub const MODEL_VERSION: u32 = 1;

/// Extension appended to a model path to hold the previous generation
/// (`model.asgm.prev`). [`write_model_generational`] maintains it and
/// [`read_model_generational`] falls back to it on corruption.
pub const PREV_SUFFIX: &str = "prev";

fn encode_model(model: &CostModel) -> Vec<u8> {
    let payload = model.to_json().to_string();
    let mut buf: Vec<u8> = Vec::with_capacity(8 + 4 + 8 + 8 + payload.len() + 8);
    buf.extend_from_slice(MODEL_MAGIC);
    buf.extend_from_slice(&MODEL_VERSION.to_le_bytes());
    buf.extend_from_slice(&model.seed.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(payload.as_bytes());
    let mut h = Fnv1a::new();
    h.write(&buf);
    buf.extend_from_slice(&h.finish().to_le_bytes());
    buf
}

/// Serialize `model` to `path`, crash-safely (temp file + rename).
pub fn write_model(path: &Path, model: &CostModel) -> Result<()> {
    let buf = encode_model(model);
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        fs::create_dir_all(dir).ok();
    }
    iofault::write_atomic("model.write", path, &buf)
        .with_context(|| format!("writing model {}", path.display()))
}

/// Path of the previous-generation sibling for a model at `path`
/// (`model.asgm` -> `model.asgm.prev`).
pub fn prev_path(path: &Path) -> std::path::PathBuf {
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "model.asgm".to_string());
    path.with_file_name(format!("{file_name}.{PREV_SUFFIX}"))
}

/// Serialize `model` to `path` keeping a two-generation history: the
/// existing file (generation N-1) is first renamed to `<path>.prev`,
/// then the new generation is written atomically. A reader that finds
/// the current file corrupt can fall back to the previous generation
/// via [`read_model_generational`].
pub fn write_model_generational(path: &Path, model: &CostModel) -> Result<()> {
    if path.exists() {
        iofault::rename("model.rotate", path, &prev_path(path))
            .with_context(|| format!("rotating previous model {}", path.display()))?;
    }
    write_model(path, model)
}

/// Load a model, falling back to the previous generation (`<path>.prev`)
/// when the current file is corrupt. Returns the model plus a flag that
/// is `true` when the fallback path was used. When both generations are
/// unreadable the error downcasts to [`CorruptArtifact`].
pub fn read_model_generational(path: &Path) -> Result<(CostModel, bool)> {
    match read_model(path) {
        Ok(m) => Ok((m, false)),
        Err(primary) => {
            let prev = prev_path(path);
            if prev.exists() {
                if let Ok(m) = read_model(&prev) {
                    iofault::recovery().generation_fallbacks.fetch_add(
                        1,
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    return Ok((m, true));
                }
            }
            Err(anyhow::Error::new(CorruptArtifact {
                path: path.to_path_buf(),
                detail: format!("{primary:#}"),
            }))
        }
    }
}

/// Load and fully verify a cost model from `path`.
pub fn read_model(path: &Path) -> Result<CostModel> {
    let buf = iofault::read_file("model.read", path)
        .with_context(|| format!("reading model {}", path.display()))?;
    let name = path.display();
    let header = 8 + 4 + 8 + 8;
    if buf.len() < header + 8 {
        return Err(anyhow!("{name}: truncated model file ({} bytes)", buf.len()));
    }
    if &buf[..8] != MODEL_MAGIC {
        return Err(anyhow!("{name}: not an AutoSAGE model file (bad magic)"));
    }
    let version = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    if version != MODEL_VERSION {
        return Err(anyhow!(
            "{name}: unsupported model version {version} (expected {MODEL_VERSION})"
        ));
    }
    let seed = u64::from_le_bytes(buf[12..20].try_into().expect("8 bytes"));
    let len = u64::from_le_bytes(buf[20..28].try_into().expect("8 bytes"));
    // u128 math: the length field is untrusted.
    if buf.len() as u128 != header as u128 + len as u128 + 8 {
        return Err(anyhow!(
            "{name}: length {} != expected for {len}-byte payload",
            buf.len()
        ));
    }
    let mut h = Fnv1a::new();
    h.write(&buf[..buf.len() - 8]);
    let stored = u64::from_le_bytes(
        buf[buf.len() - 8..].try_into().expect("8 bytes"),
    );
    if h.finish() != stored {
        return Err(anyhow!(
            "{name}: checksum mismatch (file corrupt or truncated mid-write)"
        ));
    }
    let payload = std::str::from_utf8(&buf[header..buf.len() - 8])
        .map_err(|_| anyhow!("{name}: model payload is not UTF-8"))?;
    let j = Json::parse(payload).map_err(|e| anyhow!("{name}: payload: {e}"))?;
    let mut model = CostModel::from_json(&j).with_context(|| format!("{name}: payload"))?;
    model.seed = seed;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::tiny_model;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("autosage_model_format_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_atomicity() {
        let path = tmpfile("roundtrip.asgm");
        let m = tiny_model(42);
        write_model(&path, &m).unwrap();
        assert!(!path.with_file_name("roundtrip.asgm.tmp").exists());
        let back = read_model(&path).unwrap();
        assert_eq!(back, m);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn writes_are_byte_identical_for_same_model() {
        let a = tmpfile("det_a.asgm");
        let b = tmpfile("det_b.asgm");
        write_model(&a, &tiny_model(7)).unwrap();
        write_model(&b, &tiny_model(7)).unwrap();
        assert_eq!(fs::read(&a).unwrap(), fs::read(&b).unwrap());
        let _ = fs::remove_file(&a);
        let _ = fs::remove_file(&b);
    }

    #[test]
    fn detects_corruption_truncation_bad_magic_and_version() {
        let path = tmpfile("corrupt.asgm");
        write_model(&path, &tiny_model(1)).unwrap();
        let good = fs::read(&path).unwrap();

        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0xFF;
        fs::write(&path, &flipped).unwrap();
        let err = format!("{:#}", read_model(&path).unwrap_err());
        assert!(err.contains("checksum") || err.contains("payload"), "{err}");

        fs::write(&path, &good[..good.len() - 3]).unwrap();
        assert!(read_model(&path).is_err());

        fs::write(&path, vec![b'X'; 64]).unwrap();
        let err = format!("{:#}", read_model(&path).unwrap_err());
        assert!(err.contains("magic"), "{err}");

        let mut futver = good.clone();
        futver[8] = 99;
        let mut h = Fnv1a::new();
        let n = futver.len();
        h.write(&futver[..n - 8]);
        futver[n - 8..].copy_from_slice(&h.finish().to_le_bytes());
        fs::write(&path, &futver).unwrap();
        let err = format!("{:#}", read_model(&path).unwrap_err());
        assert!(err.contains("version"), "{err}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn generational_write_keeps_previous_and_falls_back_on_corruption() {
        let path = tmpfile("gen.asgm");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(prev_path(&path));

        write_model_generational(&path, &tiny_model(1)).unwrap();
        assert!(!prev_path(&path).exists(), "no .prev after first write");
        write_model_generational(&path, &tiny_model(2)).unwrap();
        assert!(prev_path(&path).exists(), ".prev holds generation N-1");
        assert_eq!(read_model(&prev_path(&path)).unwrap(), tiny_model(1));

        // Healthy current file: no fallback.
        let (m, fell_back) = read_model_generational(&path).unwrap();
        assert_eq!(m, tiny_model(2));
        assert!(!fell_back);

        // Corrupt the current generation: reader falls back to N-1.
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        let (m, fell_back) = read_model_generational(&path).unwrap();
        assert_eq!(m, tiny_model(1));
        assert!(fell_back);

        // Both generations corrupt: typed refusal.
        fs::write(prev_path(&path), b"garbage").unwrap();
        let err = read_model_generational(&path).unwrap_err();
        assert!(
            err.downcast_ref::<CorruptArtifact>().is_some(),
            "expected CorruptArtifact, got {err:#}"
        );
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(prev_path(&path));
    }
}
