//! AutoSAGE CLI — the leader entrypoint.
//!
//! ```text
//! autosage backends
//! autosage gen     --graph reddit_s [--seed 42]
//! autosage decide  --graph er_s --op spmm --f 64 [--alpha 0.95]
//! autosage run     --graph file:g.asg --op spmm --f 64
//! autosage bench   --graph file:g.asg [--ops spmm,sddmm] [--f 64]
//!                  [--reorder hub-pack,segment-sort] [--out results]
//! autosage data    convert <in> <out.asg> | inspect <path>
//!                  | reorder <in> [out.asg] --pass hub-pack,segment-sort
//!                  | sample <in> [out.asg] --keep-frac 0.5 --min-keep-deg 8
//! autosage table   <2..12> [--iters 7] [--cap-ms 1500] [--out results]
//! autosage figure  <1..7>  [--iters 7] [--cap-ms 1500] [--out results]
//! autosage all     [--out results]
//! autosage cache   dump|clear|stats [--path autosage_cache.json]
//! autosage serve-bench [--smoke] [--workers 4] [--clients 8] [--requests 8]
//!                      [--presets er_s,file:g.asg] [--ops spmm,sddmm,attention]
//!                      [--deadline-ms 0] [--retries 0]
//! autosage manifest validate <manifest.json>
//! autosage perf     compare <baseline.json> <candidate.json>
//! autosage metrics  validate|show <metrics.prom>
//! autosage obs      report <dir>
//! autosage doctor   <dir> [--fix] [--cache FILE]
//! ```
//!
//! Everywhere a graph is named, the spec grammar is `PRESET` or
//! `file:PATH` (`.asg` snapshot, `.mtx` Matrix Market, else edge list);
//! `--preset` stays as an alias of `--graph` for presets.
//!
//! `decide`/`run`/`bench`/`table`/`figure`/`all` honor `--backend
//! auto|native|pjrt` (default: `AUTOSAGE_BACKEND`, then auto). Other
//! env toggles (AUTOSAGE_ALPHA, AUTOSAGE_PROBE_*, AUTOSAGE_VEC,
//! AUTOSAGE_CACHE, AUTOSAGE_REPLAY_ONLY, ...) apply everywhere; see
//! `config.rs`.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use autosage::bench_kit::tables::{run_figure, run_table, table_ids};
use autosage::config::Config;
use autosage::coordinator::AutoSage;
use autosage::data;
use autosage::gen::preset_names;
use autosage::graph::signature::{graph_signature, layout_digest};
use autosage::graph::Csr;
use autosage::obs;
use autosage::scheduler::{probe, InputFeatures, Op, ScheduleCache};
use autosage::telemetry::meta_sidecar;
use autosage::util::stats;

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Minimal flag parser: positionals + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(raw: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = raw.iter().peekable();
        // Flags that may appear bare, with no value (`--smoke`,
        // `--json`); every other flag still hard-errors when its value
        // is missing.
        const BOOL_FLAGS: &[&str] = &["smoke", "json", "fix"];
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = if BOOL_FLAGS.contains(&key) {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            it.next().expect("peeked").clone()
                        }
                        _ => "true".to_string(),
                    }
                } else {
                    it.next()
                        .ok_or_else(|| anyhow!("flag --{key} needs a value"))?
                        .clone()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|_| anyhow!("invalid value for --{key}: {raw:?}")),
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts").unwrap_or("artifacts"))
}

fn real_main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = raw[0].clone();
    let args = Args::parse(&raw[1..])?;
    match cmd.as_str() {
        "backends" => cmd_backends(&args),
        "gen" => cmd_gen(&args),
        "decide" => cmd_decide(&args),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "data" => cmd_data(&args),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "all" => cmd_all(&args),
        "cache" => cmd_cache(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "train" => cmd_train(&args),
        "manifest" => cmd_manifest(&args),
        "perf" => cmd_perf(&args),
        "metrics" => cmd_metrics(&args),
        "obs" => cmd_obs(&args),
        "doctor" => cmd_doctor(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `autosage help`"),
    }
}

fn print_usage() {
    println!(
        "autosage — input-aware scheduling for sparse GNN aggregation\n\
         commands:\n\
         \x20 backends  (list execution backends + signatures)\n\
         \x20 gen     --graph G [--seed N]\n\
         \x20 decide  --graph G --op <spmm|sddmm|attention> --f F [--alpha A]\n\
         \x20 run     --graph G --op <spmm|sddmm|attention> --f F\n\
         \x20 bench   --graph G [--ops spmm,sddmm,attention] [--f F]\n\
         \x20         [--reorder hub-pack,segment-sort] [--iters N]\n\
         \x20         [--cap-ms MS] [--out DIR]\n\
         \x20 data    convert <in> <out.asg>\n\
         \x20         inspect <path>\n\
         \x20         reorder <in> [out.asg] --pass hub-pack,segment-sort\n\
         \x20         sample  <in> [out.asg] [--keep-frac F] [--min-keep-deg D]\n\
         \x20                 [--json]  (degree-aware edge sampling + error bound)\n\
         \x20 table   <2..12> [--iters N] [--cap-ms MS] [--out DIR]\n\
         \x20 figure  <1..7>  [--iters N] [--cap-ms MS] [--out DIR]\n\
         \x20 all     [--out DIR]\n\
         \x20 cache   dump|clear|stats [--path FILE]\n\
         \x20 serve-bench [--smoke] [--workers K] [--clients N] [--requests M]\n\
         \x20             [--presets a,b] [--ops spmm,sddmm,attention] [--f F]\n\
         \x20             [--seed N] [--cache FILE] [--model FILE.asgm] [--out DIR]\n\
         \x20             [--deadline-ms MS] [--retries R] [--approx-frac P]\n\
         \x20             (--out also writes trace.jsonl, metrics.prom, audit.jsonl,\n\
         \x20              perf.json, manifest.json, quarantine.jsonl, recovery.json;\n\
         \x20              see AUTOSAGE_TRACE_* / AUTOSAGE_FAULT_* / AUTOSAGE_IO_FAULT_*\n\
         \x20              / AUTOSAGE_DEGRADE_* / AUTOSAGE_MODEL_RELOAD_MS in config)\n\
         \x20 train   --from DIR [--cache FILE] --out MODEL.asgm [--seed N]\n\
         \x20         [--max-depth D]  (mine audit.jsonl + schedule-cache probe\n\
         \x20          outcomes into a decision-tree cost model; deterministic\n\
         \x20          under --seed; load via --model / AUTOSAGE_MODEL with the\n\
         \x20          probe threshold AUTOSAGE_MODEL_CONFIDENCE)\n\
         \x20 manifest validate <manifest.json>\n\
         \x20 perf    compare <baseline.json> <candidate.json>\n\
         \x20 metrics validate|show <metrics.prom>\n\
         \x20 obs     report <DIR> [--json]  (stage latencies + estimate-accuracy audit)\n\
         \x20 doctor  <DIR> [--fix] [--json] [--cache FILE]  (audit/repair run\n\
         \x20         artifacts: salvage torn JSONL tails, quarantine corrupt cache\n\
         \x20         entries, check generational .asg/.asgm fallback, verify the\n\
         \x20         manifest; --fix rewrites what salvage recovered)\n\
         graph specs G: a preset <{presets}>\n\
         \x20             or file:PATH (.asg | .mtx | edge list .txt/.csv);\n\
         \x20             --preset NAME remains an alias for presets\n\
         flags: --backend <auto|native|pjrt> (default: AUTOSAGE_BACKEND or auto)\n\
         \x20      --artifacts DIR (default: artifacts; pjrt backend only)",
        presets = preset_names().join("|")
    );
}

/// Resolve the `--graph SPEC` flag (preset name or `file:PATH`),
/// accepting `--preset NAME` as the legacy alias.
fn graph_arg(args: &Args, seed: u64) -> Result<(Csr, String)> {
    let spec = args
        .get("graph")
        .or_else(|| args.get("preset"))
        .context("--graph <preset|file:PATH> (or --preset) required")?;
    data::load_graph_spec(spec, seed)
}

fn cmd_backends(args: &Args) -> Result<()> {
    println!("execution backends:");
    for (name, desc) in autosage::backend::describe_backends(&artifacts_dir(args)) {
        println!("  {name:<8} {desc}");
    }
    let cfg = Config::from_env().map_err(|e| anyhow!(e))?;
    let kind =
        autosage::backend::resolve_kind(&cfg.backend, &artifacts_dir(args))?;
    println!("selected (AUTOSAGE_BACKEND={}): {kind:?}", cfg.backend);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let seed = args.get_parse("seed", 42u64)?;
    let (g, label) = graph_arg(args, seed)?;
    let feats = InputFeatures::extract(&g, 0);
    println!("graph {label}");
    println!(
        "  rows {}  nnz {}  signature {}",
        g.n_rows,
        g.nnz(),
        graph_signature(&g)
    );
    println!(
        "  degree: avg {:.2}  p50 {:.0}  p90 {:.0}  p99 {:.0}  max {}",
        feats.avg_deg, feats.p50_deg, feats.p90_deg, feats.p99_deg, feats.max_deg
    );
    println!("  skew: gini {:.3}  cv {:.3}", feats.gini, feats.cv);
    println!(
        "  layout: bandwidth {:.4}  head-nnz {:.4}  tile-fill {:.4}",
        feats.band_frac,
        g.head_nnz_frac(),
        feats.tile_fill
    );
    println!("  degree histogram (log2 buckets):");
    let degs: Vec<f64> = g.degrees().iter().map(|&d| d as f64).collect();
    let mut hist = [0usize; 12];
    for &d in &degs {
        let b = (d.max(1.0).log2() as usize).min(11);
        hist[b] += 1;
    }
    for (b, count) in hist.iter().enumerate() {
        if *count > 0 {
            println!(
                "    deg {:>5}..{:<5} {:>6} rows  {}",
                1 << b,
                (1 << (b + 1)) - 1,
                count,
                "#".repeat((count * 60 / g.n_rows).max(1))
            );
        }
    }
    Ok(())
}

fn parse_op(args: &Args) -> Result<Op> {
    let raw = args.get("op").unwrap_or("spmm");
    Op::parse(raw).ok_or_else(|| anyhow!("unknown op {raw:?}"))
}

fn sage_from(args: &Args) -> Result<AutoSage> {
    let mut cfg = Config::from_env().map_err(|e| anyhow!(e))?;
    if let Some(a) = args.get("alpha") {
        cfg.alpha = a.parse().map_err(|_| anyhow!("bad --alpha"))?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    AutoSage::new(&artifacts_dir(args), cfg, None)
}

fn cmd_decide(args: &Args) -> Result<()> {
    let f = args.get_parse("f", 64usize)?;
    let op = parse_op(args)?;
    let seed = args.get_parse("seed", 42u64)?;
    let (g, label) = graph_arg(args, seed)?;
    let mut sage = sage_from(args)?;
    let d = sage.decide(&g, op, f)?;
    println!("graph   : {label}");
    println!("backend : {} ({})", sage.backend_name(), sage.backend_signature());
    println!("key     : {}", d.key);
    println!("choice  : {} ({})", d.choice_label(), d.choice.variant());
    println!("source  : {:?}", d.source);
    println!(
        "probe   : baseline {:.4}ms  best {:.4}ms  wall {:.2}ms  alpha {}",
        d.t_baseline_ms, d.t_star_ms, d.probe_wall_ms, sage.config().alpha
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let f = args.get_parse("f", 64usize)?;
    let op = parse_op(args)?;
    let seed = args.get_parse("seed", 42u64)?;
    let (g, label) = graph_arg(args, seed)?;
    let mut sage = sage_from(args)?;
    let data = probe::synth_operands(op, g.n_rows, f, seed);
    let get = |n: &str| data.dense.get(n).unwrap().as_slice();
    let sw = autosage::util::timing::Stopwatch::start();
    let out = match op {
        Op::Spmm => sage.spmm_auto(&g, get("b"), f)?,
        Op::Sddmm => sage.sddmm_auto(&g, get("x"), get("y"), f)?,
        Op::Attention => sage.attention_auto(&g, get("q"), get("k"), get("v"), f)?,
        Op::Softmax => bail!("softmax runs inside the attention pipeline"),
    };
    let total = sw.ms();
    let sum: f64 = out.iter().map(|&x| x as f64).sum();
    println!(
        "op={} graph={label} F={f} backend={}: {} outputs, checksum {:.4}, end-to-end {:.2}ms",
        op.as_str(),
        sage.backend_name(),
        out.len(),
        sum,
        total
    );
    let mean: Vec<f64> = out.iter().map(|&x| x as f64).collect();
    println!(
        "output stats: mean {:.4}  min {:.4}  max {:.4}",
        stats::mean(&mean),
        stats::min(&mean),
        stats::max(&mean)
    );
    Ok(())
}

fn bench_params(args: &Args) -> Result<(usize, f64)> {
    Ok((
        args.get_parse("iters", 7usize)?,
        args.get_parse("cap-ms", 1500.0f64)?,
    ))
}

/// `autosage bench`: one decision+timing table for any graph spec, with
/// an optional reordered-layout comparison (`--reorder pass,pass`) whose
/// `ReorderReport` deltas render under the table.
fn cmd_bench(args: &Args) -> Result<()> {
    use autosage::bench_kit::render::{graph_bench_csv, render_graph_bench};
    use autosage::bench_kit::runner::graph_bench_rows;
    let seed = args.get_parse("seed", 42u64)?;
    let (g, label) = graph_arg(args, seed)?;
    let f = args.get_parse("f", 64usize)?;
    let (iters, cap) = bench_params(args)?;
    let ops: Vec<Op> = match args.get("ops") {
        Some(list) => list
            .split(',')
            .map(|s| Op::parse(s).ok_or_else(|| anyhow!("unknown op {s:?}")))
            .collect::<Result<Vec<_>>>()?,
        None => vec![parse_op(args)?],
    };
    if ops.iter().any(|&o| o == Op::Softmax) {
        bail!("softmax runs inside the attention pipeline; bench spmm|sddmm|attention");
    }
    let mut sage = sage_from(args)?;
    let mut report_text = String::new();
    let reordered = match args.get("reorder") {
        None => None,
        Some(pass_spec) => {
            let passes = data::parse_passes(pass_spec)?;
            let r = data::reorder(&g, &passes);
            report_text = format!(
                "{}signatures: {} -> {}\n",
                r.report,
                graph_signature(&g),
                graph_signature(&r.graph)
            );
            Some(r)
        }
    };
    let rows = graph_bench_rows(
        &mut sage,
        &g,
        reordered.as_ref().map(|r| &r.graph),
        &ops,
        f,
        iters,
        cap,
    )?;
    let title = format!(
        "bench {label} | F={f} | backend={} | iters={iters}",
        sage.backend_name()
    );
    let mut text = render_graph_bench(&title, &rows);
    if !report_text.is_empty() {
        text.push('\n');
        text.push_str(&report_text);
    }
    let backend = backend_label(args);
    write_output(args.get("out"), &backend, "bench", &text, &graph_bench_csv(&rows))?;
    if let Some(dir) = args.get("out") {
        let dir = Path::new(dir);
        autosage::bench_kit::runner::perf_profile(&rows).save(&dir.join("perf.json"))?;
        let spec_str = args
            .get("graph")
            .or_else(|| args.get("preset"))
            .unwrap_or_else(|| label.as_str());
        let run_id = obs::trace::new_run_id("bench");
        let cfg = Config::from_env().map_err(|e| anyhow!(e))?;
        let mut m = obs::RunManifest::new(
            &run_id,
            "bench",
            seed,
            &backend,
            meta_sidecar(&backend, &cfg),
        );
        m.add_graph(spec_str, &graph_signature(&g), g.n_rows, g.nnz());
        if let Some(r) = &reordered {
            m.add_graph(
                &format!("{spec_str}+reorder"),
                &graph_signature(&r.graph),
                r.graph.n_rows,
                r.graph.nnz(),
            );
        }
        for (layout, op, row) in &rows {
            m.add_metric(&format!("{layout}_{op}_chosen_ms"), row.chosen_ms);
            m.add_metric(&format!("{layout}_{op}_speedup"), row.speedup);
        }
        for rel in ["bench.csv", "bench.txt", "bench.csv.meta.json", "perf.json"] {
            m.add_artifact(dir, rel)?;
        }
        let mpath = m.write(dir)?;
        println!("[manifest {}]", mpath.display());
    }
    Ok(())
}

/// `autosage data`: dataset ingestion verbs (convert | inspect | reorder).
fn cmd_data(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("data action: convert|inspect|reorder|sample")?;
    match action.as_str() {
        "convert" => {
            let inp = args
                .positional
                .get(1)
                .context("usage: data convert <in> <out.asg>")?;
            let out = args
                .positional
                .get(2)
                .context("usage: data convert <in> <out.asg>")?;
            let loaded = data::convert_to_asg(Path::new(inp), Path::new(out))?;
            let g = &loaded.csr;
            let n = &loaded.meta.norm;
            println!(
                "converted {inp} [{}] -> {out}: {} rows, {} cols, {} nnz",
                loaded.meta.format.as_str(),
                g.n_rows,
                g.n_cols,
                g.nnz()
            );
            println!(
                "  normalization: {} raw entries, {} dups merged, {} self-loops ({} dropped)",
                n.n_raw, n.dups_merged, n.self_loops, n.self_loops_dropped
            );
            println!("  signature {}", graph_signature(g));
            Ok(())
        }
        "inspect" => {
            let p = args
                .positional
                .get(1)
                .context("usage: data inspect <path>")?;
            let path = Path::new(p);
            let (loaded, stored_perm) = data::CsrGraph::load_with_perm(path)?;
            let g = &loaded.csr;
            let feats = InputFeatures::extract(g, 0);
            println!("{p} [{}]", loaded.meta.format.as_str());
            println!("  rows {}  cols {}  nnz {}", g.n_rows, g.n_cols, g.nnz());
            println!(
                "  signature {}  layout-digest {:016x}",
                graph_signature(g),
                layout_digest(g)
            );
            println!(
                "  degree: avg {:.2}  p50 {:.0}  p90 {:.0}  p99 {:.0}  max {}",
                feats.avg_deg, feats.p50_deg, feats.p90_deg, feats.p99_deg, feats.max_deg
            );
            println!("  skew: gini {:.3}  cv {:.3}", feats.gini, feats.cv);
            println!(
                "  layout: bandwidth {:.4}  head-nnz {:.4}  tile-fill {:.4}",
                feats.band_frac,
                g.head_nnz_frac(),
                feats.tile_fill
            );
            if let Some(perm) = stored_perm {
                println!(
                    "  reordered snapshot: row permutation stored ({} rows, un-permutable)",
                    perm.len()
                );
            } else if loaded.meta.format != data::GraphFormat::AsgSnapshot {
                let n = &loaded.meta.norm;
                println!(
                    "  normalization: {} raw entries, {} dups merged, {} self-loops ({} dropped)",
                    n.n_raw, n.dups_merged, n.self_loops, n.self_loops_dropped
                );
            }
            Ok(())
        }
        "reorder" => {
            let inp = args
                .positional
                .get(1)
                .context("usage: data reorder <in> [out.asg] --pass p1,p2")?;
            let out = args
                .positional
                .get(2)
                .map(String::as_str)
                .unwrap_or(inp.as_str());
            // Snapshots may be reordered in place; never overwrite a
            // source-format file (.mtx/edge list) with binary .asg.
            if data::GraphFormat::from_path(Path::new(out))
                != data::GraphFormat::AsgSnapshot
            {
                bail!(
                    "reorder output {out:?} must end in .asg (pass an explicit \
                     out.asg to avoid overwriting the source format)"
                );
            }
            let passes =
                data::parse_passes(args.get("pass").unwrap_or("hub-pack,segment-sort"))?;
            let inp_path = Path::new(inp.as_str());
            // Snapshots carry their permutation through recomposition;
            // other formats start from identity.
            let (loaded, prior) = data::CsrGraph::load_with_perm(inp_path)?;
            let g = loaded.csr;
            let r = data::reorder(&g, &passes);
            let total: Vec<u32> = match &prior {
                Some(p0) => r.perm.iter().map(|&np| p0[np as usize]).collect(),
                None => r.perm.clone(),
            };
            data::write_asg(Path::new(out), &r.graph, Some(&total))?;
            print!("{}", r.report);
            println!(
                "signatures: {} -> {}",
                graph_signature(&g),
                graph_signature(&r.graph)
            );
            println!(
                "written {out}: {} rows, {} nnz, row permutation stored",
                r.graph.n_rows,
                r.graph.nnz()
            );
            Ok(())
        }
        "sample" => {
            // Standalone run of the degraded-serving sampler: emit the
            // edge-sampled graph as a `.asg` artifact plus the
            // `SampleReport` whose `max_row_dropped_mass` bounds the
            // per-element SpMM error (times max|B|).
            let inp = args.positional.get(1).context(
                "usage: data sample <in> [out.asg] [--keep-frac F] \
                 [--min-keep-deg D] [--json]",
            )?;
            let keep_frac = args.get_parse("keep-frac", 0.5f64)?;
            let min_keep_deg = args.get_parse("min-keep-deg", 8usize)?;
            if !(keep_frac > 0.0 && keep_frac <= 1.0) {
                bail!("--keep-frac must be in (0, 1], got {keep_frac}");
            }
            let spec = data::SampleSpec { keep_frac, min_keep_deg };
            let (loaded, _perm) =
                data::CsrGraph::load_with_perm(Path::new(inp.as_str()))?;
            let g = loaded.csr;
            let s = data::sample_edges(&g, &spec);
            let out = match args.positional.get(2) {
                None => None,
                Some(out) => {
                    if data::GraphFormat::from_path(Path::new(out.as_str()))
                        != data::GraphFormat::AsgSnapshot
                    {
                        bail!(
                            "sample output {out:?} must end in .asg (pass an \
                             explicit out.asg to avoid overwriting the source \
                             format)"
                        );
                    }
                    data::write_asg(Path::new(out.as_str()), &s.graph, None)?;
                    Some(out.as_str())
                }
            };
            if args.get("json").map(|v| v != "false").unwrap_or(false) {
                use autosage::util::json::Json;
                let r = &s.report;
                let j = Json::obj(vec![
                    ("input", Json::str(inp.as_str())),
                    (
                        "output",
                        out.map(Json::str).unwrap_or(Json::Null),
                    ),
                    ("keep_frac", Json::num(keep_frac)),
                    ("min_keep_deg", Json::from(min_keep_deg)),
                    ("rows_sampled", Json::from(r.rows_sampled)),
                    ("edges_kept", Json::from(r.edges_kept)),
                    ("edges_dropped", Json::from(r.edges_dropped)),
                    ("max_row_dropped_mass", Json::num(r.max_row_dropped_mass)),
                    ("dropped_mass_frac", Json::num(r.dropped_mass_frac)),
                    ("signature_in", Json::str(graph_signature(&g))),
                    ("signature_out", Json::str(graph_signature(&s.graph))),
                ]);
                println!("{}", j.pretty());
            } else {
                println!(
                    "sample {inp} (keep-frac {keep_frac}, min-keep-deg {min_keep_deg})"
                );
                println!("  {}", s.report);
                println!(
                    "  signatures: {} -> {}",
                    graph_signature(&g),
                    graph_signature(&s.graph)
                );
                println!(
                    "  error bound: |Y_full - Y_sampled| <= {:.6} * max|B| per element",
                    s.report.max_row_dropped_mass
                );
                if let Some(out) = out {
                    println!(
                        "written {out}: {} rows, {} nnz",
                        s.graph.n_rows,
                        s.graph.nnz()
                    );
                }
            }
            Ok(())
        }
        other => bail!("unknown data action {other:?} (convert|inspect|reorder|sample)"),
    }
}

/// The backend label for output sidecars: the RESOLVED engine
/// (`native`/`pjrt`), not the raw `auto` choice string — two runs on
/// different actual backends must not produce identical provenance.
fn backend_label(args: &Args) -> String {
    let choice = args
        .get("backend")
        .map(str::to_string)
        .unwrap_or_else(|| {
            Config::from_env()
                .map(|c| c.backend)
                .unwrap_or_else(|_| "auto".to_string())
        });
    match autosage::backend::resolve_kind(&choice, &artifacts_dir(args)) {
        Ok(autosage::backend::BackendKind::Native) => "native".to_string(),
        Ok(autosage::backend::BackendKind::Pjrt) => "pjrt".to_string(),
        Err(_) => choice,
    }
}

fn write_output(
    out_dir: Option<&str>,
    backend: &str,
    stem: &str,
    text: &str,
    csv: &autosage::util::csv::CsvTable,
) -> Result<()> {
    println!("{text}");
    if let Some(dir) = out_dir {
        let dir = Path::new(dir);
        std::fs::create_dir_all(dir)?;
        csv.write_to(&dir.join(format!("{stem}.csv")))?;
        std::fs::write(dir.join(format!("{stem}.txt")), text)?;
        let cfg = Config::from_env().map_err(|e| anyhow!(e))?;
        std::fs::write(
            dir.join(format!("{stem}.csv.meta.json")),
            meta_sidecar(backend, &cfg).pretty(),
        )?;
        println!(
            "[written to {}/{stem}.{{csv,txt,csv.meta.json}}]",
            dir.display()
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("table id required (2..12)")?;
    let (iters, cap) = bench_params(args)?;
    let out = run_table(&artifacts_dir(args), args.get("backend"), id, iters, cap)?;
    write_output(
        args.get("out"),
        &backend_label(args),
        &format!("table{id}"),
        &out.text,
        &out.csv,
    )
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("figure id required (1..7)")?;
    let (iters, cap) = bench_params(args)?;
    let (text, csv) =
        run_figure(&artifacts_dir(args), args.get("backend"), id, iters, cap)?;
    write_output(
        args.get("out"),
        &backend_label(args),
        &format!("figure{id}"),
        &text,
        &csv,
    )
}

fn cmd_all(args: &Args) -> Result<()> {
    let (iters, cap) = bench_params(args)?;
    let out_dir = args.get("out").unwrap_or("results");
    let backend = backend_label(args);
    let sw = autosage::util::timing::Stopwatch::start();
    for id in table_ids() {
        let out = run_table(&artifacts_dir(args), args.get("backend"), id, iters, cap)?;
        write_output(Some(out_dir), &backend, &format!("table{id}"), &out.text, &out.csv)?;
    }
    for id in ["1", "2", "3", "4", "5", "6", "7"] {
        let (text, csv) =
            run_figure(&artifacts_dir(args), args.get("backend"), id, iters, cap)?;
        write_output(Some(out_dir), &backend, &format!("figure{id}"), &text, &csv)?;
    }
    println!("all tables+figures regenerated in {:.1}s", sw.ms() / 1e3);
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    use autosage::server::{run_load_traced, LoadSpec, ServerPool};
    let smoke = args.get("smoke").map(|v| v != "false").unwrap_or(false);
    let mut cfg = Config::from_env().map_err(|e| anyhow!(e))?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    // Fresh in-memory schedule cache by default so the bench measures
    // cold probes + warm replay; `--cache FILE` opts into persistence.
    cfg.cache_path = args.get("cache").unwrap_or("").to_string();
    // `--model FILE.asgm` attaches a trained cost model (overrides
    // AUTOSAGE_MODEL): cold keys above AUTOSAGE_MODEL_CONFIDENCE skip
    // the micro-probe.
    if let Some(mp) = args.get("model") {
        cfg.model_path = mp.to_string();
    }
    cfg.serve_workers = args.get_parse("workers", cfg.serve_workers)?;
    // `--deadline-ms` overrides AUTOSAGE_DEADLINE_MS for this run.
    cfg.deadline_ms = args.get_parse("deadline-ms", cfg.deadline_ms)?;
    let mut spec = if smoke { LoadSpec::smoke() } else { LoadSpec::bench() };
    spec.clients = args.get_parse("clients", spec.clients)?;
    spec.requests_per_client = args.get_parse("requests", spec.requests_per_client)?;
    spec.f = args.get_parse("f", spec.f)?;
    spec.seed = args.get_parse("seed", spec.seed)?;
    // `--retries N` turns on bounded retry with jittered backoff for
    // QueueFull rejections and deadline sheds.
    spec.max_retries = args.get_parse("retries", spec.max_retries)?;
    // `--approx-frac P` marks that fraction of SpMM requests as opt-in
    // approximate: they take the edge-sampled degraded path regardless
    // of queue depth and their replies carry the error bound.
    spec.approx_frac = args.get_parse("approx-frac", spec.approx_frac)?;
    if !(0.0..=1.0).contains(&spec.approx_frac) {
        bail!("--approx-frac must be in [0, 1], got {}", spec.approx_frac);
    }
    if let Some(p) = args.get("presets") {
        spec.presets = p.split(',').map(str::to_string).collect();
    }
    if let Some(o) = args.get("ops") {
        spec.ops = o
            .split(',')
            .map(|s| Op::parse(s).ok_or_else(|| anyhow!("unknown op {s:?}")))
            .collect::<Result<Vec<_>>>()?;
    }
    // The flight recorder and metrics registry only run when their
    // artifacts have somewhere to land: `--out DIR` gets trace.jsonl +
    // metrics.prom + audit.jsonl + perf.json + manifest.json next to
    // the serving CSV. Sampling/ring/flush shape comes from the
    // AUTOSAGE_TRACE_* knobs; the sampling hash is seeded by `--seed`
    // so reruns keep the identical sampled trace-id set.
    let run_id = obs::trace::new_run_id("serve-bench");
    let recorder = args.get("out").map(|_| {
        std::sync::Arc::new(
            obs::trace::Recorder::with_sampling(&run_id, cfg.trace_sample, spec.seed)
                .with_capacity(cfg.trace_ring),
        )
    });
    let registry = args
        .get("out")
        .map(|_| std::sync::Arc::new(obs::metrics::MetricsRegistry::new()));
    if let (Some(rec), Some(dir)) = (&recorder, args.get("out")) {
        if cfg.trace_flush_ms > 0 {
            let dir = Path::new(dir);
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating --out dir {}", dir.display()))?;
            rec.set_auto_flush(
                dir.join("trace.jsonl"),
                std::time::Duration::from_millis(cfg.trace_flush_ms as u64),
            );
        }
    }
    let pool = std::sync::Arc::new(ServerPool::spawn_observed(
        artifacts_dir(args),
        cfg.clone(),
        recorder.clone(),
        registry.clone(),
    )?);
    let report = run_load_traced(std::sync::Arc::clone(&pool), &spec, recorder.clone())?;
    println!("{}", report.text);
    if let Some(dir) = args.get("out") {
        let dir = Path::new(dir);
        let backend = backend_label(args);
        autosage::telemetry::write_csv_with_sidecar(
            dir,
            "serve_bench",
            &report.csv,
            &backend,
            &cfg,
        )?;
        if let Some(rec) = &recorder {
            rec.flush_jsonl(&dir.join("trace.jsonl"))?;
        }
        if let Some(reg) = &registry {
            let snap = autosage::server::prometheus_snapshot(
                reg,
                Some(pool.metrics()),
                recorder.as_deref(),
            );
            std::fs::write(dir.join("metrics.prom"), &snap)
                .context("writing metrics.prom")?;
            reg.write_audit_jsonl_capped(
                &dir.join("audit.jsonl"),
                cfg.log_rotate_bytes as u64,
            )?;
        }
        report.perf_profile().save(&dir.join("perf.json"))?;

        let mut m = obs::RunManifest::new(
            &run_id,
            "serve-bench",
            spec.seed,
            &backend,
            meta_sidecar(&backend, &cfg),
        );
        for (pi, name) in spec.presets.iter().enumerate() {
            let (g, _label) =
                data::load_graph_spec(name, spec.seed.wrapping_add(pi as u64))?;
            m.add_graph(name, &graph_signature(&g), g.n_rows, g.nnz());
        }
        m.add_metric("requests_total", report.total as f64);
        m.add_metric("ok", report.ok as f64);
        m.add_metric("errors", report.errors as f64);
        m.add_metric("oracle_mismatches", report.mismatches as f64);
        m.add_metric("wall_ms", report.wall_ms);
        m.add_metric("throughput_rps", report.throughput_rps);
        m.add_metric("p50_ms", report.p50_ms);
        m.add_metric("p95_ms", report.p95_ms);
        m.add_metric("p99_ms", report.p99_ms);
        m.add_metric("probes", report.probes as f64);
        m.add_metric("model_predictions", report.model_predictions as f64);
        m.add_metric("unique_keys", report.unique_keys as f64);
        m.add_metric("shed", report.shed as f64);
        m.add_metric("degraded", report.degraded as f64);
        m.add_metric("worker_panics", report.worker_panics as f64);
        m.add_metric("faults_injected", report.faults_injected as f64);
        m.add_metric("quarantined", report.quarantined as f64);
        m.add_metric("retries", report.retries as f64);
        m.add_metric("approx_requested", report.approx_requested as f64);
        m.add_metric("model_reloads", pool.model_reloads() as f64);
        m.add_metric("model_rollbacks", pool.model_rollbacks() as f64);
        for rel in [
            "serve_bench.csv",
            "serve_bench.csv.meta.json",
            "perf.json",
        ] {
            m.add_artifact(dir, rel)?;
        }
        if recorder.is_some() {
            m.add_artifact(dir, "trace.jsonl")?;
        }
        if registry.is_some() {
            m.add_artifact(dir, "metrics.prom")?;
            m.add_artifact(dir, "audit.jsonl")?;
        }
        // Chaos evidence: the quarantine log lands next to the trace so
        // a failed run names the exact poisoning requests.
        if !pool.resilience().quarantine.is_empty() {
            pool.resilience().quarantine.write_jsonl_capped(
                &dir.join("quarantine.jsonl"),
                cfg.log_rotate_bytes as u64,
            )?;
            m.add_artifact(dir, "quarantine.jsonl")?;
        }
        let mpath = m.write(dir)?;
        println!(
            "[written to {}/serve_bench.{{csv,csv.meta.json}} + trace.jsonl, \
             metrics.prom, audit.jsonl, perf.json, {}]",
            dir.display(),
            mpath.display()
        );
    }
    // Shutdown flushes (cache persist, watcher teardown) are fault
    // sites too: drop the pool before writing `recovery.json` so it
    // captures the complete injected-fault log and recovery counters
    // for the whole process lifetime. The file deliberately stays out
    // of the manifest — it is the cross-run determinism witness (CI
    // `cmp`s it between two same-seed runs) and must not absorb run
    // ids or timestamps.
    let (model_reloads, model_rollbacks) =
        (pool.model_reloads(), pool.model_rollbacks());
    drop(pool);
    if let Some(dir) = args.get("out") {
        let path = Path::new(dir).join("recovery.json");
        std::fs::write(
            &path,
            recovery_report_json(model_reloads, model_rollbacks),
        )
        .with_context(|| format!("writing {}", path.display()))?;
        println!("[recovery {}]", path.display());
    }
    // Failures the run *chose* (injected faults, deadline sheds) are
    // expected under chaos/overload; anything beyond them is a real
    // regression and still fails the bench.
    let expected = report.injected_errors + report.errors_by_kind.deadline;
    let hard_errors = report.errors.saturating_sub(expected);
    if hard_errors > 0 {
        bail!(
            "{} of {} requests failed ({} expected: injected faults + deadline sheds)",
            report.errors,
            report.total,
            expected
        );
    }
    if report.mismatches > 0 {
        bail!(
            "{} of {} responses mismatched the native oracle",
            report.mismatches,
            report.total
        );
    }
    Ok(())
}

/// `recovery.json` body: the sorted applied-fault log (site, per-site
/// op index, kind), the process-wide recovery counters, and the
/// hot-reload totals. Pure function of what the run did — two same-seed
/// runs with identical per-site op counts produce identical bytes,
/// which is exactly what the CI crash-smoke job `cmp`s.
fn recovery_report_json(model_reloads: u64, model_rollbacks: u64) -> String {
    use autosage::util::iofault;
    use autosage::util::json::Json;
    let injector = iofault::installed();
    let faults: Vec<Json> = injector
        .as_ref()
        .map(|i| i.log_snapshot())
        .unwrap_or_default()
        .into_iter()
        .map(|(site, op, kind)| {
            Json::obj(vec![
                ("site", Json::str(site)),
                ("op", Json::from(op as usize)),
                ("kind", Json::str(kind.as_str())),
            ])
        })
        .collect();
    let counters: Vec<(&str, Json)> = iofault::recovery()
        .snapshot()
        .into_iter()
        .map(|(k, v)| (k, Json::from(v as usize)))
        .collect();
    let mut text = Json::obj(vec![
        (
            "injected_total",
            Json::from(
                injector.map(|i| i.injected_total()).unwrap_or(0) as usize,
            ),
        ),
        ("io_faults", Json::Arr(faults)),
        ("recovery", Json::obj(counters)),
        ("model_reloads", Json::from(model_reloads as usize)),
        ("model_rollbacks", Json::from(model_rollbacks as usize)),
    ])
    .pretty();
    text.push('\n');
    text
}

/// `autosage train`: mine probe + audit telemetry into a trained cost
/// model (`.asgm`). Deterministic: the same telemetry and the same
/// `--seed` produce a byte-identical model file.
fn cmd_train(args: &Args) -> Result<()> {
    use autosage::model::{
        class_summary, examples_from_audit, examples_from_cache, merge_and_cap,
        write_model, CostModel, Example, DEFAULT_MAX_DEPTH, TRAIN_EXAMPLE_CAP,
    };
    use autosage::obs::report::calibration_table;

    let out = args
        .get("out")
        .context("--out MODEL.asgm required (where to write the trained model)")?;
    let from = args.get("from");
    let cache_path = args.get("cache");
    if from.is_none() && cache_path.is_none() {
        bail!(
            "nothing to mine: pass --from DIR (a serve-bench --out directory \
             with audit.jsonl) and/or --cache FILE (a persisted schedule cache)"
        );
    }
    let seed = args.get_parse("seed", 42u64)?;
    let max_depth = args.get_parse("max-depth", DEFAULT_MAX_DEPTH)?;

    // Source 1: probe-resolved schedule-cache entries (the ones that
    // carry feature vectors).
    let mut sources: Vec<Vec<Example>> = Vec::new();
    if let Some(cp) = cache_path {
        let cache = ScheduleCache::load(Path::new(cp))?;
        let ex = examples_from_cache(&cache);
        println!(
            "cache {cp}: {} entries, {} probe-labeled examples",
            cache.len(),
            ex.len()
        );
        sources.push(ex);
    }
    // Source 2 (mined later, so fresher audit rows win dedup): the
    // audit stream's probe outcomes, which also feed the calibration
    // damping table.
    let mut calib = Vec::new();
    if let Some(dir) = from {
        let audit_path = Path::new(dir).join("audit.jsonl");
        let text = std::fs::read_to_string(&audit_path)
            .with_context(|| format!("reading {}", audit_path.display()))?;
        let ex = examples_from_audit(&text)?;
        calib = calibration_table(&text)?;
        println!(
            "audit {}: {} labeled examples, {} calibration rows",
            audit_path.display(),
            ex.len(),
            calib.len()
        );
        sources.push(ex);
    }
    let examples = merge_and_cap(sources, TRAIN_EXAMPLE_CAP, seed);
    println!("training set: {} examples (cap {TRAIN_EXAMPLE_CAP})", examples.len());
    for (op, classes) in class_summary(&examples) {
        let detail = classes
            .iter()
            .map(|(v, c)| format!("{v} x{c}"))
            .collect::<Vec<_>>()
            .join(", ");
        println!("  {op:<10} {detail}");
    }

    let model = CostModel::train(&examples, &calib, seed, max_depth)?;
    let out_path = Path::new(out);
    write_model(out_path, &model)?;
    let bytes = std::fs::metadata(out_path).map(|m| m.len()).unwrap_or(0);
    for op in model.op_names() {
        println!(
            "  {op:<10} tree depth {}",
            model.ops[op].tree.depth()
        );
    }
    println!(
        "written {out} ({bytes} bytes, seed {seed}, max depth {max_depth}) — \
         serve with --model {out} (threshold: AUTOSAGE_MODEL_CONFIDENCE)"
    );
    Ok(())
}

/// `autosage manifest`: run-manifest verbs.
fn cmd_manifest(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("manifest action: validate <manifest.json>")?;
    match action.as_str() {
        "validate" => {
            let p = args
                .positional
                .get(1)
                .context("usage: manifest validate <manifest.json>")?;
            let rep = obs::manifest::validate(Path::new(p))?;
            println!(
                "manifest OK: run {} (kind {}, {} artifacts verified)",
                rep.run_id, rep.kind, rep.n_artifacts
            );
            Ok(())
        }
        other => bail!("unknown manifest action {other:?} (validate)"),
    }
}

/// `autosage perf`: perf-profile verbs (the CI regression gate).
fn cmd_perf(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("perf action: compare <baseline.json> <candidate.json>")?;
    match action.as_str() {
        "compare" => {
            let b = args
                .positional
                .get(1)
                .context("usage: perf compare <baseline.json> <candidate.json>")?;
            let c = args
                .positional
                .get(2)
                .context("usage: perf compare <baseline.json> <candidate.json>")?;
            let base = obs::PerfProfile::load(Path::new(b))?;
            let cand = obs::PerfProfile::load(Path::new(c))?;
            let rep = obs::compare(&base, &cand);
            print!("{}", rep.render(b, c));
            if !rep.passed() {
                bail!(
                    "perf gate failed: {} regressed, {} missing",
                    rep.regressions,
                    rep.missing
                );
            }
            Ok(())
        }
        other => bail!("unknown perf action {other:?} (compare)"),
    }
}

/// `autosage metrics`: Prometheus-snapshot verbs.
fn cmd_metrics(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("metrics action: validate|show <metrics.prom>")?;
    let p = args
        .positional
        .get(1)
        .with_context(|| format!("usage: metrics {action} <metrics.prom>"))?;
    let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
    match action.as_str() {
        "validate" => {
            let snap = obs::metrics::validate_serving_snapshot(&text)
                .with_context(|| format!("validating {p}"))?;
            println!("metrics OK: {p} ({} series, all required present)", snap.len());
            Ok(())
        }
        "show" => {
            let snap = obs::metrics::parse_prometheus(&text)?;
            for (name, value) in &snap {
                println!("{name} = {value}");
            }
            Ok(())
        }
        other => bail!("unknown metrics action {other:?} (validate|show)"),
    }
}

/// `autosage obs`: offline observability reports over run artifacts.
fn cmd_obs(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("obs action: report <dir>")?;
    match action.as_str() {
        "report" => {
            let dir = args
                .positional
                .get(1)
                .context("usage: obs report <dir> [--json] (a serve-bench --out directory)")?;
            if args.get("json").map(|v| v != "false").unwrap_or(false) {
                let j = obs::report::report_dir_json(Path::new(dir))?;
                println!("{j}");
            } else {
                let text = obs::report::report_dir(Path::new(dir))?;
                print!("{text}");
            }
            Ok(())
        }
        other => bail!("unknown obs action {other:?} (report)"),
    }
}

fn cmd_cache(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .context("cache action: dump|clear|stats")?;
    let path = PathBuf::from(args.get("path").unwrap_or("autosage_cache.json"));
    match action.as_str() {
        "dump" => {
            let cache = ScheduleCache::load(&path)?;
            println!("cache {} ({} entries)", path.display(), cache.len());
            for (k, v) in cache.dump() {
                println!(
                    "  {k} -> {} (t_b {:.4}ms, t* {:.4}ms, alpha {})",
                    v.variant, v.t_baseline_ms, v.t_star_ms, v.alpha
                );
            }
            Ok(())
        }
        "clear" => {
            if path.exists() {
                std::fs::remove_file(&path)?;
                println!("removed {}", path.display());
            } else {
                println!("no cache at {}", path.display());
            }
            Ok(())
        }
        "stats" => {
            let cache = ScheduleCache::load(&path)?;
            println!("cache {} — {} entries", path.display(), cache.len());
            println!(
                "lifetime counters: {} hits, {} misses",
                cache.hits, cache.misses
            );
            let mut per_op: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
            for (k, v) in cache.dump() {
                let op = k.rsplit('|').next().unwrap_or("?").to_string();
                *per_op.entry(op).or_default().entry(v.variant).or_default() += 1;
            }
            for (op, variants) in per_op {
                let n: usize = variants.values().sum();
                let detail = variants
                    .iter()
                    .map(|(v, c)| format!("{v} x{c}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                println!("  {op:<10} {n} entries ({detail})");
            }
            Ok(())
        }
        other => bail!("unknown cache action {other:?}"),
    }
}

/// `autosage doctor`: audit — and with `--fix`, repair — the durable
/// state of a run directory. It reuses the exact salvage paths the
/// serving layer runs at load time (valid-prefix JSONL recovery,
/// per-entry cache quarantine, generational `.asg`/`.asgm` fallback,
/// manifest self-hash validation), so what doctor reports recovered is
/// what a restarted pool would actually see.
fn cmd_doctor(args: &Args) -> Result<()> {
    use autosage::server::QuarantineLog;
    use autosage::util::iofault;
    use autosage::util::json::Json;

    let dir = args
        .positional
        .first()
        .context("usage: doctor <DIR> [--fix] [--json] [--cache FILE]")?;
    let dir = Path::new(dir.as_str());
    if !dir.is_dir() {
        bail!("doctor: {} is not a directory", dir.display());
    }
    let fix = args.get("fix").map(|v| v != "false").unwrap_or(false);
    let as_json = args.get("json").map(|v| v != "false").unwrap_or(false);

    let mut rows: Vec<(String, String, String)> = Vec::new();
    let mut issues = 0usize;
    let mut repaired = 0usize;

    // Manifest first: its artifact hashes describe the directory as the
    // run wrote it, before any --fix rewrite changes them.
    let manifest = dir.join("manifest.json");
    if manifest.exists() {
        match obs::manifest::validate(&manifest) {
            Ok(rep) => rows.push((
                "manifest.json".into(),
                "ok".into(),
                format!("run {} ({} artifacts verified)", rep.run_id, rep.n_artifacts),
            )),
            Err(e) => {
                issues += 1;
                rows.push(("manifest.json".into(), "invalid".into(), format!("{e:#}")));
            }
        }
    }

    // JSONL streams: valid-prefix salvage. `kept` counts schema-valid
    // entries for quarantine.jsonl (stricter) and JSON-valid lines for
    // the rest; either way the keepable lines are a prefix of the file,
    // so a --fix rewrite of `lines[..kept]` is always sound.
    for name in ["trace.jsonl", "audit.jsonl", "quarantine.jsonl"] {
        let path = dir.join(name);
        if !path.exists() {
            continue;
        }
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                issues += 1;
                rows.push((name.into(), "unreadable".into(), e.to_string()));
                continue;
            }
        };
        let (kept, dropped) = if name == "quarantine.jsonl" {
            let (entries, dropped) = QuarantineLog::salvage_jsonl(&text);
            (entries.len(), dropped)
        } else {
            let (lines, dropped) = iofault::salvage_jsonl(&text);
            (lines.len(), dropped)
        };
        if dropped == 0 {
            rows.push((name.into(), "ok".into(), format!("{kept} lines")));
        } else if fix {
            let (lines, _) = iofault::salvage_jsonl(&text);
            let mut out = lines[..kept.min(lines.len())].join("\n");
            if !out.is_empty() {
                out.push('\n');
            }
            std::fs::write(&path, out)
                .with_context(|| format!("rewriting {}", path.display()))?;
            issues += 1;
            repaired += 1;
            rows.push((
                name.into(),
                "repaired".into(),
                format!("kept {kept} lines, dropped {dropped} torn tail lines"),
            ));
        } else {
            issues += 1;
            rows.push((
                name.into(),
                "torn".into(),
                format!(
                    "{kept} valid lines, {dropped} dropped \
                     (--fix rewrites the valid prefix)"
                ),
            ));
        }
    }

    // Schedule cache: per-entry quarantine or whole-file reset, exactly
    // as a restarting pool would load it. The audit path never mutates;
    // --fix persists the salvaged view (or resets a hopeless file,
    // keeping the original as `<path>.corrupt`).
    let cache_path = args.get("cache").map(PathBuf::from).or_else(|| {
        let p = dir.join("autosage_cache.json");
        p.exists().then_some(p)
    });
    if let Some(cp) = cache_path {
        if !cp.exists() {
            bail!("doctor: no schedule cache at {}", cp.display());
        }
        let label = cp
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| cp.display().to_string());
        match ScheduleCache::load(&cp) {
            Ok(cache) if cache.quarantined == 0 => {
                rows.push((label, "ok".into(), format!("{} entries", cache.len())));
            }
            Ok(mut cache) => {
                issues += 1;
                if fix {
                    cache.save()?;
                    repaired += 1;
                    rows.push((
                        label,
                        "repaired".into(),
                        format!(
                            "{} corrupt entries quarantined, {} kept",
                            cache.quarantined,
                            cache.len()
                        ),
                    ));
                } else {
                    rows.push((
                        label,
                        "degraded".into(),
                        format!(
                            "{} corrupt entries quarantined on load, {} kept \
                             (--fix persists the salvaged view)",
                            cache.quarantined,
                            cache.len()
                        ),
                    ));
                }
            }
            Err(e) => {
                issues += 1;
                if fix {
                    let (mut cache, _salvage) = ScheduleCache::load_salvaged(&cp);
                    cache.save()?;
                    repaired += 1;
                    rows.push((
                        label,
                        "reset".into(),
                        "file-level corruption: original kept as .corrupt, \
                         cache restarted empty"
                            .into(),
                    ));
                } else {
                    rows.push((
                        label,
                        "corrupt".into(),
                        format!("{e:#} (--fix moves it aside and restarts empty)"),
                    ));
                }
            }
        }
    }

    // Generational binary artifacts: current generation good, `.prev`
    // fallback needed, or terminal corruption (both generations bad).
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.ends_with(".asg") || n.ends_with(".asgm"))
        .collect();
    names.sort();
    for name in names {
        let path = dir.join(&name);
        let outcome = if name.ends_with(".asgm") {
            autosage::model::read_model_generational(&path).map(|(_, fb)| fb)
        } else {
            data::read_asg_generational(&path).map(|(_, fb)| fb)
        };
        match outcome {
            Ok(false) => {
                rows.push((name, "ok".into(), "current generation".into()));
            }
            Ok(true) => {
                issues += 1;
                if fix {
                    // Promote the readable previous generation back to
                    // current so the next load pays no fallback.
                    let mut prev = path.as_os_str().to_os_string();
                    prev.push(".prev");
                    std::fs::copy(PathBuf::from(prev), &path)
                        .with_context(|| format!("restoring {}", path.display()))?;
                    repaired += 1;
                    rows.push((
                        name,
                        "repaired".into(),
                        "corrupt current generation replaced by .prev".into(),
                    ));
                } else {
                    rows.push((
                        name,
                        "stale".into(),
                        "current generation corrupt, previous generation \
                         readable (--fix restores it)"
                            .into(),
                    ));
                }
            }
            Err(e) => {
                issues += 1;
                let detail = match e.downcast_ref::<iofault::CorruptArtifact>() {
                    Some(c) => {
                        format!("corrupt, no usable previous generation: {}", c.detail)
                    }
                    None => format!("{e:#}"),
                };
                rows.push((name, "corrupt".into(), detail));
            }
        }
    }

    if as_json {
        let artifacts: Vec<Json> = rows
            .iter()
            .map(|(name, status, detail)| {
                Json::obj(vec![
                    ("artifact", Json::str(name.as_str())),
                    ("status", Json::str(status.as_str())),
                    ("detail", Json::str(detail.as_str())),
                ])
            })
            .collect();
        let counters: Vec<(&str, Json)> = iofault::recovery()
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::from(v as usize)))
            .collect();
        let j = Json::obj(vec![
            ("dir", Json::str(dir.display().to_string())),
            ("checked", Json::from(rows.len())),
            ("issues", Json::from(issues)),
            ("repaired", Json::from(repaired)),
            ("artifacts", Json::Arr(artifacts)),
            ("recovery", Json::obj(counters)),
        ]);
        println!("{}", j.pretty());
    } else {
        println!(
            "doctor {}: {} artifacts checked, {} issues, {} repaired",
            dir.display(),
            rows.len(),
            issues,
            repaired
        );
        for (name, status, detail) in &rows {
            println!("  {name:<24} {status:<9} {detail}");
        }
        if issues > repaired && !fix {
            println!("  (re-run with --fix to repair what salvage recovered)");
        }
    }
    Ok(())
}
