//! Kernel-catalog runtime: the artifact manifest (parsed from
//! `artifacts/manifest.json` or synthesized for the native backend),
//! host tensors, and — behind the `pjrt` cargo feature — the PJRT
//! client that compiles and executes AOT HLO artifacts.

#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use client::Device;
pub use manifest::{ArtifactEntry, InputSpec, Manifest};
pub use tensor::Tensor;
