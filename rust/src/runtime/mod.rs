//! PJRT runtime: loads AOT artifacts (HLO text) produced by
//! `python/compile/aot.py`, compiles them once, and executes them on the
//! request path. Python never runs here.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::Device;
pub use manifest::{ArtifactEntry, InputSpec, Manifest};
pub use tensor::Tensor;
