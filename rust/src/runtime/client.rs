//! The PJRT device wrapper: compile-once executable cache + execution.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Artifacts
//! are compiled lazily on first use and cached for the process lifetime
//! (the paper's steady-state replay is "near-zero overhead" because both
//! the schedule *and* the compiled kernel are cached).
//!
//! PJRT handles are not `Send`; the coordinator owns a `Device` on a
//! single service thread (see `coordinator::queue`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::ArtifactEntry;
use super::tensor::Tensor;

/// A PJRT device with a lazy executable cache.
pub struct Device {
    client: xla::PjRtClient,
    /// artifact name -> compiled executable
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile-time bookkeeping for telemetry (§8.6 warm-up accounting)
    compile_ms: RefCell<HashMap<String, f64>>,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Device {
            client,
            executables: RefCell::new(HashMap::new()),
            compile_ms: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn platform_version(&self) -> String {
        self.client.platform_version()
    }

    /// Device signature for cache keys (paper §4.2 `device_sig()`).
    pub fn signature(&self) -> String {
        crate::graph::signature::device_signature(
            &self.platform_name(),
            &self.platform_version(),
        )
    }

    /// Compile (or fetch cached) executable for an artifact.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(&entry.name) {
            return Ok(exe.clone());
        }
        let sw = crate::util::timing::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&entry.path)
            .map_err(|e| anyhow!("loading {}: {e}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", entry.name))?;
        let exe = Rc::new(exe);
        self.compile_ms.borrow_mut().insert(entry.name.clone(), sw.ms());
        self.executables
            .borrow_mut()
            .insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Total compile time spent so far (telemetry).
    pub fn total_compile_ms(&self) -> f64 {
        self.compile_ms.borrow().values().sum()
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }

    /// Upload host tensors to device-resident buffers (done once per
    /// graph; the probe/bench timing loops then run device-to-device).
    pub fn upload(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: {} inputs supplied, artifact takes {}",
                entry.name,
                inputs.len(),
                entry.inputs.len()
            );
        }
        let mut bufs = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&entry.inputs) {
            t.check_spec(spec)
                .with_context(|| format!("artifact {}", entry.name))?;
            let buf = match t {
                Tensor::F32 { data, shape } => self
                    .client
                    .buffer_from_host_buffer(data, shape, None),
                Tensor::I32 { data, shape } => self
                    .client
                    .buffer_from_host_buffer(data, shape, None),
            }
            .map_err(|e| anyhow!("upload {}/{}: {e}", entry.name, spec.name))?;
            bufs.push(buf);
        }
        Ok(bufs)
    }

    /// Execute on pre-uploaded buffers; returns the raw output buffer
    /// (still on device). The artifact returns a 1-tuple.
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        bufs: &[xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let outs = exe
            .execute_b(bufs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        // outs is [replicas][outputs]; single replica, single (tuple) output.
        outs.into_iter()
            .next()
            .and_then(|v| v.into_iter().next())
            .ok_or_else(|| anyhow!("execute returned no outputs"))
    }

    /// Fetch an output buffer to host as f32. Artifacts are lowered with
    /// an array root (return_tuple=False); tolerate tuple roots too for
    /// forward-compatibility with hand-authored HLO.
    pub fn fetch_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e}"))?;
        match lit.to_vec::<f32>() {
            Ok(v) => Ok(v),
            Err(_) => {
                let out = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e}"))?;
                out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
            }
        }
    }

    /// Convenience: upload, execute, fetch.
    pub fn run_f32(&self, entry: &ArtifactEntry, inputs: &[Tensor]) -> Result<Vec<f32>> {
        let exe = self.load(entry)?;
        let bufs = self.upload(entry, inputs)?;
        let out = self.execute_buffers(&exe, &bufs)?;
        self.fetch_f32(&out)
    }

    /// Block until an execution's output is materialized (timing fence).
    /// PJRT CPU executes eagerly-async; a 4-byte raw host copy is the
    /// cheapest synchronization (the CUDA-event analog). Falls back to a
    /// full literal fetch for tuple-rooted outputs.
    pub fn sync(&self, buf: &xla::PjRtBuffer) -> Result<()> {
        let mut probe = [0f32; 1];
        if buf.copy_raw_to_host_sync(&mut probe, 0).is_ok() {
            return Ok(());
        }
        let _ = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e}"))?;
        Ok(())
    }
}
