//! Host-side tensors handed to the PJRT runtime.
//!
//! A deliberately tiny type: dense row-major data + shape, f32 or i32.
//! Shape is validated against the artifact's `InputSpec` at call time so
//! a packing bug fails loudly instead of feeding the kernel garbage.

use anyhow::{bail, Result};

use super::manifest::InputSpec;

/// Row-major dense tensor, f32 or i32.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32 { data, shape }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "s32",
        }
    }

    /// Check this tensor against an artifact input spec.
    pub fn check_spec(&self, spec: &InputSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input {}: dtype {} != artifact dtype {}",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input {}: shape {:?} != artifact shape {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_query() {
        let t = Tensor::f32(vec![0.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), "f32");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::i32(vec![1, 2, 3], vec![2, 2]);
    }

    #[test]
    fn spec_check() {
        let spec = InputSpec {
            name: "b".into(),
            dtype: "f32".into(),
            shape: vec![4, 2],
        };
        assert!(Tensor::f32(vec![0.0; 8], vec![4, 2]).check_spec(&spec).is_ok());
        assert!(Tensor::f32(vec![0.0; 8], vec![2, 4]).check_spec(&spec).is_err());
        assert!(Tensor::i32(vec![0; 8], vec![4, 2]).check_spec(&spec).is_err());
    }
}
