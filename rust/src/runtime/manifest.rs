//! Artifact manifest: the contract between the AOT compile path and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One named input of an artifact (call order is significant).
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub dtype: String, // "f32" | "s32"
    pub shape: Vec<usize>,
}

/// One compiled artifact: an (op, variant, shape-bucket) instantiation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub op: String,
    pub variant: String,
    pub params: BTreeMap<String, i64>,
    pub path: PathBuf, // absolute
    pub inputs: Vec<InputSpec>,
    /// Preset tag this bucket was sized for (informational).
    pub preset_tag: Option<String>,
}

impl ArtifactEntry {
    pub fn param(&self, key: &str) -> Option<i64> {
        self.params.get(key).copied()
    }
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.param(key).and_then(|v| usize::try_from(v).ok())
    }
    /// Required parameter, or an error naming the entry and the param
    /// (the facade and the native kernels must never panic on a
    /// malformed catalog entry).
    pub fn require_usize(&self, key: &str) -> Result<usize> {
        self.param_usize(key).ok_or_else(|| {
            anyhow!("artifact {}: missing required param {key:?}", self.name)
        })
    }
    /// The preset tag this bucket was sized for (informational).
    pub fn preset(&self) -> Option<&str> {
        self.preset_tag.as_deref()
    }
    /// Whether this is a probe-size (n_pad = 512) bucket.
    pub fn is_probe(&self) -> bool {
        self.name.contains("_probe_")
    }
}

/// The parsed manifest: all artifacts under one directory.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts`", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let entries_json = root
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing entries[]"))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let op = e.get("op").as_str().unwrap_or_default().to_string();
            let variant = e.get("variant").as_str().unwrap_or_default().to_string();
            let rel = e
                .get("path")
                .as_str()
                .ok_or_else(|| anyhow!("entry {name}: missing path"))?;
            let mut params = BTreeMap::new();
            let mut preset_tag = None;
            if let Some(obj) = e.get("params").as_obj() {
                for (k, v) in obj {
                    if let Some(i) = v.as_i64() {
                        params.insert(k.clone(), i);
                    } else if let Some(s) = v.as_str() {
                        if k == "preset" {
                            preset_tag = Some(s.to_string());
                        }
                    }
                }
            }
            let mut inputs = Vec::new();
            for inp in e.get("inputs").as_arr().unwrap_or(&[]) {
                let shape = inp
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<Vec<_>>>()?;
                inputs.push(InputSpec {
                    name: inp.get("name").as_str().unwrap_or_default().to_string(),
                    dtype: inp.get("dtype").as_str().unwrap_or_default().to_string(),
                    shape,
                });
            }
            if op.is_empty() || variant.is_empty() || inputs.is_empty() {
                bail!("entry {name}: incomplete record");
            }
            entries.push(ArtifactEntry {
                name,
                op,
                variant,
                params,
                path: dir.join(rel),
                inputs,
                preset_tag,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Synthesize the full kernel catalog for the native backend — no
    /// artifact files involved. Mirrors `python/compile/catalog.py`
    /// (same presets, variants, tiles and naming, so schedule caches
    /// and CLI flows are interchangeable across backends) plus a tiny
    /// `micro` bucket family so small inputs and tests stay fast.
    pub fn synthetic() -> Manifest {
        let dir = PathBuf::from("<native-synthetic>");
        let mut entries = Vec::new();
        for p in SYNTH_PRESETS {
            // Full-size buckets.
            let h_pad = p.hub.map(|h| h.1).unwrap_or(0);
            synth_spmm(&mut entries, &dir, p, p.n_pad, p.nnz_pad, h_pad, "full");
            synth_sddmm(&mut entries, &dir, p, p.n_pad, "full");
            synth_softmax(&mut entries, &dir, p, p.n_pad, "full");
            synth_attention(&mut entries, &dir, p, p.n_pad, p.nnz_pad, "full");
            // Probe-size buckets (induced subgraph, min 512 rows).
            if p.probe_buckets {
                let hp = p.hub.map(|h| h.3).unwrap_or(0);
                synth_spmm(&mut entries, &dir, p, PROBE_N, p.nnz_pad_probe, hp, "probe");
                synth_sddmm(&mut entries, &dir, p, PROBE_N, "probe");
                synth_softmax(&mut entries, &dir, p, PROBE_N, "probe");
                synth_attention(&mut entries, &dir, p, PROBE_N, p.nnz_pad_probe, "probe");
            }
        }
        synth_linear(&mut entries, &dir);
        debug_assert_eq!(
            entries.len(),
            entries
                .iter()
                .map(|e| e.name.as_str())
                .collect::<std::collections::BTreeSet<_>>()
                .len(),
            "duplicate synthetic artifact names"
        );
        Manifest { dir, entries }
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries for an op at a given feature width and size class.
    /// `f = None` matches ops without an F parameter (softmax).
    pub fn candidates(
        &self,
        op: &str,
        f: Option<usize>,
        probe: bool,
    ) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.is_probe() == probe)
            .filter(|e| match f {
                Some(f) => e.param_usize("f") == Some(f),
                None => true,
            })
            .collect()
    }
}

// ----------------------------------------------------- synthetic catalog

/// Shape contract of one synthetic bucket family (mirror of
/// `python/compile/catalog.py` `PRESETS`).
struct SynthPreset {
    name: &'static str,
    n_pad: usize,
    w_plain: usize,
    nnz_pad: usize,
    nnz_pad_probe: usize,
    fs: &'static [usize],
    sddmm_fs: &'static [usize],
    /// (w_light, h_pad, w_hub, h_pad_probe)
    hub: Option<(usize, usize, usize, usize)>,
    /// Emit probe-size (n_pad = 512) twins.
    probe_buckets: bool,
}

const PROBE_N: usize = 512;
/// SpMM row-tile instantiations: (r, ft); ft = 128 is the wide-lane
/// ("vec") path, legal only when F % 128 == 0.
const SPMM_TILES: &[(usize, usize)] = &[(8, 32), (32, 32), (8, 128)];
const HUB_TILES: &[(usize, usize)] = &[(8, 32), (8, 128)];
const SDDMM_TILES: &[(usize, usize)] = &[(8, 32), (8, 128)];
const SOFTMAX_R: usize = 8;

const SYNTH_PRESETS: &[SynthPreset] = &[
    // Tiny buckets so sub-256-row inputs (and the test suite) never pay
    // for a 4096-row pad. No probe twins: such inputs always take the
    // full-bucket probe path.
    SynthPreset {
        name: "micro",
        n_pad: 256,
        w_plain: 16,
        nnz_pad: 4096,
        nnz_pad_probe: 0,
        fs: &[8, 16, 32, 64, 128],
        sddmm_fs: &[8, 16, 32, 64, 128],
        hub: Some((4, 64, 16, 0)),
        probe_buckets: false,
    },
    SynthPreset {
        name: "er_s",
        n_pad: 4096,
        w_plain: 32,
        nnz_pad: 32768,
        nnz_pad_probe: 8192,
        fs: &[32, 64, 128, 256],
        sddmm_fs: &[64, 128],
        hub: Some((8, 256, 32, 64)),
        probe_buckets: true,
    },
    SynthPreset {
        name: "hub_s",
        n_pad: 4096,
        w_plain: 512,
        nnz_pad: 524288,
        nnz_pad_probe: 65536,
        fs: &[64, 128, 256],
        sddmm_fs: &[],
        hub: Some((8, 1024, 512, 128)),
        probe_buckets: true,
    },
    SynthPreset {
        name: "reddit_s",
        n_pad: 4096,
        w_plain: 256,
        nnz_pad: 262144,
        nnz_pad_probe: 65536,
        fs: &[32, 64, 96, 128, 192, 256],
        sddmm_fs: &[],
        hub: Some((128, 256, 256, 64)),
        probe_buckets: true,
    },
    SynthPreset {
        name: "products_s",
        n_pad: 8192,
        w_plain: 128,
        nnz_pad: 262144,
        nnz_pad_probe: 32768,
        fs: &[32, 64, 96, 128, 192, 256],
        sddmm_fs: &[64, 128],
        hub: Some((64, 256, 128, 64)),
        probe_buckets: true,
    },
    SynthPreset {
        name: "t10a",
        n_pad: 2048,
        w_plain: 512,
        nnz_pad: 262144,
        nnz_pad_probe: 65536,
        fs: &[128],
        sddmm_fs: &[],
        hub: Some((64, 64, 512, 32)),
        probe_buckets: true,
    },
    SynthPreset {
        name: "t10b",
        n_pad: 2048,
        w_plain: 1024,
        nnz_pad: 131072,
        nnz_pad_probe: 65536,
        fs: &[128],
        sddmm_fs: &[],
        hub: Some((32, 64, 1024, 32)),
        probe_buckets: true,
    },
];

type SynthInput = (&'static str, &'static str, Vec<usize>);

fn synth_entry(
    dir: &Path,
    name: String,
    op: &str,
    variant: &str,
    preset: &str,
    params: &[(&str, usize)],
    inputs: Vec<SynthInput>,
) -> ArtifactEntry {
    let mut p = BTreeMap::new();
    for (k, v) in params {
        p.insert((*k).to_string(), *v as i64);
    }
    let path = dir.join(format!("{name}.native"));
    ArtifactEntry {
        name,
        op: op.to_string(),
        variant: variant.to_string(),
        params: p,
        path,
        inputs: inputs
            .into_iter()
            .map(|(n, d, shape)| InputSpec {
                name: n.to_string(),
                dtype: d.to_string(),
                shape,
            })
            .collect(),
        preset_tag: Some(preset.to_string()),
    }
}

fn synth_spmm(
    out: &mut Vec<ArtifactEntry>,
    dir: &Path,
    p: &SynthPreset,
    n_pad: usize,
    nnz_pad: usize,
    h_pad: usize,
    tag: &str,
) {
    let w = p.w_plain;
    for &f in p.fs {
        let base = [("n_pad", n_pad), ("w", w), ("f", f)];
        // Vendor baseline: COO scatter.
        out.push(synth_entry(
            dir,
            format!("spmm_base_{}_{tag}_F{f}", p.name),
            "spmm",
            "baseline_scatter",
            p.name,
            &[("n_pad", n_pad), ("w", w), ("f", f), ("nnz_pad", nnz_pad)],
            vec![
                ("row", "s32", vec![nnz_pad]),
                ("col", "s32", vec![nnz_pad]),
                ("val", "f32", vec![nnz_pad]),
                ("b", "f32", vec![n_pad, f]),
            ],
        ));
        // Whole-row gather (grid-free limit).
        out.push(synth_entry(
            dir,
            format!("spmm_ellg_{}_{tag}_F{f}", p.name),
            "spmm",
            "ell_gather",
            p.name,
            &base,
            vec![
                ("colind", "s32", vec![n_pad, w]),
                ("val", "f32", vec![n_pad, w]),
                ("b", "f32", vec![n_pad, f]),
            ],
        ));
        // Row-tile kernels.
        for &(r, ft) in SPMM_TILES {
            if f % ft != 0 {
                continue;
            }
            out.push(synth_entry(
                dir,
                format!("spmm_ell_r{r}_f{ft}_{}_{tag}_F{f}", p.name),
                "spmm",
                &format!("ell_r{r}_f{ft}"),
                p.name,
                &[("n_pad", n_pad), ("w", w), ("f", f), ("r", r), ("ft", ft)],
                vec![
                    ("colind", "s32", vec![n_pad, w]),
                    ("val", "f32", vec![n_pad, w]),
                    ("b", "f32", vec![n_pad, f]),
                ],
            ));
        }
        // Hub-split kernels.
        if let Some((w_light, _, w_hub, _)) = p.hub {
            let hub_inputs = |f: usize| -> Vec<SynthInput> {
                vec![
                    ("light_colind", "s32", vec![n_pad, w_light]),
                    ("light_val", "f32", vec![n_pad, w_light]),
                    ("hub_rows", "s32", vec![h_pad]),
                    ("hub_colind", "s32", vec![h_pad, w_hub]),
                    ("hub_val", "f32", vec![h_pad, w_hub]),
                    ("b", "f32", vec![n_pad, f]),
                ]
            };
            out.push(synth_entry(
                dir,
                format!("spmm_hubg_{}_{tag}_F{f}", p.name),
                "spmm",
                "hub_gather",
                p.name,
                &[
                    ("n_pad", n_pad),
                    ("w", w),
                    ("f", f),
                    ("w_light", w_light),
                    ("h_pad", h_pad),
                    ("w_hub", w_hub),
                ],
                hub_inputs(f),
            ));
            for &(r, ft) in HUB_TILES {
                if f % ft != 0 {
                    continue;
                }
                out.push(synth_entry(
                    dir,
                    format!("spmm_hub_r{r}_f{ft}_{}_{tag}_F{f}", p.name),
                    "spmm",
                    &format!("hub_r{r}_f{ft}"),
                    p.name,
                    &[
                        ("n_pad", n_pad),
                        ("w", w),
                        ("f", f),
                        ("r", r),
                        ("ft", ft),
                        ("w_light", w_light),
                        ("h_pad", h_pad),
                        ("w_hub", w_hub),
                    ],
                    hub_inputs(f),
                ));
            }
        }
    }
}

fn synth_sddmm(out: &mut Vec<ArtifactEntry>, dir: &Path, p: &SynthPreset, n_pad: usize, tag: &str) {
    let w = p.w_plain;
    for &f in p.sddmm_fs {
        let inputs = |f: usize| -> Vec<SynthInput> {
            vec![
                ("colind", "s32", vec![n_pad, w]),
                ("mask", "f32", vec![n_pad, w]),
                ("x", "f32", vec![n_pad, f]),
                ("y", "f32", vec![n_pad, f]),
            ]
        };
        out.push(synth_entry(
            dir,
            format!("sddmm_base_{}_{tag}_F{f}", p.name),
            "sddmm",
            "baseline_gather",
            p.name,
            &[("n_pad", n_pad), ("w", w), ("f", f)],
            inputs(f),
        ));
        for &(r, ft) in SDDMM_TILES {
            if f % ft != 0 {
                continue;
            }
            out.push(synth_entry(
                dir,
                format!("sddmm_ell_r{r}_f{ft}_{}_{tag}_F{f}", p.name),
                "sddmm",
                &format!("ell_r{r}_f{ft}"),
                p.name,
                &[("n_pad", n_pad), ("w", w), ("f", f), ("r", r), ("ft", ft)],
                inputs(f),
            ));
        }
    }
}

fn synth_softmax(out: &mut Vec<ArtifactEntry>, dir: &Path, p: &SynthPreset, n_pad: usize, tag: &str) {
    if p.sddmm_fs.is_empty() {
        return;
    }
    let w = p.w_plain;
    let inputs = || -> Vec<SynthInput> {
        vec![
            ("val", "f32", vec![n_pad, w]),
            ("mask", "f32", vec![n_pad, w]),
        ]
    };
    out.push(synth_entry(
        dir,
        format!("softmax_base_{}_{tag}", p.name),
        "softmax",
        "baseline",
        p.name,
        &[("n_pad", n_pad), ("w", w)],
        inputs(),
    ));
    out.push(synth_entry(
        dir,
        format!("softmax_ell_r{SOFTMAX_R}_{}_{tag}", p.name),
        "softmax",
        &format!("ell_r{SOFTMAX_R}"),
        p.name,
        &[("n_pad", n_pad), ("w", w), ("r", SOFTMAX_R)],
        inputs(),
    ));
}

fn synth_attention(
    out: &mut Vec<ArtifactEntry>,
    dir: &Path,
    p: &SynthPreset,
    n_pad: usize,
    nnz_pad: usize,
    tag: &str,
) {
    let w = p.w_plain;
    for &f in p.sddmm_fs {
        out.push(synth_entry(
            dir,
            format!("attn_base_{}_{tag}_F{f}", p.name),
            "attention",
            "baseline",
            p.name,
            &[("n_pad", n_pad), ("w", w), ("f", f), ("nnz_pad", nnz_pad)],
            vec![
                ("colind", "s32", vec![n_pad, w]),
                ("mask", "f32", vec![n_pad, w]),
                ("row", "s32", vec![nnz_pad]),
                ("col", "s32", vec![nnz_pad]),
                ("q", "f32", vec![n_pad, f]),
                ("k", "f32", vec![n_pad, f]),
                ("v", "f32", vec![n_pad, f]),
            ],
        ));
        let fused_inputs = |f: usize| -> Vec<SynthInput> {
            vec![
                ("colind", "s32", vec![n_pad, w]),
                ("mask", "f32", vec![n_pad, w]),
                ("q", "f32", vec![n_pad, f]),
                ("k", "f32", vec![n_pad, f]),
                ("v", "f32", vec![n_pad, f]),
            ]
        };
        out.push(synth_entry(
            dir,
            format!("attn_fgather_{}_{tag}_F{f}", p.name),
            "attention",
            "fused_gather",
            p.name,
            &[("n_pad", n_pad), ("w", w), ("f", f)],
            fused_inputs(f),
        ));
        for &(r, ft) in SDDMM_TILES {
            if f % ft != 0 {
                continue;
            }
            out.push(synth_entry(
                dir,
                format!("attn_fused_r{r}_f{ft}_{}_{tag}_F{f}", p.name),
                "attention",
                &format!("fused_r{r}_f{ft}"),
                p.name,
                &[("n_pad", n_pad), ("w", w), ("f", f), ("r", r), ("ft", ft)],
                fused_inputs(f),
            ));
        }
    }
}

fn synth_linear(out: &mut Vec<ArtifactEntry>, dir: &Path) {
    // Dense transform buckets for the GCN end-to-end example, plus
    // micro sizes for tests.
    for (n_pad, f_in, f_out) in [
        (8192, 64, 64),
        (8192, 128, 128),
        (8192, 128, 64),
        (8192, 64, 128),
        (256, 16, 16),
        (256, 32, 32),
    ] {
        out.push(synth_entry(
            dir,
            format!("linear_relu_n{n_pad}_{f_in}x{f_out}"),
            "linear_relu",
            "dense",
            "dense",
            &[("n_pad", n_pad), ("f_in", f_in), ("f_out", f_out)],
            vec![
                ("h", "f32", vec![n_pad, f_in]),
                ("w", "f32", vec![f_in, f_out]),
                ("bias", "f32", vec![f_out]),
            ],
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax": "0.8.2",
      "entries": [
        {"name": "spmm_base_er_s_full_F64", "op": "spmm",
         "variant": "baseline_scatter",
         "params": {"n_pad": 4096, "w": 32, "f": 64, "preset": "er_s",
                    "nnz_pad": 32768},
         "path": "spmm_base_er_s_full_F64.hlo.txt",
         "inputs": [
            {"name": "row", "dtype": "s32", "shape": [32768]},
            {"name": "col", "dtype": "s32", "shape": [32768]},
            {"name": "val", "dtype": "f32", "shape": [32768]},
            {"name": "b", "dtype": "f32", "shape": [4096, 64]}]},
        {"name": "spmm_ell_r8_f32_er_s_probe_F64", "op": "spmm",
         "variant": "ell_r8_f32",
         "params": {"n_pad": 512, "w": 32, "f": 64, "r": 8, "ft": 32,
                    "preset": "er_s"},
         "path": "spmm_ell_r8_f32_er_s_probe_F64.hlo.txt",
         "inputs": [
            {"name": "colind", "dtype": "s32", "shape": [512, 32]},
            {"name": "val", "dtype": "f32", "shape": [512, 32]},
            {"name": "b", "dtype": "f32", "shape": [512, 64]}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.by_name("spmm_base_er_s_full_F64").unwrap();
        assert_eq!(e.op, "spmm");
        assert_eq!(e.param_usize("nnz_pad"), Some(32768));
        assert_eq!(e.preset(), Some("er_s"));
        assert!(!e.is_probe());
        assert_eq!(e.path, Path::new("/tmp/arts/spmm_base_er_s_full_F64.hlo.txt"));
        assert_eq!(e.inputs[3].shape, vec![4096, 64]);
    }

    #[test]
    fn candidates_filter() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        assert_eq!(m.candidates("spmm", Some(64), false).len(), 1);
        assert_eq!(m.candidates("spmm", Some(64), true).len(), 1);
        assert_eq!(m.candidates("spmm", Some(128), false).len(), 0);
        assert_eq!(m.candidates("sddmm", Some(64), false).len(), 0);
    }

    #[test]
    fn rejects_incomplete() {
        let bad = r#"{"entries": [{"name": "x", "op": "spmm",
            "variant": "v", "path": "p", "inputs": []}]}"#;
        assert!(Manifest::parse(Path::new("/x"), bad).is_err());
    }

    #[test]
    fn require_usize_names_entry_and_param() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let e = m.by_name("spmm_base_er_s_full_F64").unwrap();
        assert_eq!(e.require_usize("n_pad").unwrap(), 4096);
        let err = e.require_usize("nope").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("spmm_base_er_s_full_F64"), "{msg}");
        assert!(msg.contains("nope"), "{msg}");
    }

    #[test]
    fn synthetic_catalog_is_complete_and_unique() {
        let m = Manifest::synthetic();
        assert!(m.entries.len() > 100, "only {} entries", m.entries.len());
        let names: std::collections::BTreeSet<&str> =
            m.entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names.len(), m.entries.len(), "duplicate names");
        // Every op family is present at full and probe size.
        for op in ["spmm", "sddmm", "softmax", "attention"] {
            assert!(
                m.entries.iter().any(|e| e.op == op && !e.is_probe()),
                "{op}: no full buckets"
            );
            assert!(
                m.entries.iter().any(|e| e.op == op && e.is_probe()),
                "{op}: no probe buckets"
            );
        }
        // Baselines exist wherever candidates exist.
        assert!(m
            .entries
            .iter()
            .any(|e| e.op == "spmm" && e.variant == "baseline_scatter"));
        assert!(m
            .entries
            .iter()
            .any(|e| e.op == "sddmm" && e.variant == "baseline_gather"));
        // Wide-lane tiles only at F % 128 == 0.
        for e in &m.entries {
            if e.variant.contains("f128") {
                assert_eq!(e.param_usize("f").unwrap() % 128, 0, "{}", e.name);
            }
        }
        // Input shapes are consistent with the bucket params.
        for e in &m.entries {
            let n_pad = e.param_usize("n_pad").unwrap();
            for spec in &e.inputs {
                if spec.name == "colind" || spec.name == "mask" {
                    assert_eq!(spec.shape[0], n_pad, "{}", e.name);
                }
            }
        }
    }

    #[test]
    fn synthetic_candidates_cover_presets() {
        let m = Manifest::synthetic();
        // The probe path needs probe-size baselines + candidates for
        // every (spmm, F) the bench sweeps.
        for f in [32, 64, 128, 256] {
            let probe = m.candidates("spmm", Some(f), true);
            assert!(
                probe.iter().any(|e| e.variant == "baseline_scatter"),
                "F={f}: no probe baseline"
            );
            assert!(
                probe.iter().any(|e| e.variant != "baseline_scatter"),
                "F={f}: no probe candidates"
            );
            let full = m.candidates("spmm", Some(f), false);
            assert!(full.len() >= 4, "F={f}: only {} full entries", full.len());
        }
    }
}
