//! Artifact manifest: the contract between the AOT compile path and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One named input of an artifact (call order is significant).
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub dtype: String, // "f32" | "s32"
    pub shape: Vec<usize>,
}

/// One compiled artifact: an (op, variant, shape-bucket) instantiation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub op: String,
    pub variant: String,
    pub params: BTreeMap<String, i64>,
    pub path: PathBuf, // absolute
    pub inputs: Vec<InputSpec>,
    /// Preset tag this bucket was sized for (informational).
    pub preset_tag: Option<String>,
}

impl ArtifactEntry {
    pub fn param(&self, key: &str) -> Option<i64> {
        self.params.get(key).copied()
    }
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.param(key).and_then(|v| usize::try_from(v).ok())
    }
    /// The preset tag this bucket was sized for (informational).
    pub fn preset(&self) -> Option<&str> {
        self.preset_tag.as_deref()
    }
    /// Whether this is a probe-size (n_pad = 512) bucket.
    pub fn is_probe(&self) -> bool {
        self.name.contains("_probe_")
    }
}

/// The parsed manifest: all artifacts under one directory.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts`", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let entries_json = root
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing entries[]"))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let op = e.get("op").as_str().unwrap_or_default().to_string();
            let variant = e.get("variant").as_str().unwrap_or_default().to_string();
            let rel = e
                .get("path")
                .as_str()
                .ok_or_else(|| anyhow!("entry {name}: missing path"))?;
            let mut params = BTreeMap::new();
            let mut preset_tag = None;
            if let Some(obj) = e.get("params").as_obj() {
                for (k, v) in obj {
                    if let Some(i) = v.as_i64() {
                        params.insert(k.clone(), i);
                    } else if let Some(s) = v.as_str() {
                        if k == "preset" {
                            preset_tag = Some(s.to_string());
                        }
                    }
                }
            }
            let mut inputs = Vec::new();
            for inp in e.get("inputs").as_arr().unwrap_or(&[]) {
                let shape = inp
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<Vec<_>>>()?;
                inputs.push(InputSpec {
                    name: inp.get("name").as_str().unwrap_or_default().to_string(),
                    dtype: inp.get("dtype").as_str().unwrap_or_default().to_string(),
                    shape,
                });
            }
            if op.is_empty() || variant.is_empty() || inputs.is_empty() {
                bail!("entry {name}: incomplete record");
            }
            entries.push(ArtifactEntry {
                name,
                op,
                variant,
                params,
                path: dir.join(rel),
                inputs,
                preset_tag,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries for an op at a given feature width and size class.
    /// `f = None` matches ops without an F parameter (softmax).
    pub fn candidates(
        &self,
        op: &str,
        f: Option<usize>,
        probe: bool,
    ) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.is_probe() == probe)
            .filter(|e| match f {
                Some(f) => e.param_usize("f") == Some(f),
                None => true,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax": "0.8.2",
      "entries": [
        {"name": "spmm_base_er_s_full_F64", "op": "spmm",
         "variant": "baseline_scatter",
         "params": {"n_pad": 4096, "w": 32, "f": 64, "preset": "er_s",
                    "nnz_pad": 32768},
         "path": "spmm_base_er_s_full_F64.hlo.txt",
         "inputs": [
            {"name": "row", "dtype": "s32", "shape": [32768]},
            {"name": "col", "dtype": "s32", "shape": [32768]},
            {"name": "val", "dtype": "f32", "shape": [32768]},
            {"name": "b", "dtype": "f32", "shape": [4096, 64]}]},
        {"name": "spmm_ell_r8_f32_er_s_probe_F64", "op": "spmm",
         "variant": "ell_r8_f32",
         "params": {"n_pad": 512, "w": 32, "f": 64, "r": 8, "ft": 32,
                    "preset": "er_s"},
         "path": "spmm_ell_r8_f32_er_s_probe_F64.hlo.txt",
         "inputs": [
            {"name": "colind", "dtype": "s32", "shape": [512, 32]},
            {"name": "val", "dtype": "f32", "shape": [512, 32]},
            {"name": "b", "dtype": "f32", "shape": [512, 64]}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.by_name("spmm_base_er_s_full_F64").unwrap();
        assert_eq!(e.op, "spmm");
        assert_eq!(e.param_usize("nnz_pad"), Some(32768));
        assert_eq!(e.preset(), Some("er_s"));
        assert!(!e.is_probe());
        assert_eq!(e.path, Path::new("/tmp/arts/spmm_base_er_s_full_F64.hlo.txt"));
        assert_eq!(e.inputs[3].shape, vec![4096, 64]);
    }

    #[test]
    fn candidates_filter() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        assert_eq!(m.candidates("spmm", Some(64), false).len(), 1);
        assert_eq!(m.candidates("spmm", Some(64), true).len(), 1);
        assert_eq!(m.candidates("spmm", Some(128), false).len(), 0);
        assert_eq!(m.candidates("sddmm", Some(64), false).len(), 0);
    }

    #[test]
    fn rejects_incomplete() {
        let bad = r#"{"entries": [{"name": "x", "op": "spmm",
            "variant": "v", "path": "p", "inputs": []}]}"#;
        assert!(Manifest::parse(Path::new("/x"), bad).is_err());
    }
}
