//! Telemetry (paper §5 + §10): CSV event logs with `.meta.json`
//! sidecars recording device, toolchain and env toggles, so every CSV
//! is self-describing and replayable.

use std::cell::RefCell;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::Config;
use crate::scheduler::{Decision, DecisionSource};
use crate::util::csv::CsvTable;
use crate::util::json::Json;

/// Event sink. With `dir = None` events are kept in memory only
/// (inspectable in tests); with a directory they flush to
/// `events.csv` + `events.csv.meta.json`.
pub struct Telemetry {
    dir: Option<PathBuf>,
    events: RefCell<CsvTable>,
    device_sig: String,
}

const HEADER: &[&str] = &[
    "event", "op", "f", "variant", "source", "t_baseline_ms", "t_star_ms",
    "probe_wall_ms", "key",
];

impl Telemetry {
    pub fn new(dir: Option<&Path>, device_sig: &str) -> Telemetry {
        Telemetry {
            dir: dir.map(|d| d.to_path_buf()),
            events: RefCell::new(CsvTable::new(HEADER)),
            device_sig: device_sig.to_string(),
        }
    }

    /// Record a scheduling decision.
    pub fn decision(&self, d: &Decision) {
        let source = match d.source {
            DecisionSource::Cache => "cache",
            DecisionSource::Probe => "probe",
            DecisionSource::Model => "model",
            DecisionSource::ReplayFallback => "replay_fallback",
        };
        self.events.borrow_mut().push(vec![
            "decision".into(),
            d.op.as_str().into(),
            d.f.to_string(),
            d.choice.variant().into(),
            source.into(),
            format!("{:.6}", d.t_baseline_ms),
            format!("{:.6}", d.t_star_ms),
            format!("{:.6}", d.probe_wall_ms),
            d.key.clone(),
        ]);
    }

    /// Record a probed candidate sample.
    pub fn probe_sample(&self, op: &str, f: usize, variant: &str, median_ms: f64) {
        self.events.borrow_mut().push(vec![
            "probe".into(),
            op.into(),
            f.to_string(),
            variant.into(),
            "probe".into(),
            String::new(),
            format!("{median_ms:.6}"),
            String::new(),
            String::new(),
        ]);
    }

    pub fn n_events(&self) -> usize {
        self.events.borrow().n_rows()
    }

    /// Rows matching an event kind (test/CLI inspection).
    pub fn events_of(&self, kind: &str) -> Vec<Vec<String>> {
        self.events
            .borrow()
            .rows()
            .iter()
            .filter(|r| r[0] == kind)
            .cloned()
            .collect()
    }

    /// Flush `events.csv` + `.meta.json` sidecar. No-op in memory mode.
    pub fn flush(&self, cfg: &Config) -> Result<Option<PathBuf>> {
        let Some(dir) = &self.dir else { return Ok(None) };
        fs::create_dir_all(dir)?;
        let csv_path = dir.join("events.csv");
        self.events.borrow().write_to(&csv_path)?;
        let meta = meta_sidecar(&self.device_sig, cfg);
        fs::write(
            dir.join("events.csv.meta.json"),
            meta.pretty(),
        )?;
        Ok(Some(csv_path))
    }
}

// ----------------------------------------------------------- serving

/// One shard's serving statistics — the snapshot shape produced by
/// `server::metrics::ServerMetrics` and rendered by `serve-bench`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeShardStats {
    pub shard: usize,
    pub requests: u64,
    pub batches: u64,
    pub coalesced: u64,
    pub probes: u64,
    pub cache_hits: u64,
    pub errors: u64,
    pub rejected: u64,
    /// Requests shed at dequeue because their queue wait already
    /// exceeded the deadline (`AUTOSAGE_DEADLINE_MS`).
    pub shed: u64,
    /// Requests served on the edge-sampled graph under overload
    /// (graceful degradation, `AUTOSAGE_DEGRADE_WATERMARK`).
    pub degraded: u64,
    /// Worker panics caught by supervision (injected or organic); the
    /// shard stays alive and the poisoning request is quarantined.
    pub panics: u64,
    pub max_queue_depth: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

pub const SERVING_HEADER: &[&str] = &[
    "shard", "requests", "batches", "coalesced", "probes", "cache_hits",
    "errors", "rejected", "shed", "degraded", "panics", "max_queue_depth",
    "p50_ms", "p95_ms", "p99_ms",
];

/// Per-shard serving metrics → CSV with a trailing aggregate row.
///
/// Pass `pool` (from `ServerMetrics::pool_stats`, counters summed and
/// latency quantiles computed on the *merged* per-shard histograms) to
/// get a statistically meaningful `pool` row. Without it the fallback
/// `total` row sums counters but can only take the per-shard max of the
/// quantiles — a conservative upper bound, NOT a pool percentile (a
/// nearly idle shard with a few slow requests would dominate it), which
/// is why every caller with access to live `ServerMetrics` passes
/// `pool`.
pub fn serving_table(shards: &[ServeShardStats], pool: Option<&ServeShardStats>) -> CsvTable {
    fn push(t: &mut CsvTable, label: String, s: &ServeShardStats) {
        t.push(vec![
            label,
            s.requests.to_string(),
            s.batches.to_string(),
            s.coalesced.to_string(),
            s.probes.to_string(),
            s.cache_hits.to_string(),
            s.errors.to_string(),
            s.rejected.to_string(),
            s.shed.to_string(),
            s.degraded.to_string(),
            s.panics.to_string(),
            s.max_queue_depth.to_string(),
            format!("{:.3}", s.p50_ms),
            format!("{:.3}", s.p95_ms),
            format!("{:.3}", s.p99_ms),
        ]);
    }
    let mut t = CsvTable::new(SERVING_HEADER);
    let mut total = ServeShardStats::default();
    for s in shards {
        push(&mut t, s.shard.to_string(), s);
        total.requests += s.requests;
        total.batches += s.batches;
        total.coalesced += s.coalesced;
        total.probes += s.probes;
        total.cache_hits += s.cache_hits;
        total.errors += s.errors;
        total.rejected += s.rejected;
        total.shed += s.shed;
        total.degraded += s.degraded;
        total.panics += s.panics;
        total.max_queue_depth = total.max_queue_depth.max(s.max_queue_depth);
        total.p50_ms = total.p50_ms.max(s.p50_ms);
        total.p95_ms = total.p95_ms.max(s.p95_ms);
        total.p99_ms = total.p99_ms.max(s.p99_ms);
    }
    match pool {
        Some(p) => push(&mut t, "pool".into(), p),
        None => push(&mut t, "total".into(), &total),
    }
    t
}

/// Write any CSV in the repo's standard artifact convention:
/// `<stem>.csv` + `<stem>.csv.meta.json` sidecar. Returns the CSV path.
pub fn write_csv_with_sidecar(
    dir: &Path,
    stem: &str,
    csv: &CsvTable,
    device_sig: &str,
    cfg: &Config,
) -> Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let csv_path = dir.join(format!("{stem}.csv"));
    csv.write_to(&csv_path)?;
    fs::write(
        dir.join(format!("{stem}.csv.meta.json")),
        meta_sidecar(device_sig, cfg).pretty(),
    )?;
    Ok(csv_path)
}

/// The `.meta.json` sidecar content (paper §10: "GPU/SM, Torch/CUDA
/// versions, and env vars" → here: device/backend signature, runtime
/// identity, and all AUTOSAGE_* toggles).
pub fn meta_sidecar(device_sig: &str, cfg: &Config) -> Json {
    let env_toggles: Vec<(String, Json)> = std::env::vars()
        .filter(|(k, _)| k.starts_with("AUTOSAGE_"))
        .map(|(k, v)| (k, Json::str(v)))
        .collect();
    Json::obj(vec![
        ("device_sig", Json::str(device_sig)),
        ("runtime", Json::str(format!("autosage-{}", env!("CARGO_PKG_VERSION")))),
        ("backend_cfg", Json::str(cfg.backend.clone())),
        ("alpha", Json::num(cfg.alpha)),
        ("probe_frac", Json::num(cfg.probe_frac)),
        ("probe_iters", Json::num(cfg.probe_iters as f64)),
        ("probe_cap_ms", Json::num(cfg.probe_cap_ms)),
        ("top_k", Json::num(cfg.top_k as f64)),
        ("allow_vec", Json::from(cfg.allow_vec)),
        ("replay_only", Json::from(cfg.replay_only)),
        (
            "env",
            Json::Obj(env_toggles.into_iter().collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Choice, Op};

    fn decision() -> Decision {
        Decision {
            op: Op::Spmm,
            f: 64,
            key: "d|g|F64|spmm".into(),
            choice: Choice::Candidate("ell_r8_f32".into()),
            source: DecisionSource::Probe,
            t_baseline_ms: 1.0,
            t_star_ms: 0.5,
            probe_wall_ms: 12.0,
            features: None,
        }
    }

    #[test]
    fn records_events_in_memory() {
        let t = Telemetry::new(None, "dev");
        t.decision(&decision());
        t.probe_sample("spmm", 64, "hub_r8_f32", 0.7);
        assert_eq!(t.n_events(), 2);
        let d = t.events_of("decision");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0][3], "ell_r8_f32");
        assert!(t.flush(&Config::default()).unwrap().is_none());
    }

    #[test]
    fn flush_writes_csv_and_sidecar() {
        let dir = std::env::temp_dir().join("autosage_telemetry_test");
        let _ = fs::remove_dir_all(&dir);
        let t = Telemetry::new(Some(&dir), "devsig");
        t.decision(&decision());
        let path = t.flush(&Config::default()).unwrap().unwrap();
        assert!(path.exists());
        let meta_raw =
            fs::read_to_string(dir.join("events.csv.meta.json")).unwrap();
        let meta = Json::parse(&meta_raw).unwrap();
        assert_eq!(meta.get("device_sig").as_str(), Some("devsig"));
        assert_eq!(meta.get("alpha").as_f64(), Some(0.95));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn serving_table_has_per_shard_and_total_rows() {
        let shards = vec![
            ServeShardStats {
                shard: 0,
                requests: 10,
                probes: 2,
                p99_ms: 4.0,
                ..Default::default()
            },
            ServeShardStats {
                shard: 1,
                requests: 5,
                probes: 1,
                p99_ms: 9.0,
                ..Default::default()
            },
        ];
        let t = serving_table(&shards, None);
        assert_eq!(t.header().len(), SERVING_HEADER.len());
        assert_eq!(t.n_rows(), 3);
        let total = &t.rows()[2];
        assert_eq!(total[0], "total");
        assert_eq!(total[1], "15"); // requests sum
        assert_eq!(total[4], "3"); // probes sum
        assert_eq!(total[14], "9.000"); // p99 max (fallback upper bound)
    }

    #[test]
    fn serving_table_pool_row_uses_merged_stats_not_shard_max() {
        // Skewed shards: the merged-histogram pool row must be able to
        // report a p99 BELOW the per-shard max — something the fallback
        // total row can never do.
        let shards = vec![
            ServeShardStats {
                shard: 0,
                requests: 990,
                p99_ms: 1.5,
                ..Default::default()
            },
            ServeShardStats {
                shard: 1,
                requests: 10,
                p99_ms: 300.0,
                ..Default::default()
            },
        ];
        let pool = ServeShardStats {
            shard: 2,
            requests: 1000,
            p50_ms: 1.5,
            p95_ms: 1.5,
            p99_ms: 3.0, // merged: the slow shard is only 1% of traffic
            ..Default::default()
        };
        let t = serving_table(&shards, Some(&pool));
        let row = &t.rows()[2];
        assert_eq!(row[0], "pool");
        assert_eq!(row[1], "1000");
        assert_eq!(row[14], "3.000", "merged p99, not per-shard max 300");
    }

    #[test]
    fn csv_with_sidecar_roundtrip() {
        let dir = std::env::temp_dir().join("autosage_serving_sidecar_test");
        let _ = fs::remove_dir_all(&dir);
        let t = serving_table(&[ServeShardStats::default()], None);
        let path =
            write_csv_with_sidecar(&dir, "serve_bench", &t, "devsig", &Config::default())
                .unwrap();
        assert!(path.exists());
        let meta_raw =
            fs::read_to_string(dir.join("serve_bench.csv.meta.json")).unwrap();
        assert_eq!(
            Json::parse(&meta_raw).unwrap().get("device_sig").as_str(),
            Some("devsig")
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sidecar_captures_autosage_env() {
        std::env::set_var("AUTOSAGE_TEST_SIDECAR", "42");
        let meta = meta_sidecar("d", &Config::default());
        assert_eq!(
            meta.get("env").get("AUTOSAGE_TEST_SIDECAR").as_str(),
            Some("42")
        );
        std::env::remove_var("AUTOSAGE_TEST_SIDECAR");
    }
}
