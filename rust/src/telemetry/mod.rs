//! Telemetry (paper §5 + §10): CSV event logs with `.meta.json`
//! sidecars recording device, toolchain and env toggles, so every CSV
//! is self-describing and replayable.

use std::cell::RefCell;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::Config;
use crate::scheduler::{Decision, DecisionSource};
use crate::util::csv::CsvTable;
use crate::util::json::Json;

/// Event sink. With `dir = None` events are kept in memory only
/// (inspectable in tests); with a directory they flush to
/// `events.csv` + `events.csv.meta.json`.
pub struct Telemetry {
    dir: Option<PathBuf>,
    events: RefCell<CsvTable>,
    device_sig: String,
}

const HEADER: &[&str] = &[
    "event", "op", "f", "variant", "source", "t_baseline_ms", "t_star_ms",
    "probe_wall_ms", "key",
];

impl Telemetry {
    pub fn new(dir: Option<&Path>, device_sig: &str) -> Telemetry {
        Telemetry {
            dir: dir.map(|d| d.to_path_buf()),
            events: RefCell::new(CsvTable::new(HEADER)),
            device_sig: device_sig.to_string(),
        }
    }

    /// Record a scheduling decision.
    pub fn decision(&self, d: &Decision) {
        let source = match d.source {
            DecisionSource::Cache => "cache",
            DecisionSource::Probe => "probe",
            DecisionSource::ReplayFallback => "replay_fallback",
        };
        self.events.borrow_mut().push(vec![
            "decision".into(),
            d.op.as_str().into(),
            d.f.to_string(),
            d.choice.variant().into(),
            source.into(),
            format!("{:.6}", d.t_baseline_ms),
            format!("{:.6}", d.t_star_ms),
            format!("{:.6}", d.probe_wall_ms),
            d.key.clone(),
        ]);
    }

    /// Record a probed candidate sample.
    pub fn probe_sample(&self, op: &str, f: usize, variant: &str, median_ms: f64) {
        self.events.borrow_mut().push(vec![
            "probe".into(),
            op.into(),
            f.to_string(),
            variant.into(),
            "probe".into(),
            String::new(),
            format!("{median_ms:.6}"),
            String::new(),
            String::new(),
        ]);
    }

    pub fn n_events(&self) -> usize {
        self.events.borrow().n_rows()
    }

    /// Rows matching an event kind (test/CLI inspection).
    pub fn events_of(&self, kind: &str) -> Vec<Vec<String>> {
        self.events
            .borrow()
            .rows()
            .iter()
            .filter(|r| r[0] == kind)
            .cloned()
            .collect()
    }

    /// Flush `events.csv` + `.meta.json` sidecar. No-op in memory mode.
    pub fn flush(&self, cfg: &Config) -> Result<Option<PathBuf>> {
        let Some(dir) = &self.dir else { return Ok(None) };
        fs::create_dir_all(dir)?;
        let csv_path = dir.join("events.csv");
        self.events.borrow().write_to(&csv_path)?;
        let meta = meta_sidecar(&self.device_sig, cfg);
        fs::write(
            dir.join("events.csv.meta.json"),
            meta.pretty(),
        )?;
        Ok(Some(csv_path))
    }
}

/// The `.meta.json` sidecar content (paper §10: "GPU/SM, Torch/CUDA
/// versions, and env vars" → here: device/backend signature, runtime
/// identity, and all AUTOSAGE_* toggles).
pub fn meta_sidecar(device_sig: &str, cfg: &Config) -> Json {
    let env_toggles: Vec<(String, Json)> = std::env::vars()
        .filter(|(k, _)| k.starts_with("AUTOSAGE_"))
        .map(|(k, v)| (k, Json::str(v)))
        .collect();
    Json::obj(vec![
        ("device_sig", Json::str(device_sig)),
        ("runtime", Json::str(format!("autosage-{}", env!("CARGO_PKG_VERSION")))),
        ("backend_cfg", Json::str(cfg.backend.clone())),
        ("alpha", Json::num(cfg.alpha)),
        ("probe_frac", Json::num(cfg.probe_frac)),
        ("probe_iters", Json::num(cfg.probe_iters as f64)),
        ("probe_cap_ms", Json::num(cfg.probe_cap_ms)),
        ("top_k", Json::num(cfg.top_k as f64)),
        ("allow_vec", Json::from(cfg.allow_vec)),
        ("replay_only", Json::from(cfg.replay_only)),
        (
            "env",
            Json::Obj(env_toggles.into_iter().collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Choice, Op};

    fn decision() -> Decision {
        Decision {
            op: Op::Spmm,
            f: 64,
            key: "d|g|F64|spmm".into(),
            choice: Choice::Candidate("ell_r8_f32".into()),
            source: DecisionSource::Probe,
            t_baseline_ms: 1.0,
            t_star_ms: 0.5,
            probe_wall_ms: 12.0,
        }
    }

    #[test]
    fn records_events_in_memory() {
        let t = Telemetry::new(None, "dev");
        t.decision(&decision());
        t.probe_sample("spmm", 64, "hub_r8_f32", 0.7);
        assert_eq!(t.n_events(), 2);
        let d = t.events_of("decision");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0][3], "ell_r8_f32");
        assert!(t.flush(&Config::default()).unwrap().is_none());
    }

    #[test]
    fn flush_writes_csv_and_sidecar() {
        let dir = std::env::temp_dir().join("autosage_telemetry_test");
        let _ = fs::remove_dir_all(&dir);
        let t = Telemetry::new(Some(&dir), "devsig");
        t.decision(&decision());
        let path = t.flush(&Config::default()).unwrap().unwrap();
        assert!(path.exists());
        let meta_raw =
            fs::read_to_string(dir.join("events.csv.meta.json")).unwrap();
        let meta = Json::parse(&meta_raw).unwrap();
        assert_eq!(meta.get("device_sig").as_str(), Some("devsig"));
        assert_eq!(meta.get("alpha").as_f64(), Some(0.95));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sidecar_captures_autosage_env() {
        std::env::set_var("AUTOSAGE_TEST_SIDECAR", "42");
        let meta = meta_sidecar("d", &Config::default());
        assert_eq!(
            meta.get("env").get("AUTOSAGE_TEST_SIDECAR").as_str(),
            Some("42")
        );
        std::env::remove_var("AUTOSAGE_TEST_SIDECAR");
    }
}
