//! Serving metrics: per-shard throughput/error/queue counters and
//! log-bucketed latency histograms (p50/p95/p99), lock-free on the hot
//! path (relaxed atomics only). Snapshots flow through `telemetry` into
//! the repo's standard CSV + `.meta.json` sidecar format, and into the
//! Prometheus-style `metrics.prom` exposition via
//! [`prometheus_snapshot`].
//!
//! The histogram type itself lives in [`crate::obs::metrics`] (it is a
//! generic observability primitive); this module re-exports it and owns
//! the pool-shaped aggregation. Pool-wide percentiles are ALWAYS
//! derived by merging the per-shard histograms bucket-wise
//! ([`ServerMetrics::merged_latency`]) — never by averaging (or taking
//! the max of) per-shard quantiles.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::metrics::MetricsRegistry;
use crate::obs::trace::Recorder;
use crate::telemetry::ServeShardStats;

pub use crate::obs::metrics::LatencyHistogram;

/// One shard's counters. All relaxed atomics: torn cross-counter reads
/// in a snapshot are acceptable for monitoring.
#[derive(Default)]
pub struct ShardMetrics {
    /// Requests dequeued by the worker (includes ones that later error).
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// Batches drained (one scheduling pass each).
    pub batches: AtomicU64,
    /// Requests that shared a batch-mate with the same (graph, op, F)
    /// key, i.e. executed under a coalesced decision.
    pub coalesced: AtomicU64,
    /// Fresh micro-probes run by this shard (cache + single-flight
    /// misses that this worker won).
    pub probes: AtomicU64,
    /// Decisions served from the shared schedule cache.
    pub cache_hits: AtomicU64,
    /// Submissions rejected with `QueueFull` (backpressure).
    pub rejected: AtomicU64,
    /// Requests shed at dequeue because their queue wait already
    /// exceeded `AUTOSAGE_DEADLINE_MS`.
    pub shed: AtomicU64,
    /// Requests served on the edge-sampled graph (graceful
    /// degradation under overload).
    pub degraded: AtomicU64,
    /// Worker panics caught by supervision (injected or organic);
    /// the shard survives every one of them.
    pub panics: AtomicU64,
    pub queue_depth: AtomicU64,
    pub max_queue_depth: AtomicU64,
    /// End-to-end latency (enqueue → response) per completed request.
    pub latency: LatencyHistogram,
}

/// All shards of one pool.
pub struct ServerMetrics {
    pub shards: Vec<ShardMetrics>,
}

impl ServerMetrics {
    pub fn new(n_shards: usize) -> ServerMetrics {
        ServerMetrics {
            shards: (0..n_shards).map(|_| ShardMetrics::default()).collect(),
        }
    }

    pub fn snapshot(&self) -> Vec<ServeShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| ServeShardStats {
                shard: i,
                requests: s.requests.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                coalesced: s.coalesced.load(Ordering::Relaxed),
                probes: s.probes.load(Ordering::Relaxed),
                cache_hits: s.cache_hits.load(Ordering::Relaxed),
                errors: s.errors.load(Ordering::Relaxed),
                rejected: s.rejected.load(Ordering::Relaxed),
                shed: s.shed.load(Ordering::Relaxed),
                degraded: s.degraded.load(Ordering::Relaxed),
                panics: s.panics.load(Ordering::Relaxed),
                max_queue_depth: s.max_queue_depth.load(Ordering::Relaxed),
                p50_ms: s.latency.quantile_ms(0.50),
                p95_ms: s.latency.quantile_ms(0.95),
                p99_ms: s.latency.quantile_ms(0.99),
            })
            .collect()
    }

    /// Bucket-wise merge of every shard's latency histogram — the only
    /// statistically meaningful source of pool-level quantiles.
    pub fn merged_latency(&self) -> LatencyHistogram {
        LatencyHistogram::merged(self.shards.iter().map(|s| &s.latency))
    }

    /// Pool-wide stats row: counters summed across shards, latency
    /// quantiles from the merged histogram. `shard` is set to the shard
    /// count (one past the last real index) — renderers label this row
    /// "pool", they never print the index.
    pub fn pool_stats(&self) -> ServeShardStats {
        let sum = |f: fn(&ShardMetrics) -> &AtomicU64| -> u64 {
            self.shards.iter().map(|s| f(s).load(Ordering::Relaxed)).sum()
        };
        let merged = self.merged_latency();
        ServeShardStats {
            shard: self.shards.len(),
            requests: sum(|s| &s.requests),
            batches: sum(|s| &s.batches),
            coalesced: sum(|s| &s.coalesced),
            probes: sum(|s| &s.probes),
            cache_hits: sum(|s| &s.cache_hits),
            errors: sum(|s| &s.errors),
            rejected: sum(|s| &s.rejected),
            shed: sum(|s| &s.shed),
            degraded: sum(|s| &s.degraded),
            panics: sum(|s| &s.panics),
            max_queue_depth: self
                .shards
                .iter()
                .map(|s| s.max_queue_depth.load(Ordering::Relaxed))
                .max()
                .unwrap_or(0),
            p50_ms: merged.quantile_ms(0.50),
            p95_ms: merged.quantile_ms(0.95),
            p99_ms: merged.quantile_ms(0.99),
        }
    }

    pub fn total_probes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.probes.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_requests(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.requests.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.rejected.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_errors(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.errors.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_shed(&self) -> u64 {
        self.shards.iter().map(|s| s.shed.load(Ordering::Relaxed)).sum()
    }

    pub fn total_degraded(&self) -> u64 {
        self.shards.iter().map(|s| s.degraded.load(Ordering::Relaxed)).sum()
    }

    pub fn total_panics(&self) -> u64 {
        self.shards.iter().map(|s| s.panics.load(Ordering::Relaxed)).sum()
    }

    /// Mirror the pool counters and the merged latency histogram into
    /// the registry so one `render_prometheus` covers everything.
    /// Counter mirrors use `set_counter` (absolute totals), so repeated
    /// exports are idempotent; the pool latency histogram is rebuilt
    /// from a fresh merge each time for the same reason.
    pub fn export_into(&self, reg: &MetricsRegistry) {
        let pool = self.pool_stats();
        reg.set_counter("autosage_pool_requests_total", pool.requests);
        reg.set_counter("autosage_pool_batches_total", pool.batches);
        reg.set_counter("autosage_pool_coalesced_total", pool.coalesced);
        reg.set_counter("autosage_pool_probes_total", pool.probes);
        reg.set_counter("autosage_pool_cache_hits_total", pool.cache_hits);
        reg.set_counter("autosage_pool_errors_total", pool.errors);
        reg.set_counter("autosage_pool_rejected_total", pool.rejected);
        reg.set_counter("autosage_pool_shed_total", pool.shed);
        reg.set_counter("autosage_pool_degraded_total", pool.degraded);
        reg.set_counter("autosage_worker_panics_total", pool.panics);
        reg.set_gauge(
            "autosage_pool_max_queue_depth",
            pool.max_queue_depth as f64,
        );
        for (i, s) in self.shards.iter().enumerate() {
            reg.set_gauge(
                &format!("autosage_pool_queue_depth{{shard=\"{i}\"}}"),
                s.queue_depth.load(Ordering::Relaxed) as f64,
            );
        }
        // Overwrite (not accumulate) so repeated exports stay
        // idempotent: the registry's pool histogram is a mirror of the
        // live per-shard histograms, rebuilt from a fresh merge.
        reg.histogram("autosage_pool_latency_ms")
            .store_from(&self.merged_latency());
    }
}

/// Render one unified Prometheus text snapshot: the registry's own
/// series, the recorder's sampling/drop counters, and the pool counters
/// + merged-histogram percentiles. Safe to call repeatedly (all mirrors
/// are absolute stores).
pub fn prometheus_snapshot(
    reg: &MetricsRegistry,
    pool: Option<&ServerMetrics>,
    recorder: Option<&Recorder>,
) -> String {
    reg.set_counter(
        "autosage_traces_sampled_out_total",
        recorder.map(|r| r.traces_sampled_out()).unwrap_or(0),
    );
    reg.set_counter(
        "autosage_spans_dropped_total",
        recorder.map(|r| r.spans_dropped()).unwrap_or(0),
    );
    if let Some(r) = recorder {
        reg.set_gauge("autosage_trace_sample_rate", r.sample_rate());
    }
    // Materialize the learned-scheduler counters even when no model is
    // attached (or it never fired): the required-series validation —
    // and dashboards diffing model vs no-model runs — need explicit
    // zeros, not absent series.
    for name in [
        "autosage_model_predictions_total",
        "autosage_model_low_confidence_probes_total",
        "autosage_model_agree_total",
        "autosage_model_disagree_total",
    ] {
        reg.counter(name);
    }
    // Same for the resilience counters: fault-free runs must export
    // explicit zeros so the required-series validation (and chaos-vs-
    // clean dashboards) see the series either way. The live increments
    // happen in the workers (`reg.inc`); these just materialize them.
    for name in [
        "autosage_faults_injected_total",
        "autosage_requests_quarantined_total",
    ] {
        reg.counter(name);
    }
    // Durability counters: crash-point I/O faults injected (absolute
    // mirror of the installed injector), write retries + salvage
    // recoveries + log rotations (process-wide recovery stats), and the
    // hot-reload transition counters (live-incremented at transition
    // time; materialized here so clean runs export explicit zeros).
    {
        use crate::util::iofault;
        let inj = iofault::installed();
        reg.set_counter(
            "autosage_io_faults_injected_total",
            inj.as_ref().map(|i| i.injected_total()).unwrap_or(0),
        );
        if let Some(i) = inj.as_ref() {
            for kind in iofault::IoFaultKind::ALL {
                let n = i.injected_of(kind);
                if n > 0 {
                    reg.set_counter(
                        &format!(
                            "autosage_io_faults_injected_total{{kind=\"{}\"}}",
                            kind.as_str()
                        ),
                        n,
                    );
                }
            }
        }
        let rec = iofault::recovery();
        reg.set_counter(
            "autosage_io_write_retries_total",
            rec.write_retries.load(std::sync::atomic::Ordering::Relaxed),
        );
        reg.set_counter("autosage_salvage_total", rec.salvage_total());
        reg.set_counter(
            "autosage_log_rotations_total",
            rec.rotations.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
    for name in [
        "autosage_model_reloads_total",
        "autosage_model_rollbacks_total",
    ] {
        reg.counter(name);
    }
    if let Some(p) = pool {
        p.export_into(reg);
    }
    reg.render_prometheus()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_orders_quantiles() {
        let h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record_ms(1.0);
        }
        for _ in 0..10 {
            h.record_ms(100.0);
        }
        assert_eq!(h.count(), 100);
        let (p50, p99) = (h.quantile_ms(0.5), h.quantile_ms(0.99));
        assert!(p50 < p99, "p50 {p50} must be < p99 {p99}");
        assert!(p50 < 2.0, "p50 {p50} should sit near 1ms");
        assert!(p99 > 50.0, "p99 {p99} should sit near 100ms");
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
    }

    #[test]
    fn sub_microsecond_clamps_to_first_bucket() {
        let h = LatencyHistogram::new();
        h.record_ms(0.0);
        h.record_ms(1e-9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ms(1.0) < 0.01);
    }

    #[test]
    fn snapshot_and_totals() {
        let m = ServerMetrics::new(2);
        m.shards[0].probes.fetch_add(2, Ordering::Relaxed);
        m.shards[1].probes.fetch_add(1, Ordering::Relaxed);
        m.shards[1].requests.fetch_add(5, Ordering::Relaxed);
        m.shards[1].latency.record_ms(3.0);
        assert_eq!(m.total_probes(), 3);
        assert_eq!(m.total_requests(), 5);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].shard, 0);
        assert_eq!(snap[1].probes, 1);
        assert!(snap[1].p50_ms > 0.0);
    }

    #[test]
    fn pool_stats_merge_histograms_across_skewed_shards() {
        // Regression test for the satellite: pool p50/p95/p99 must come
        // from the merged distribution, not from aggregating per-shard
        // quantiles. Shard 0 is busy and fast; shard 1 saw a handful of
        // slow requests. Per-shard-quantile aggregation (max, as the
        // old total row did, or an average) would report a slow pool
        // p50; the merged histogram knows 980 of 1000 samples were fast
        // (20 slow ones keep the p99 rank of 990 inside the slow tail).
        let m = ServerMetrics::new(2);
        for _ in 0..980 {
            m.shards[0].latency.record_ms(1.0);
        }
        for _ in 0..20 {
            m.shards[1].latency.record_ms(200.0);
        }
        m.shards[0].requests.fetch_add(980, Ordering::Relaxed);
        m.shards[1].requests.fetch_add(20, Ordering::Relaxed);
        let pool = m.pool_stats();
        assert_eq!(pool.requests, 1000);
        assert!(pool.p50_ms < 2.0, "merged p50 {} must stay fast", pool.p50_ms);
        assert!(pool.p99_ms > 100.0, "merged p99 {} must see the tail", pool.p99_ms);
        let snap = m.snapshot();
        let max_p50 = snap.iter().map(|s| s.p50_ms).fold(0.0, f64::max);
        let avg_p50 = snap.iter().map(|s| s.p50_ms).sum::<f64>() / snap.len() as f64;
        assert!(pool.p50_ms < avg_p50, "merged {} < avg {}", pool.p50_ms, avg_p50);
        assert!(pool.p50_ms < max_p50, "merged {} < max {}", pool.p50_ms, max_p50);
    }

    #[test]
    fn prometheus_snapshot_is_idempotent_and_complete() {
        let m = ServerMetrics::new(2);
        m.shards[0].requests.fetch_add(3, Ordering::Relaxed);
        m.shards[0].latency.record_ms(1.0);
        m.shards[1].latency.record_ms(8.0);
        let reg = MetricsRegistry::new();
        let rec = Recorder::with_sampling("prom-test", 0.5, 7);
        let _ = rec.sample_ctx();
        let first = prometheus_snapshot(&reg, Some(&m), Some(&rec));
        crate::obs::metrics::validate_serving_snapshot(&first).expect("valid snapshot");
        assert!(first.contains("autosage_pool_requests_total 3\n"));
        assert!(first.contains("autosage_trace_sample_rate 0.5\n"));
        assert!(first.contains("autosage_io_faults_injected_total"));
        assert!(first.contains("autosage_model_reloads_total"));
        // Re-render without new traffic: absolute mirrors must not
        // double-count. The process-global durability mirrors (salvage
        // and retry stats shared with every concurrently-running test)
        // are excluded from the comparison — they may legitimately move
        // between renders under `cargo test`'s parallelism.
        let second = prometheus_snapshot(&reg, Some(&m), Some(&rec));
        let stable = |s: &str| -> String {
            s.lines()
                .filter(|l| {
                    !l.starts_with("autosage_io_")
                        && !l.starts_with("autosage_salvage_total")
                        && !l.starts_with("autosage_log_rotations_total")
                })
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(stable(&first), stable(&second), "snapshot must be idempotent");
    }
}
