//! Serving metrics: per-shard throughput/error/queue counters and
//! log-bucketed latency histograms (p50/p95/p99), lock-free on the hot
//! path (relaxed atomics only). Snapshots flow through `telemetry` into
//! the repo's standard CSV + `.meta.json` sidecar format.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::telemetry::ServeShardStats;

/// Histogram bucket count: 40 log2 buckets cover 1 µs .. ~9 minutes.
const N_BUCKETS: usize = 40;

/// Log2-bucketed latency histogram. Bucket `b` counts samples in
/// `[2^b, 2^(b+1))` microseconds; quantiles report the geometric
/// midpoint of the bucket holding the q-th sample (≤ ~50% relative
/// error, which is plenty for p50/p95/p99 monitoring without locks).
pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS],
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn bucket_of(ms: f64) -> usize {
        let us = (ms * 1000.0).max(1.0) as u64;
        ((63 - us.leading_zeros()) as usize).min(N_BUCKETS - 1)
    }

    pub fn record_ms(&self, ms: f64) {
        self.buckets[Self::bucket_of(ms)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Latency quantile estimate in milliseconds (0.0 when empty).
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            cum += bucket.load(Ordering::Relaxed);
            if cum >= rank {
                return (1u64 << b) as f64 * 1.5 / 1000.0;
            }
        }
        (1u64 << (N_BUCKETS - 1)) as f64 * 1.5 / 1000.0
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One shard's counters. All relaxed atomics: torn cross-counter reads
/// in a snapshot are acceptable for monitoring.
#[derive(Default)]
pub struct ShardMetrics {
    /// Requests dequeued by the worker (includes ones that later error).
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// Batches drained (one scheduling pass each).
    pub batches: AtomicU64,
    /// Requests that shared a batch-mate with the same (graph, op, F)
    /// key, i.e. executed under a coalesced decision.
    pub coalesced: AtomicU64,
    /// Fresh micro-probes run by this shard (cache + single-flight
    /// misses that this worker won).
    pub probes: AtomicU64,
    /// Decisions served from the shared schedule cache.
    pub cache_hits: AtomicU64,
    /// Submissions rejected with `QueueFull` (backpressure).
    pub rejected: AtomicU64,
    pub queue_depth: AtomicU64,
    pub max_queue_depth: AtomicU64,
    /// End-to-end latency (enqueue → response) per completed request.
    pub latency: LatencyHistogram,
}

/// All shards of one pool.
pub struct ServerMetrics {
    pub shards: Vec<ShardMetrics>,
}

impl ServerMetrics {
    pub fn new(n_shards: usize) -> ServerMetrics {
        ServerMetrics {
            shards: (0..n_shards).map(|_| ShardMetrics::default()).collect(),
        }
    }

    pub fn snapshot(&self) -> Vec<ServeShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| ServeShardStats {
                shard: i,
                requests: s.requests.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                coalesced: s.coalesced.load(Ordering::Relaxed),
                probes: s.probes.load(Ordering::Relaxed),
                cache_hits: s.cache_hits.load(Ordering::Relaxed),
                errors: s.errors.load(Ordering::Relaxed),
                rejected: s.rejected.load(Ordering::Relaxed),
                max_queue_depth: s.max_queue_depth.load(Ordering::Relaxed),
                p50_ms: s.latency.quantile_ms(0.50),
                p95_ms: s.latency.quantile_ms(0.95),
                p99_ms: s.latency.quantile_ms(0.99),
            })
            .collect()
    }

    pub fn total_probes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.probes.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_requests(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.requests.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.rejected.load(Ordering::Relaxed))
            .sum()
    }

    pub fn total_errors(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.errors.load(Ordering::Relaxed))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_orders_quantiles() {
        let h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record_ms(1.0);
        }
        for _ in 0..10 {
            h.record_ms(100.0);
        }
        assert_eq!(h.count(), 100);
        let (p50, p99) = (h.quantile_ms(0.5), h.quantile_ms(0.99));
        assert!(p50 < p99, "p50 {p50} must be < p99 {p99}");
        assert!(p50 < 2.0, "p50 {p50} should sit near 1ms");
        assert!(p99 > 50.0, "p99 {p99} should sit near 100ms");
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
    }

    #[test]
    fn sub_microsecond_clamps_to_first_bucket() {
        let h = LatencyHistogram::new();
        h.record_ms(0.0);
        h.record_ms(1e-9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile_ms(1.0) < 0.01);
    }

    #[test]
    fn snapshot_and_totals() {
        let m = ServerMetrics::new(2);
        m.shards[0].probes.fetch_add(2, Ordering::Relaxed);
        m.shards[1].probes.fetch_add(1, Ordering::Relaxed);
        m.shards[1].requests.fetch_add(5, Ordering::Relaxed);
        m.shards[1].latency.record_ms(3.0);
        assert_eq!(m.total_probes(), 3);
        assert_eq!(m.total_requests(), 5);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].shard, 0);
        assert_eq!(snap[1].probes, 1);
        assert!(snap[1].p50_ms > 0.0);
    }
}
