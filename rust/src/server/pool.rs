//! Sharded worker pool: the concurrent serving engine.
//!
//! K worker threads, each owning its own execution backend (PJRT
//! handles are thread-bound; native backends are simply constructed
//! where they run). Requests are routed by `graph_sig` hash so one
//! graph's schedule locality stays on one shard, while the probed
//! decisions themselves live in a pool-wide [`SharedScheduleCache`]
//! with single-flight deduplication — a decision probed on any shard is
//! replayed by every shard.
//!
//! Each shard has a *bounded* queue: `try_submit` returns
//! [`SubmitError::QueueFull`] instead of growing unboundedly
//! (backpressure), `submit` blocks until the shard has room. Workers
//! drain their queue in batches (up to `serve_batch_max`, waiting up to
//! `serve_batch_window_us` for stragglers) and coalesce same
//! `(graph, op, F)` requests under one scheduling decision.
//!
//! Resilience (see [`super::resilience`]): per-request execution runs
//! under `catch_unwind` supervision — a panicking request is
//! quarantined and replied with a typed [`ServeError::Panic`] while
//! the shard keeps serving; requests carry a deadline and are shed at
//! dequeue once their queue wait blows it; a deterministic fault
//! injector can place backend errors / panics / latency spikes as a
//! pure function of (seed, request id); and under queue-depth overload
//! eligible SpMM requests degrade to an edge-sampled graph with a
//! per-reply error bound instead of rejecting. Clients can also opt
//! into the sampled-graph path explicitly (`submit_approx_*`): an
//! approximate request degrades regardless of queue depth and its
//! reply carries the same error bound.
//!
//! Validated model hot-reload: when `AUTOSAGE_MODEL_RELOAD_MS` > 0 a
//! watcher thread polls the model path off the request path. A changed
//! file is loaded through the generational reader (corrupt current →
//! previous generation; both corrupt → rejected, never installed) and
//! becomes a *canary candidate*: it shadows the incumbent, grading its
//! predictions against ground truth (probe outcomes and feature-
//! bearing cache hits) for `AUTOSAGE_MODEL_CANARY_N` observations.
//! Agreement ≥ `AUTOSAGE_MODEL_CANARY_AGREE` promotes it (workers pick
//! the new generation up at their next batch); anything less rolls it
//! back. Transitions land in `autosage_model_reloads_total` /
//! `autosage_model_rollbacks_total` and as `model_reload` trace events.

use std::path::PathBuf;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::Config;
use crate::coordinator::AutoSage;
use crate::data::sample::SampleSpec;
use crate::graph::signature::{graph_signature, Fnv1a};
use crate::graph::Csr;
use crate::model::CostModel;
use crate::obs::metrics::{feature_bucket, AuditSample, MetricsRegistry};
use crate::obs::trace::{Recorder, SpanRecord, TraceCtx, TraceId};
use crate::scheduler::{cache_key, CachedChoice, DecisionSource, Op};
use crate::telemetry::ServeShardStats;
use crate::util::iofault;

use super::metrics::{ServerMetrics, ShardMetrics};
use super::resilience::{FaultKind, QuarantineEntry, Resilience, ServeError};
use super::shared_cache::{Lookup, SharedScheduleCache};

/// Operator result + how it was scheduled and served.
pub struct ServeResponse {
    pub result: Result<Vec<f32>, ServeError>,
    /// Chosen kernel variant id ("" when scheduling itself failed).
    pub variant: String,
    /// Decision replayed from the (shared or worker-local) cache.
    pub from_cache: bool,
    pub shard: usize,
    /// Number of same-key requests that executed under this decision.
    pub batch_size: usize,
    /// Time spent queued before the worker started executing it.
    pub queue_ms: f64,
    /// End-to-end enqueue → response time.
    pub total_ms: f64,
    /// `Some(mass)` when this request was served on the edge-sampled
    /// graph (graceful degradation): the per-element error of an SpMM
    /// result is bounded by `mass × max|B|` (see `data::sample`).
    pub degraded: Option<f64>,
    /// Kind of chaos the fault injector applied to this request, if any
    /// ("error" / "panic" / "latency").
    pub injected_fault: Option<&'static str>,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's bounded queue is full (backpressure); retry
    /// later or use the blocking `submit`.
    QueueFull,
    /// The pool has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "shard queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server pool shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct QueuedRequest {
    op: Op,
    graph: Csr,
    f: usize,
    operands: Vec<(String, Vec<f32>)>,
    respond: mpsc::Sender<ServeResponse>,
    /// Structural graph signature (computed once at submit; also the
    /// routing key).
    sig: String,
    enqueued: Instant,
    /// Flight-recorder context the request travels under (None when the
    /// pool runs untraced).
    trace: Option<TraceCtx>,
    /// Pool-wide submission index — the fault injector's stream id.
    req_id: u64,
    /// Deadline propagated with the request (`AUTOSAGE_DEADLINE_MS`,
    /// 0 = none): shed at dequeue once queue wait exceeds it.
    deadline_ms: f64,
    /// Client opted into approximate serving: an eligible SpMM request
    /// takes the edge-sampled-graph path regardless of queue depth and
    /// its reply carries the error bound.
    approx: bool,
    /// Sentinel used by `debug_stop_shard`: makes the worker exit its
    /// loop cleanly after the current batch (never served).
    stop: bool,
}

struct Shard {
    tx: SyncSender<QueuedRequest>,
    join: JoinHandle<()>,
    /// Flipped false by the worker on ANY exit (shutdown, init
    /// failure, stop sentinel, unwinding panic) so submits fail fast
    /// with `Closed` instead of enqueueing into a dead shard.
    alive: Arc<AtomicBool>,
}

/// Sets the shard's liveness flag to false when the worker unwinds or
/// returns — the satellite fix: a dead shard is visible at submit time,
/// not only in pool `Drop`.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

/// A canary candidate model being graded in shadow mode.
struct Candidate {
    model: Arc<CostModel>,
    agree: u64,
    disagree: u64,
}

/// The pool's live model slot: the incumbent every worker serves with,
/// plus at most one canary candidate under shadow grading. Workers
/// watch `generation` and re-fetch the incumbent when it changes, so a
/// promotion never blocks the request path on a lock inside `decide`.
struct ModelSlot {
    incumbent: Mutex<Option<Arc<CostModel>>>,
    /// Bumped on every promotion.
    generation: AtomicU64,
    candidate: Mutex<Option<Candidate>>,
    reloads: AtomicU64,
    rollbacks: AtomicU64,
}

/// Outcome of grading one ground-truth observation against the canary.
enum CanaryVerdict {
    Promoted,
    RolledBack { agree: u64, disagree: u64 },
}

impl ModelSlot {
    fn new(initial: Option<Arc<CostModel>>) -> ModelSlot {
        ModelSlot {
            incumbent: Mutex::new(initial),
            generation: AtomicU64::new(0),
            candidate: Mutex::new(None),
            reloads: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
        }
    }

    fn current(&self) -> Option<Arc<CostModel>> {
        self.incumbent.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Install a freshly-loaded model as the canary candidate. Returns
    /// false when it is byte-equal to the incumbent (nothing to canary).
    /// A still-grading previous candidate is replaced and its partial
    /// grade discarded — the newest file wins.
    fn set_candidate(&self, m: Arc<CostModel>) -> bool {
        let mut cand = self.candidate.lock().unwrap_or_else(|p| p.into_inner());
        {
            let inc = self.incumbent.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(cur) = inc.as_ref() {
                if **cur == *m {
                    return false;
                }
            }
        }
        *cand = Some(Candidate { model: m, agree: 0, disagree: 0 });
        true
    }

    /// Grade one ground-truth `(op, features) → variant` observation
    /// against the candidate in shadow mode. Observations the candidate
    /// cannot predict (no tree for the op) don't count toward the
    /// quota. Returns the verdict once `canary_n` observations are in:
    /// agreement fraction ≥ `canary_agree` promotes the candidate to
    /// incumbent (new generation), anything less rolls it back.
    fn grade(
        &self,
        op: &str,
        features: &[f64],
        actual_variant: &str,
        canary_n: usize,
        canary_agree: f64,
    ) -> Option<CanaryVerdict> {
        let mut guard = self.candidate.lock().unwrap_or_else(|p| p.into_inner());
        let cand = guard.as_mut()?;
        let predicted = cand.model.predict(op, features)?;
        if predicted.variant == actual_variant {
            cand.agree += 1;
        } else {
            cand.disagree += 1;
        }
        let graded = cand.agree + cand.disagree;
        if (graded as usize) < canary_n.max(1) {
            return None;
        }
        let frac = cand.agree as f64 / graded as f64;
        let cand = guard.take().expect("candidate checked above");
        if frac >= canary_agree {
            let mut inc = self.incumbent.lock().unwrap_or_else(|p| p.into_inner());
            *inc = Some(cand.model);
            drop(inc);
            self.generation.fetch_add(1, Ordering::Release);
            self.reloads.fetch_add(1, Ordering::Relaxed);
            Some(CanaryVerdict::Promoted)
        } else {
            self.rollbacks.fetch_add(1, Ordering::Relaxed);
            Some(CanaryVerdict::RolledBack {
                agree: cand.agree,
                disagree: cand.disagree,
            })
        }
    }
}

/// Record a `model_reload` transition: counter + trace event. Runs off
/// the request path (watcher thread) or once per transition (grading),
/// never per request.
fn note_model_transition(
    registry: Option<&MetricsRegistry>,
    recorder: Option<&Recorder>,
    outcome: &str,
    detail: &str,
) {
    if let Some(reg) = registry {
        match outcome {
            "promoted" => reg.inc("autosage_model_reloads_total"),
            "rolled_back" | "rejected" => reg.inc("autosage_model_rollbacks_total"),
            _ => {}
        }
    }
    if let Some(r) = recorder {
        r.event(
            TraceId(0),
            None,
            "model_reload",
            vec![
                ("outcome".to_string(), outcome.to_string()),
                ("detail".to_string(), detail.to_string()),
            ],
        );
    }
}

/// Handle to the running pool. Dropping it shuts the workers down and
/// surfaces any worker panic (satellite: a crashed worker is not
/// silent).
pub struct ServerPool {
    shards: Vec<Shard>,
    metrics: Arc<ServerMetrics>,
    shared: Arc<SharedScheduleCache>,
    /// Configured per-shard queue bound (`max_queue_depth` clamp: the
    /// depth counter transiently includes in-flight submitters, but
    /// actual occupancy can never exceed this).
    queue_bound: u64,
    /// Flight recorder shared with every shard worker (None = untraced).
    recorder: Option<Arc<Recorder>>,
    /// Metrics registry shared with every shard worker (None = unmetered).
    registry: Option<Arc<MetricsRegistry>>,
    /// Live model slot: incumbent + canary candidate + generation.
    /// Workers re-fetch the incumbent when the generation changes.
    slot: Arc<ModelSlot>,
    /// Model-path watcher thread (hot-reload), present when
    /// `model_reload_ms > 0` and a model path is configured.
    watcher_stop: Arc<AtomicBool>,
    watcher: Option<JoinHandle<()>>,
    /// Fault injector + quarantine log + degrade cache, shared with
    /// every shard worker.
    resilience: Arc<Resilience>,
    /// Pool-wide request counter: each submission gets the next id,
    /// which is also its fault-injection stream.
    next_req_id: AtomicU64,
    /// Deadline stamped on every submitted request
    /// (`AUTOSAGE_DEADLINE_MS`, 0 = none).
    deadline_ms: f64,
}

/// Route a graph signature to a shard.
fn shard_of(sig: &str, n_shards: usize) -> usize {
    let mut h = Fnv1a::new();
    h.write(sig.as_bytes());
    (h.finish() % n_shards as u64) as usize
}

impl ServerPool {
    /// Spawn `cfg.serve_workers` shard workers. Each worker constructs
    /// its own backend on its own thread; the schedule cache (path from
    /// `cfg.cache_path`) is loaded once and shared across shards.
    pub fn spawn(artifacts_dir: PathBuf, cfg: Config) -> Result<ServerPool> {
        ServerPool::spawn_traced(artifacts_dir, cfg, None)
    }

    /// Like [`Self::spawn`], with a flight recorder: every shard worker
    /// records queue/schedule/execute/reply spans for traced requests.
    pub fn spawn_traced(
        artifacts_dir: PathBuf,
        cfg: Config,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<ServerPool> {
        ServerPool::spawn_observed(artifacts_dir, cfg, recorder, None)
    }

    /// Like [`Self::spawn_traced`], with a metrics registry: shard
    /// workers feed scheduler decision counters, batch-size histograms,
    /// cache-persistence counters, and the predicted-vs-measured audit
    /// stream into it.
    pub fn spawn_observed(
        artifacts_dir: PathBuf,
        cfg: Config,
        recorder: Option<Arc<Recorder>>,
        registry: Option<Arc<MetricsRegistry>>,
    ) -> Result<ServerPool> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        // Crash-point I/O chaos: install the seeded injector before the
        // first artifact touch so cache/model loads run under fire too.
        // Rate 0 leaves any manually-installed injector alone.
        if cfg.io_fault_rate > 0.0 {
            let kinds =
                iofault::parse_io_kinds(&cfg.io_fault_kinds).map_err(|e| anyhow!(e))?;
            iofault::install(Some(Arc::new(iofault::IoFaultInjector::new(
                cfg.io_fault_seed as u64,
                cfg.io_fault_rate,
                kinds,
            ))));
        }
        let n = cfg.serve_workers.max(1);
        let (shared, salvage) = SharedScheduleCache::load_salvaged(&cfg.cache_path);
        let shared = Arc::new(shared);
        if salvage.entries_quarantined > 0 || salvage.file_reset {
            let msg = format!(
                "schedule cache salvage: {} entries quarantined, file reset: {}",
                salvage.entries_quarantined, salvage.file_reset
            );
            if let Some(r) = &recorder {
                r.warn(None, "cache_salvage", &msg);
            } else {
                eprintln!("autosage: warning: {msg}");
            }
        }
        let metrics = Arc::new(ServerMetrics::new(n));
        let flush = Duration::from_millis(cfg.cache_flush_ms as u64);
        // The trained cost model (if any) is loaded ONCE here and shared
        // read-only across every shard — a load failure is a spawn-time
        // error, not K identical per-worker failures. The generational
        // reader falls back to the previous generation when the current
        // file is corrupt; only both-corrupt refuses to spawn.
        let model = if cfg.model_path.is_empty() {
            None
        } else {
            let (m, fell_back) = crate::model::read_model_generational(
                std::path::Path::new(&cfg.model_path),
            )?;
            if fell_back {
                let msg = format!(
                    "model {} corrupt; serving previous generation",
                    cfg.model_path
                );
                if let Some(r) = &recorder {
                    r.warn(None, "model_generation_fallback", &msg);
                } else {
                    eprintln!("autosage: warning: {msg}");
                }
            }
            Some(Arc::new(m))
        };
        let slot = Arc::new(ModelSlot::new(model));
        // Workers keep their scheduler caches in-memory: the shared
        // layer owns cross-shard visibility and persistence. The model
        // path is cleared too — workers receive the Arc, not the file.
        let mut worker_cfg = cfg.clone();
        worker_cfg.cache_path = String::new();
        worker_cfg.model_path = String::new();
        // One injector / quarantine log / degrade cache for the whole
        // pool: fault placement is pool-global by request id, and each
        // distinct graph is edge-sampled at most once.
        let resilience =
            Arc::new(Resilience::from_config(&cfg).map_err(|e| anyhow!(e))?);
        let mut shards = Vec::with_capacity(n);
        for shard_id in 0..n {
            let (tx, rx) = mpsc::sync_channel(cfg.serve_queue_depth.max(1));
            let dir = artifacts_dir.clone();
            let wcfg = worker_cfg.clone();
            let sh = Arc::clone(&shared);
            let m = Arc::clone(&metrics);
            let rec = recorder.clone();
            let reg = registry.clone();
            let sl = Arc::clone(&slot);
            let res = Arc::clone(&resilience);
            let alive = Arc::new(AtomicBool::new(true));
            let alive_w = Arc::clone(&alive);
            let join = std::thread::Builder::new()
                .name(format!("autosage-shard-{shard_id}"))
                .spawn(move || {
                    worker_loop(shard_id, rx, dir, wcfg, sh, m, rec, reg, sl, res, alive_w, flush)
                })
                .with_context(|| format!("spawning shard {shard_id} worker"))?;
            shards.push(Shard { tx, join, alive });
        }
        // Hot-reload watcher: polls the model path off the request path
        // and installs changed files as canary candidates.
        let watcher_stop = Arc::new(AtomicBool::new(false));
        let watcher = if cfg.model_reload_ms > 0 && !cfg.model_path.is_empty() {
            let path = PathBuf::from(&cfg.model_path);
            let sl = Arc::clone(&slot);
            let stop = Arc::clone(&watcher_stop);
            let rec = recorder.clone();
            let reg = registry.clone();
            let poll = Duration::from_millis(cfg.model_reload_ms as u64);
            Some(
                std::thread::Builder::new()
                    .name("autosage-model-watch".to_string())
                    .spawn(move || model_watcher(path, sl, poll, stop, rec, reg))
                    .context("spawning model hot-reload watcher")?,
            )
        } else {
            None
        };
        Ok(ServerPool {
            shards,
            metrics,
            shared,
            queue_bound: cfg.serve_queue_depth.max(1) as u64,
            recorder,
            registry,
            slot,
            watcher_stop,
            watcher,
            resilience,
            next_req_id: AtomicU64::new(0),
            deadline_ms: cfg.deadline_ms,
        })
    }

    /// Non-blocking submit: rejects with [`SubmitError::QueueFull`]
    /// when the target shard's bounded queue has no room.
    pub fn try_submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        self.try_submit_traced(op, graph, f, operands, None)
    }

    /// Non-blocking submit carrying a flight-recorder context — the
    /// retrying loadgen path.
    pub fn try_submit_traced(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
        trace: Option<TraceCtx>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        self.try_submit_opts(op, graph, f, operands, trace, false)
    }

    /// [`Self::try_submit_traced`] with the approximate-mode flag: an
    /// eligible SpMM request routes through the edge-sampled graph
    /// regardless of queue depth; the reply carries the error bound.
    pub fn try_submit_opts(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
        trace: Option<TraceCtx>,
        approx: bool,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        let (mut qr, shard, rx) = self.package(op, graph, f, operands);
        qr.trace = trace;
        qr.approx = approx;
        let sm = &self.metrics.shards[shard];
        // Dead-shard fast path (satellite): a stopped/crashed worker is
        // visible here, not only when the channel finally disconnects.
        if !self.shards[shard].alive.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        // Count depth *before* the send so the worker's decrement can
        // never observe (and wrap below) zero.
        let d = sm.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.shards[shard].tx.try_send(qr) {
            Ok(()) => {
                self.note_depth(sm, d);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                sm.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Blocking submit: waits for queue room instead of rejecting.
    pub fn submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        self.submit_traced(op, graph, f, operands, None)
    }

    /// Blocking submit carrying a flight-recorder context: the worker's
    /// queue/schedule/execute/reply spans attach to `trace`.
    pub fn submit_traced(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
        trace: Option<TraceCtx>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        self.submit_opts(op, graph, f, operands, trace, false)
    }

    /// [`Self::submit_traced`] with the approximate-mode flag (see
    /// [`Self::try_submit_opts`]).
    pub fn submit_opts(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
        trace: Option<TraceCtx>,
        approx: bool,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        let (mut qr, shard, rx) = self.package(op, graph, f, operands);
        qr.trace = trace;
        qr.approx = approx;
        let sm = &self.metrics.shards[shard];
        if !self.shards[shard].alive.load(Ordering::Acquire) {
            return Err(SubmitError::Closed);
        }
        let d = sm.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.shards[shard].tx.send(qr) {
            Ok(()) => {
                self.note_depth(sm, d);
                Ok(rx)
            }
            Err(_) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Convenience: blocking submit + wait for the response.
    pub fn call(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<ServeResponse> {
        let rx = self
            .submit(op, graph, f, operands)
            .map_err(|e| anyhow!("serve submit failed: {e}"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    fn package(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> (QueuedRequest, usize, Receiver<ServeResponse>) {
        let sig = graph_signature(&graph);
        let shard = shard_of(&sig, self.shards.len());
        let (respond, rx) = mpsc::channel();
        let qr = QueuedRequest {
            op,
            graph,
            f,
            operands,
            respond,
            sig,
            enqueued: Instant::now(),
            trace: None,
            req_id: self.next_req_id.fetch_add(1, Ordering::Relaxed),
            deadline_ms: self.deadline_ms,
            approx: false,
            stop: false,
        };
        (qr, shard, rx)
    }

    /// The pool's flight recorder, if it was spawned with one.
    pub fn recorder(&self) -> Option<&Arc<Recorder>> {
        self.recorder.as_ref()
    }

    /// The pool's metrics registry, if it was spawned with one.
    pub fn registry(&self) -> Option<&Arc<MetricsRegistry>> {
        self.registry.as_ref()
    }

    /// Whether a trained cost model is attached to the shards.
    pub fn has_model(&self) -> bool {
        self.slot.current().is_some()
    }

    /// Model generation currently served (bumps on every promotion).
    pub fn model_generation(&self) -> u64 {
        self.slot.generation()
    }

    /// Hot-reload promotions since spawn.
    pub fn model_reloads(&self) -> u64 {
        self.slot.reloads.load(Ordering::Relaxed)
    }

    /// Hot-reload rollbacks (canary disagreement or corrupt candidate)
    /// since spawn.
    pub fn model_rollbacks(&self) -> u64 {
        self.slot.rollbacks.load(Ordering::Relaxed)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    pub fn snapshot(&self) -> Vec<ServeShardStats> {
        self.metrics.snapshot()
    }

    /// (hits, misses, entries) of the shared schedule cache.
    pub fn cache_stats(&self) -> (usize, usize, usize) {
        self.shared.stats()
    }

    /// The pool's resilience state: fault injector (if chaos is on),
    /// quarantine log, degrade cache.
    pub fn resilience(&self) -> &Resilience {
        &self.resilience
    }

    /// Whether a shard's worker is still serving (false once it exits
    /// for any reason).
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.shards[shard].alive.load(Ordering::Acquire)
    }

    /// True when every shard worker is still serving — the chaos
    /// harness's "no permanently-dead shard" assertion.
    pub fn all_shards_alive(&self) -> bool {
        self.shards.iter().all(|s| s.alive.load(Ordering::Acquire))
    }

    /// Test hook: make one shard's worker exit its loop cleanly after
    /// the current batch — the "dead shard" scenario without a real
    /// crash. Blocks until the sentinel is enqueued.
    #[doc(hidden)]
    pub fn debug_stop_shard(&self, shard: usize) {
        let (respond, _rx) = mpsc::channel();
        let qr = QueuedRequest {
            op: Op::Spmm,
            graph: Csr::from_rows(0, Vec::new()),
            f: 0,
            operands: Vec::new(),
            respond,
            sig: String::new(),
            enqueued: Instant::now(),
            trace: None,
            req_id: u64::MAX,
            deadline_ms: 0.0,
            approx: false,
            stop: true,
        };
        let sm = &self.metrics.shards[shard];
        sm.queue_depth.fetch_add(1, Ordering::Relaxed);
        if self.shards[shard].tx.send(qr).is_err() {
            sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Record the observed queue depth after a SUCCESSFUL enqueue only
    /// (rejected/blocked submissions must not inflate the high-water
    /// mark), clamped to the configured bound since the raw counter
    /// transiently includes concurrent in-flight submitters.
    fn note_depth(&self, sm: &ShardMetrics, depth: u64) {
        sm.max_queue_depth
            .fetch_max(depth.min(self.queue_bound), Ordering::Relaxed);
    }
}

impl Drop for ServerPool {
    fn drop(&mut self) {
        // Stop the hot-reload watcher first: no candidate may install
        // while the pool is winding down.
        self.watcher_stop.store(true, Ordering::Release);
        if let Some(w) = self.watcher.take() {
            let _ = w.join();
        }
        // Close every shard queue first so all workers wind down in
        // parallel, then join and surface panics.
        let shards = std::mem::take(&mut self.shards);
        let mut joins = Vec::with_capacity(shards.len());
        for s in shards {
            drop(s.tx);
            joins.push(s.join);
        }
        for (i, j) in joins.into_iter().enumerate() {
            if let Err(panic) = j.join() {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                eprintln!("autosage: server shard {i} worker panicked: {msg}");
                // Never panic inside Drop while already unwinding — a
                // double panic aborts the test binary and masks the
                // original failure.
                debug_assert!(
                    std::thread::panicking(),
                    "server shard {i} worker panicked: {msg}"
                );
            }
        }
        // Final flush of dirty cache state (entries and hit/miss
        // counters) now that every worker has stopped. Failure is a
        // warning, not a panic: the serving session itself succeeded.
        // Satellite: the failure lands in the metrics warn counter and
        // the recorder; stderr is only the fallback when the pool runs
        // fully unobserved.
        if let Err(e) = self.shared.persist() {
            if let Some(reg) = &self.registry {
                reg.inc("autosage_cache_persist_errors_total");
            }
            if let Some(r) = &self.recorder {
                r.warn(None, "cache_persist_shutdown", &format!("{e:#}"));
            }
            if self.registry.is_none() && self.recorder.is_none() {
                eprintln!(
                    "autosage: warning: schedule cache flush on shutdown failed: {e:#}"
                );
            }
        }
    }
}

// ------------------------------------------------------------- worker

/// Per-worker resilience settings derived from config once at spawn.
struct WorkerSettings {
    queue_bound: u64,
    degrade_watermark: f64,
    sample_spec: SampleSpec,
    /// Canary quota: ground-truth observations graded before a
    /// candidate model's promote/rollback verdict.
    canary_n: usize,
    /// Agreement fraction required to promote (0.0 = always promote —
    /// the deterministic-promotion test knob).
    canary_agree: f64,
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shard: usize,
    rx: Receiver<QueuedRequest>,
    artifacts_dir: PathBuf,
    cfg: Config,
    shared: Arc<SharedScheduleCache>,
    metrics: Arc<ServerMetrics>,
    recorder: Option<Arc<Recorder>>,
    registry: Option<Arc<MetricsRegistry>>,
    slot: Arc<ModelSlot>,
    resilience: Arc<Resilience>,
    alive: Arc<AtomicBool>,
    flush: Duration,
) {
    let _alive = AliveGuard(alive);
    let batch_max = cfg.serve_batch_max.max(1);
    let window = Duration::from_micros(cfg.serve_batch_window_us as u64);
    let settings = WorkerSettings {
        queue_bound: cfg.serve_queue_depth.max(1) as u64,
        degrade_watermark: cfg.degrade_watermark,
        sample_spec: SampleSpec {
            keep_frac: cfg.degrade_keep_frac,
            min_keep_deg: cfg.degrade_min_deg,
        },
        canary_n: cfg.model_canary_n,
        canary_agree: cfg.model_canary_agree,
    };
    let mut sage = match AutoSage::new(&artifacts_dir, cfg, None) {
        Ok(s) => s,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("shard {shard} init failed: {e:#}");
            let sm = &metrics.shards[shard];
            for req in rx {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                if req.stop {
                    continue;
                }
                sm.requests.fetch_add(1, Ordering::Relaxed);
                sm.errors.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(ServeResponse {
                    result: Err(ServeError::Execute { msg: msg.clone(), injected: false }),
                    variant: String::new(),
                    from_cache: false,
                    shard,
                    batch_size: 0,
                    queue_ms: 0.0,
                    total_ms: 0.0,
                    degraded: None,
                    injected_fault: None,
                });
            }
            return;
        }
    };
    sage.set_recorder(recorder.clone());
    sage.set_metrics(registry.clone());
    let mut model_gen = slot.generation();
    sage.set_model(slot.current());
    while let Ok(first) = rx.recv() {
        // Pick up a hot-reload promotion at batch granularity: the
        // generation check is one atomic load per batch, the slot lock
        // is touched only when it actually changed.
        let g = slot.generation();
        if g != model_gen {
            model_gen = g;
            sage.set_model(slot.current());
        }
        let mut batch = collect_batch(&rx, first, batch_max, window);
        let sm = &metrics.shards[shard];
        sm.queue_depth.fetch_sub(batch.len() as u64, Ordering::Relaxed);
        let stop = batch.iter().any(|q| q.stop);
        if stop {
            batch.retain(|q| !q.stop);
        }
        if !batch.is_empty() {
            sm.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            sm.batches.fetch_add(1, Ordering::Relaxed);
            if let Some(reg) = &registry {
                // Batch *size*, not latency — reuse the log2 buckets
                // anyway: the interesting question ("did coalescing
                // happen at all, and how skewed is it") survives the
                // coarse resolution.
                reg.histogram("autosage_pool_batch_size").record_ms(batch.len() as f64);
            }
            serve_batch(
                shard,
                &mut sage,
                &shared,
                sm,
                recorder.as_deref(),
                registry.as_deref(),
                &resilience,
                &settings,
                &slot,
                batch,
            );
        }
        // Satellite (PR 2 debt): cache persistence moved off the
        // pool-wide mutex and out of `ProbeTicket::resolve` — dirty
        // state flushes here, throttled, and I/O errors demote to a
        // warning trace event instead of failing requests.
        match shared.maybe_persist(flush) {
            Ok(true) => {
                if let Some(reg) = &registry {
                    reg.inc("autosage_cache_persist_total");
                }
            }
            Ok(false) => {}
            Err(e) => {
                if let Some(reg) = &registry {
                    reg.inc("autosage_cache_persist_errors_total");
                }
                if let Some(r) = &recorder {
                    r.warn(None, "cache_persist", &format!("{e:#}"));
                }
                eprintln!("autosage: warning: schedule cache flush failed: {e:#}");
            }
        }
        // Same throttle pattern for the trace ring: long serving runs
        // stream spans to disk instead of holding everything in memory.
        if let Some(r) = &recorder {
            if let Err(e) = r.maybe_flush() {
                r.warn(None, "trace_flush", &format!("{e:#}"));
                eprintln!("autosage: warning: trace flush failed: {e:#}");
            }
        }
        if stop {
            return;
        }
    }
}

/// Drain up to `batch_max` requests, waiting at most `window` past the
/// first one for stragglers (window 0 = drain whatever is queued now).
fn collect_batch(
    rx: &Receiver<QueuedRequest>,
    first: QueuedRequest,
    batch_max: usize,
    window: Duration,
) -> Vec<QueuedRequest> {
    let mut batch = vec![first];
    let opened = Instant::now();
    while batch.len() < batch_max {
        let elapsed = opened.elapsed();
        if elapsed >= window {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        } else {
            match rx.recv_timeout(window - elapsed) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    batch
}

/// Extract a readable message from a caught panic payload.
fn panic_message(panic: Box<dyn std::any::Any + Send>) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

/// Quarantine a poisoning request: bounded log + counter + warn trace.
fn quarantine_request(
    res: &Resilience,
    registry: Option<&MetricsRegistry>,
    recorder: Option<&Recorder>,
    entry: QuarantineEntry,
) {
    if let Some(reg) = registry {
        reg.inc("autosage_requests_quarantined_total");
    }
    if let Some(r) = recorder {
        r.warn(
            None,
            "quarantine",
            &format!(
                "shard {} req {} op {} F{} sig {}: {}",
                entry.shard, entry.req_id, entry.op, entry.f, entry.sig, entry.msg
            ),
        );
    }
    res.quarantine.record(entry);
}

/// Reply to one request with its final result, recording latency and
/// the reply trace event. Counter updates (errors/completed/shed/…)
/// stay with the caller — they differ per path.
#[allow(clippy::too_many_arguments)]
fn reply_now(
    shard: usize,
    sm: &ShardMetrics,
    recorder: Option<&Recorder>,
    qr: QueuedRequest,
    result: Result<Vec<f32>, ServeError>,
    variant: String,
    from_cache: bool,
    batch_size: usize,
    queue_ms: f64,
    degraded: Option<f64>,
    injected_fault: Option<&'static str>,
) {
    let ok = result.is_ok();
    let total_ms = ms_since(qr.enqueued);
    sm.latency.record_ms(total_ms);
    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
        r.event(
            ctx.trace,
            Some(ctx.parent),
            "reply",
            vec![("ok".to_string(), ok.to_string())],
        );
    }
    let _ = qr.respond.send(ServeResponse {
        result,
        variant,
        from_cache,
        shard,
        batch_size,
        queue_ms,
        total_ms,
        degraded,
        injected_fault,
    });
}

/// Shed a request whose queue wait blew its deadline: typed
/// `DeadlineExceeded` reply, `shed` counter, trace event.
fn shed_request(
    shard: usize,
    sm: &ShardMetrics,
    recorder: Option<&Recorder>,
    qr: QueuedRequest,
    batch_size: usize,
) {
    sm.shed.fetch_add(1, Ordering::Relaxed);
    let waited_ms = ms_since(qr.enqueued);
    let deadline_ms = qr.deadline_ms;
    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
        r.event(
            ctx.trace,
            Some(ctx.parent),
            "shed",
            vec![("waited_ms".to_string(), format!("{waited_ms:.3}"))],
        );
    }
    reply_now(
        shard,
        sm,
        recorder,
        qr,
        Err(ServeError::DeadlineExceeded { waited_ms, deadline_ms }),
        String::new(),
        false,
        batch_size,
        waited_ms,
        None,
        None,
    );
}

/// Group a batch by coalescing key (graph signature, op, F) preserving
/// arrival order, then schedule each group ONCE and execute its members
/// under that decision. Scheduling and execution both run under
/// `catch_unwind` supervision: a panic quarantines the poisoning
/// request and the worker keeps serving.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    shard: usize,
    sage: &mut AutoSage,
    shared: &SharedScheduleCache,
    sm: &ShardMetrics,
    recorder: Option<&Recorder>,
    registry: Option<&MetricsRegistry>,
    res: &Resilience,
    settings: &WorkerSettings,
    slot: &ModelSlot,
    batch: Vec<QueuedRequest>,
) {
    // Deadline shedding at dequeue: a request that already waited past
    // its deadline is not worth scheduling, let alone executing.
    let mut live = Vec::with_capacity(batch.len());
    for qr in batch {
        if qr.deadline_ms > 0.0 && ms_since(qr.enqueued) > qr.deadline_ms {
            shed_request(shard, sm, recorder, qr, 1);
        } else {
            live.push(qr);
        }
    }
    let mut groups: Vec<(String, Vec<QueuedRequest>)> = Vec::new();
    for qr in live {
        let gk = format!("{}|{}|F{}", qr.sig, qr.op.as_str(), qr.f);
        match groups.iter_mut().find(|(k, _)| *k == gk) {
            Some((_, members)) => members.push(qr),
            None => groups.push((gk, vec![qr])),
        }
    }
    for (_, group) in groups {
        let batch_size = group.len();
        if batch_size > 1 {
            sm.coalesced.fetch_add(batch_size as u64 - 1, Ordering::Relaxed);
        }
        let leader = &group[0];
        // Pre-allocate the schedule span id and point the scheduler's
        // trace context at it, so estimate/probe/guardrail sub-spans and
        // cache events emitted inside `decide` parent under it.
        let sched = match (recorder, leader.trace) {
            (Some(r), Some(ctx)) => {
                let span = r.next_span_id();
                sage.set_trace_ctx(Some((ctx.trace, span)));
                Some((r, ctx, span, r.now_us()))
            }
            _ => {
                sage.set_trace_ctx(None);
                None
            }
        };
        // Supervised scheduling: a panic inside decide (estimate,
        // probe, backend) quarantines the group leader and fails the
        // group with a typed reply — the shard stays alive.
        let decided: Result<(String, DecisionSource), ServeError> = match catch_unwind(
            AssertUnwindSafe(|| {
                decide_for(sage, shared, sm, slot, settings, registry, recorder, leader)
            }),
        ) {
                Ok(Ok(v)) => Ok(v),
                Ok(Err(e)) => {
                    Err(ServeError::Execute { msg: format!("{e:#}"), injected: false })
                }
                Err(panic) => {
                    let msg = panic_message(panic);
                    sm.panics.fetch_add(1, Ordering::Relaxed);
                    quarantine_request(
                        res,
                        registry,
                        recorder,
                        QuarantineEntry {
                            req_id: leader.req_id,
                            shard,
                            sig: leader.sig.clone(),
                            op: leader.op.as_str().to_string(),
                            f: leader.f,
                            injected: false,
                            msg: msg.clone(),
                        },
                    );
                    Err(ServeError::Panic { msg, injected: false })
                }
            };
        if let Some((r, ctx, span, start_us)) = sched {
            let (outcome, source, variant) = match &decided {
                Ok((v, src)) => {
                    let s = match src {
                        DecisionSource::Cache => "cache",
                        DecisionSource::Probe => "probe",
                        DecisionSource::Model => "model",
                        DecisionSource::ReplayFallback => "replay",
                    };
                    ("ok", s, v.clone())
                }
                Err(_) => ("error", "-", String::new()),
            };
            r.record(SpanRecord {
                trace: ctx.trace,
                span,
                parent: Some(ctx.parent),
                name: "schedule".to_string(),
                start_us,
                dur_us: r.now_us().saturating_sub(start_us),
                attrs: vec![
                    ("batch_size".to_string(), batch_size.to_string()),
                    ("outcome".to_string(), outcome.to_string()),
                    ("source".to_string(), source.to_string()),
                    ("variant".to_string(), variant),
                ],
            });
        }
        match decided {
            Err(e) => {
                for qr in group {
                    sm.errors.fetch_add(1, Ordering::Relaxed);
                    let queue_ms = ms_since(qr.enqueued);
                    reply_now(
                        shard,
                        sm,
                        recorder,
                        qr,
                        Err(e.clone()),
                        String::new(),
                        false,
                        batch_size,
                        queue_ms,
                        None,
                        None,
                    );
                }
            }
            Ok((variant, source)) => {
                let from_cache = source == DecisionSource::Cache;
                // Audit loop: the roofline's prediction for the chosen
                // variant, computed ONCE per coalescing group (members
                // share graph/op/F by construction), compared below
                // against each member's measured execute time. Every
                // cleanly executed request is audited — the audit
                // stream is deliberately NOT subject to trace sampling,
                // but faulted/degraded executions are skipped (their
                // measured time is not the full-graph prediction's).
                let audit = registry.map(|_| {
                    let leader = &group[0];
                    (
                        sage.estimate_ms(&leader.graph, leader.op, leader.f, &variant),
                        feature_bucket(leader.graph.n_rows, leader.graph.nnz(), leader.f),
                        leader.op.as_str().to_string(),
                    )
                });
                for qr in group {
                    // Re-check the deadline before executing: injected
                    // latency or a slow batch-mate may have burned the
                    // budget since dequeue.
                    if qr.deadline_ms > 0.0 && ms_since(qr.enqueued) > qr.deadline_ms {
                        shed_request(shard, sm, recorder, qr, batch_size);
                        continue;
                    }
                    let queue_ms = ms_since(qr.enqueued);
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        r.span_between(
                            ctx.trace,
                            Some(ctx.parent),
                            "queue",
                            r.us_of(qr.enqueued),
                            r.now_us(),
                            vec![("shard".to_string(), shard.to_string())],
                        );
                    }
                    // Deterministic chaos placement: pure in
                    // (fault seed, request id), so same-seed runs
                    // inject the identical fault set.
                    let fault = res.injector.as_ref().and_then(|inj| inj.decide(qr.req_id));
                    let injected_kind = fault.map(|k| k.as_str());
                    if let Some(kind) = fault {
                        if let Some(inj) = res.injector.as_ref() {
                            inj.note(qr.req_id, kind);
                        }
                        if let Some(reg) = registry {
                            reg.inc("autosage_faults_injected_total");
                            reg.inc(&format!(
                                "autosage_faults_injected_total{{kind=\"{}\"}}",
                                kind.as_str()
                            ));
                        }
                        if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                            r.event(
                                ctx.trace,
                                Some(ctx.parent),
                                "fault",
                                vec![("kind".to_string(), kind.as_str().to_string())],
                            );
                        }
                    }
                    // Graceful degradation: queue depth at/over the
                    // watermark — or an explicit approximate-mode
                    // request — serves eligible SpMM on the
                    // edge-sampled graph instead of the full one.
                    let degrade = if qr.op == Op::Spmm
                        && !matches!(fault, Some(FaultKind::Error))
                    {
                        let overloaded = settings.degrade_watermark > 0.0 && {
                            let depth = sm.queue_depth.load(Ordering::Relaxed) as f64;
                            depth
                                >= settings.degrade_watermark
                                    * settings.queue_bound as f64
                        };
                        if qr.approx || overloaded {
                            let sg = res.degrade.get_or_build(
                                &qr.sig,
                                &qr.graph,
                                &settings.sample_spec,
                            );
                            // A graph with nothing to drop gains
                            // nothing from "degrading".
                            if sg.report.edges_dropped > 0 {
                                Some(sg)
                            } else {
                                None
                            }
                        } else {
                            None
                        }
                    } else {
                        None
                    };
                    if qr.approx && degrade.is_some() {
                        if let Some(reg) = registry {
                            reg.inc("autosage_approx_served_total");
                        }
                    }
                    let degraded_mass =
                        degrade.as_ref().map(|sg| sg.report.max_row_dropped_mass);
                    let exec_start_us = recorder.map(|r| r.now_us());
                    let exec_started = Instant::now();
                    let result: Result<Vec<f32>, ServeError> = if matches!(
                        fault,
                        Some(FaultKind::Error)
                    ) {
                        Err(ServeError::Execute {
                            msg: format!("injected backend error (req {})", qr.req_id),
                            injected: true,
                        })
                    } else {
                        if matches!(fault, Some(FaultKind::Latency)) {
                            let ms =
                                res.injector.as_ref().map(|i| i.latency_ms()).unwrap_or(0.0);
                            std::thread::sleep(Duration::from_secs_f64(ms.max(0.0) / 1e3));
                        }
                        let exec_graph: &Csr =
                            degrade.as_ref().map(|sg| &sg.graph).unwrap_or(&qr.graph);
                        let inject_panic = matches!(fault, Some(FaultKind::Panic));
                        // Worker supervision: the panic (injected or
                        // organic) unwinds only to here.
                        match catch_unwind(AssertUnwindSafe(|| {
                            if inject_panic {
                                panic!("injected worker panic (req {})", qr.req_id);
                            }
                            execute_one(sage, &qr, exec_graph, &variant)
                        })) {
                            Ok(Ok(v)) => Ok(v),
                            Ok(Err(e)) => Err(ServeError::Execute {
                                msg: format!("{e:#}"),
                                injected: false,
                            }),
                            Err(panic) => {
                                let msg = panic_message(panic);
                                sm.panics.fetch_add(1, Ordering::Relaxed);
                                quarantine_request(
                                    res,
                                    registry,
                                    recorder,
                                    QuarantineEntry {
                                        req_id: qr.req_id,
                                        shard,
                                        sig: qr.sig.clone(),
                                        op: qr.op.as_str().to_string(),
                                        f: qr.f,
                                        injected: inject_panic,
                                        msg: msg.clone(),
                                    },
                                );
                                Err(ServeError::Panic { msg, injected: inject_panic })
                            }
                        }
                    };
                    let exec_ms = ms_since(exec_started);
                    if let (Some(reg), Some((pred, bucket, op))) = (registry, audit.as_ref()) {
                        let clean = result.is_ok() && fault.is_none() && degrade.is_none();
                        if let (Some(p), true) = (pred, clean) {
                            reg.record_audit(AuditSample::executed(
                                op.clone(),
                                variant.clone(),
                                bucket.clone(),
                                *p,
                                exec_ms,
                            ));
                        }
                        reg.histogram("autosage_execute_ms").record_ms(exec_ms);
                    }
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        let mut attrs = vec![
                            ("variant".to_string(), variant.clone()),
                            ("backend".to_string(), sage.backend_name().to_string()),
                            ("shard".to_string(), shard.to_string()),
                        ];
                        if let Some((Some(p), _, _)) = audit.as_ref() {
                            attrs.push(("predicted_ms".to_string(), format!("{p:.4}")));
                        }
                        if let Some(mass) = degraded_mass {
                            attrs.push(("degraded".to_string(), "true".to_string()));
                            attrs.push(("error_bound_mass".to_string(), format!("{mass:.6}")));
                        }
                        if let Some(kind) = injected_kind {
                            attrs.push(("injected_fault".to_string(), kind.to_string()));
                        }
                        r.span_between(
                            ctx.trace,
                            Some(ctx.parent),
                            "execute",
                            exec_start_us.unwrap_or(0),
                            r.now_us(),
                            attrs,
                        );
                    }
                    match &result {
                        Ok(_) => {
                            sm.completed.fetch_add(1, Ordering::Relaxed);
                            if degrade.is_some() {
                                sm.degraded.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            sm.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    };
                    reply_now(
                        shard,
                        sm,
                        recorder,
                        qr,
                        result,
                        variant.clone(),
                        from_cache,
                        batch_size,
                        queue_ms,
                        degraded_mass,
                        injected_kind,
                    );
                }
            }
        }
    }
}

/// Grade one ground-truth observation against the canary candidate (if
/// any) and record the promote/rollback transition when the verdict
/// quota is reached. Cheap when no candidate is in flight: one lock
/// acquire, no prediction.
fn canary_grade(
    slot: &ModelSlot,
    settings: &WorkerSettings,
    registry: Option<&MetricsRegistry>,
    recorder: Option<&Recorder>,
    op: &str,
    features: &[f64],
    actual_variant: &str,
) {
    match slot.grade(
        op,
        features,
        actual_variant,
        settings.canary_n,
        settings.canary_agree,
    ) {
        None => {}
        Some(CanaryVerdict::Promoted) => {
            note_model_transition(
                registry,
                recorder,
                "promoted",
                &format!("canary agreed over {} observations", settings.canary_n),
            );
        }
        Some(CanaryVerdict::RolledBack { agree, disagree }) => {
            note_model_transition(
                registry,
                recorder,
                "rolled_back",
                &format!("canary agreement {agree}/{}", agree + disagree),
            );
        }
    }
}

/// Schedule one coalescing group: shared-cache lookup with
/// single-flight — concurrent misses on the same key across shards
/// block on ONE probe instead of probing K times.
#[allow(clippy::too_many_arguments)]
fn decide_for(
    sage: &mut AutoSage,
    shared: &SharedScheduleCache,
    sm: &ShardMetrics,
    slot: &ModelSlot,
    settings: &WorkerSettings,
    registry: Option<&MetricsRegistry>,
    recorder: Option<&Recorder>,
    leader: &QueuedRequest,
) -> Result<(String, DecisionSource)> {
    let key = cache_key(
        &sage.backend_signature(),
        &leader.sig,
        if leader.op.has_f() { leader.f } else { 0 },
        leader.op.as_str(),
    );
    match shared.lookup(&key) {
        Lookup::Hit(c) => {
            sm.cache_hits.fetch_add(1, Ordering::Relaxed);
            // Feature-bearing cache hits are probe outcomes from an
            // earlier request — ground truth the canary candidate is
            // graded against in shadow mode.
            if let Some(feats) = c.features.as_deref() {
                canary_grade(
                    slot,
                    settings,
                    registry,
                    recorder,
                    leader.op.as_str(),
                    feats,
                    &c.variant,
                );
            }
            Ok((c.variant, DecisionSource::Cache))
        }
        Lookup::Probe(ticket) => {
            // On error the ticket drops unresolved, handing the probe
            // to a waiter instead of wedging the key.
            let d = sage.decide(&leader.graph, leader.op, leader.f)?;
            if d.source == DecisionSource::Probe {
                sm.probes.fetch_add(1, Ordering::Relaxed);
            }
            // A fresh probe outcome is the strongest ground truth the
            // shadow canary gets.
            if d.source == DecisionSource::Probe {
                if let Some(feats) = d.features.as_deref() {
                    canary_grade(
                        slot,
                        settings,
                        registry,
                        recorder,
                        leader.op.as_str(),
                        feats,
                        d.choice.variant(),
                    );
                }
            }
            // Probe resolutions carry the input's feature vector into
            // the shared cache (training data for `autosage train`);
            // model-predicted decisions deliberately carry none.
            ticket.resolve(CachedChoice {
                variant: d.choice.variant().to_string(),
                t_baseline_ms: d.t_baseline_ms,
                t_star_ms: d.t_star_ms,
                alpha: sage.config().alpha,
                features: d.features,
            });
            Ok((d.choice.variant().to_string(), d.source))
        }
    }
}

/// Hot-reload watcher body: poll the model path, load changed files
/// through the generational reader off the request path, and install
/// them as canary candidates. A file that fails to load through BOTH
/// generations is rejected and counted as a rollback — a torn or
/// corrupt write can never reach serving.
fn model_watcher(
    path: PathBuf,
    slot: Arc<ModelSlot>,
    poll: Duration,
    stop: Arc<AtomicBool>,
    recorder: Option<Arc<Recorder>>,
    registry: Option<Arc<MetricsRegistry>>,
) {
    let fingerprint = |p: &std::path::Path| -> Option<(u64, std::time::SystemTime)> {
        let md = std::fs::metadata(p).ok()?;
        Some((md.len(), md.modified().ok()?))
    };
    let mut last = fingerprint(&path);
    while !stop.load(Ordering::Acquire) {
        // Sleep in short slices so pool Drop never waits a full poll.
        let mut slept = Duration::from_millis(0);
        while slept < poll && !stop.load(Ordering::Acquire) {
            let step = (poll - slept).min(Duration::from_millis(20));
            std::thread::sleep(step);
            slept += step;
        }
        if stop.load(Ordering::Acquire) {
            return;
        }
        let now = fingerprint(&path);
        if now.is_none() || now == last {
            continue;
        }
        last = now;
        match crate::model::read_model_generational(&path) {
            Ok((m, fell_back)) => {
                if slot.set_candidate(Arc::new(m)) {
                    note_model_transition(
                        registry.as_deref(),
                        recorder.as_deref(),
                        "candidate",
                        &format!(
                            "loaded {} (generation fallback: {fell_back})",
                            path.display()
                        ),
                    );
                }
            }
            Err(e) => {
                slot.rollbacks.fetch_add(1, Ordering::Relaxed);
                note_model_transition(
                    registry.as_deref(),
                    recorder.as_deref(),
                    "rejected",
                    &format!("{e:#}"),
                );
            }
        }
    }
}

/// Execute one request's op on `graph` — usually `qr.graph`, but the
/// edge-sampled substitute when the request degraded under overload.
fn execute_one(
    sage: &mut AutoSage,
    qr: &QueuedRequest,
    graph: &Csr,
    variant: &str,
) -> Result<Vec<f32>> {
    let get = |name: &str| -> Result<&Vec<f32>> {
        qr.operands
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("request missing operand {name:?}"))
    };
    match qr.op {
        Op::Spmm => sage.spmm_with(graph, get("b")?, qr.f, variant),
        Op::Sddmm => sage.sddmm_with(graph, get("x")?, get("y")?, qr.f, variant),
        Op::Softmax => sage.softmax_with(graph, get("val")?, variant),
        Op::Attention => sage.attention_with(
            graph,
            get("q")?,
            get("k")?,
            get("v")?,
            qr.f,
            variant,
        ),
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_stable_and_bounded() {
        for n in 1..9 {
            let s = shard_of("abc123ff00", n);
            assert!(s < n);
            assert_eq!(s, shard_of("abc123ff00", n), "routing must be pure");
        }
        assert_eq!(shard_of("anything", 1), 0);
    }

    #[test]
    fn submit_error_display() {
        assert!(SubmitError::QueueFull.to_string().contains("full"));
        assert!(SubmitError::Closed.to_string().contains("shut down"));
        assert_ne!(SubmitError::QueueFull, SubmitError::Closed);
    }

    /// A one-op model that predicts `hi` for feature[0]=1 and `lo` for
    /// feature[0]=0 (13-wide vectors matching FEATURE_NAMES).
    fn split_model(lo: &str, hi: &str) -> Arc<CostModel> {
        let ex = |f0: f64, label: &str| crate::model::Example {
            op: "spmm".to_string(),
            features: {
                let mut v = vec![0.0; 13];
                v[0] = f0;
                v
            },
            label: label.to_string(),
        };
        let examples =
            vec![ex(0.0, lo), ex(1.0, hi), ex(0.0, lo), ex(1.0, hi)];
        Arc::new(CostModel::train(&examples, &[], 7, 4).unwrap())
    }

    fn hi_features() -> Vec<f64> {
        let mut v = vec![0.0; 13];
        v[0] = 1.0;
        v
    }

    #[test]
    fn canary_promotes_on_agreement() {
        let slot = ModelSlot::new(None);
        assert!(slot.set_candidate(split_model("csr", "ell")));
        // Agreement threshold 0.0 with quota 1: one graded observation
        // promotes deterministically, whatever the candidate predicted.
        let verdict = slot.grade("spmm", &hi_features(), "ell", 1, 0.0);
        assert!(matches!(verdict, Some(CanaryVerdict::Promoted)));
        assert_eq!(slot.generation(), 1);
        assert_eq!(slot.reloads.load(Ordering::Relaxed), 1);
        let promoted = slot.current().expect("promoted model installed");
        assert_eq!(
            promoted.predict("spmm", &hi_features()).unwrap().variant,
            "ell"
        );
    }

    #[test]
    fn canary_rolls_back_on_disagreement() {
        let slot = ModelSlot::new(Some(split_model("csr", "ell")));
        assert!(slot.set_candidate(split_model("csr", "hub")));
        // The candidate predicts "hub" where ground truth says "ell":
        // 0/1 agreement under a 0.5 threshold rolls it back.
        let verdict = slot.grade("spmm", &hi_features(), "ell", 1, 0.5);
        assert!(matches!(
            verdict,
            Some(CanaryVerdict::RolledBack { agree: 0, disagree: 1 })
        ));
        assert_eq!(slot.generation(), 0, "rollback must not bump the generation");
        assert_eq!(slot.rollbacks.load(Ordering::Relaxed), 1);
        // The incumbent keeps serving and the candidate is gone.
        assert_eq!(
            slot.current().unwrap().predict("spmm", &hi_features()).unwrap().variant,
            "ell"
        );
        assert!(slot.grade("spmm", &hi_features(), "ell", 1, 0.0).is_none());
    }

    #[test]
    fn identical_candidate_is_ignored_and_unknown_ops_do_not_count() {
        let m = split_model("csr", "ell");
        let slot = ModelSlot::new(Some(Arc::clone(&m)));
        assert!(
            !slot.set_candidate(Arc::clone(&m)),
            "byte-equal model must not re-canary"
        );
        let other = split_model("csr", "hub");
        assert!(slot.set_candidate(other));
        // An op the candidate has no tree for doesn't consume quota.
        assert!(slot.grade("sddmm", &hi_features(), "ell", 1, 0.0).is_none());
        assert!(matches!(
            slot.grade("spmm", &hi_features(), "hub", 1, 0.0),
            Some(CanaryVerdict::Promoted)
        ));
    }
}
