//! Sharded worker pool: the concurrent serving engine.
//!
//! K worker threads, each owning its own execution backend (PJRT
//! handles are thread-bound; native backends are simply constructed
//! where they run). Requests are routed by `graph_sig` hash so one
//! graph's schedule locality stays on one shard, while the probed
//! decisions themselves live in a pool-wide [`SharedScheduleCache`]
//! with single-flight deduplication — a decision probed on any shard is
//! replayed by every shard.
//!
//! Each shard has a *bounded* queue: `try_submit` returns
//! [`SubmitError::QueueFull`] instead of growing unboundedly
//! (backpressure), `submit` blocks until the shard has room. Workers
//! drain their queue in batches (up to `serve_batch_max`, waiting up to
//! `serve_batch_window_us` for stragglers) and coalesce same
//! `(graph, op, F)` requests under one scheduling decision.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::Config;
use crate::coordinator::AutoSage;
use crate::graph::signature::{graph_signature, Fnv1a};
use crate::graph::Csr;
use crate::obs::metrics::{feature_bucket, AuditSample, MetricsRegistry};
use crate::obs::trace::{Recorder, SpanRecord, TraceCtx};
use crate::scheduler::{cache_key, CachedChoice, DecisionSource, Op};
use crate::telemetry::ServeShardStats;

use super::metrics::{ServerMetrics, ShardMetrics};
use super::shared_cache::{Lookup, SharedScheduleCache};

/// Operator result + how it was scheduled and served.
pub struct ServeResponse {
    pub result: Result<Vec<f32>>,
    /// Chosen kernel variant id ("" when scheduling itself failed).
    pub variant: String,
    /// Decision replayed from the (shared or worker-local) cache.
    pub from_cache: bool,
    pub shard: usize,
    /// Number of same-key requests that executed under this decision.
    pub batch_size: usize,
    /// Time spent queued before the worker started executing it.
    pub queue_ms: f64,
    /// End-to-end enqueue → response time.
    pub total_ms: f64,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's bounded queue is full (backpressure); retry
    /// later or use the blocking `submit`.
    QueueFull,
    /// The pool has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "shard queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server pool shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct QueuedRequest {
    op: Op,
    graph: Csr,
    f: usize,
    operands: Vec<(String, Vec<f32>)>,
    respond: mpsc::Sender<ServeResponse>,
    /// Structural graph signature (computed once at submit; also the
    /// routing key).
    sig: String,
    enqueued: Instant,
    /// Flight-recorder context the request travels under (None when the
    /// pool runs untraced).
    trace: Option<TraceCtx>,
}

struct Shard {
    tx: SyncSender<QueuedRequest>,
    join: JoinHandle<()>,
}

/// Handle to the running pool. Dropping it shuts the workers down and
/// surfaces any worker panic (satellite: a crashed worker is not
/// silent).
pub struct ServerPool {
    shards: Vec<Shard>,
    metrics: Arc<ServerMetrics>,
    shared: Arc<SharedScheduleCache>,
    /// Configured per-shard queue bound (`max_queue_depth` clamp: the
    /// depth counter transiently includes in-flight submitters, but
    /// actual occupancy can never exceed this).
    queue_bound: u64,
    /// Flight recorder shared with every shard worker (None = untraced).
    recorder: Option<Arc<Recorder>>,
    /// Metrics registry shared with every shard worker (None = unmetered).
    registry: Option<Arc<MetricsRegistry>>,
    /// Trained cost model shared read-only with every shard worker
    /// (None = probe-only scheduling).
    model: Option<Arc<crate::model::CostModel>>,
}

/// Route a graph signature to a shard.
fn shard_of(sig: &str, n_shards: usize) -> usize {
    let mut h = Fnv1a::new();
    h.write(sig.as_bytes());
    (h.finish() % n_shards as u64) as usize
}

impl ServerPool {
    /// Spawn `cfg.serve_workers` shard workers. Each worker constructs
    /// its own backend on its own thread; the schedule cache (path from
    /// `cfg.cache_path`) is loaded once and shared across shards.
    pub fn spawn(artifacts_dir: PathBuf, cfg: Config) -> Result<ServerPool> {
        ServerPool::spawn_traced(artifacts_dir, cfg, None)
    }

    /// Like [`Self::spawn`], with a flight recorder: every shard worker
    /// records queue/schedule/execute/reply spans for traced requests.
    pub fn spawn_traced(
        artifacts_dir: PathBuf,
        cfg: Config,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<ServerPool> {
        ServerPool::spawn_observed(artifacts_dir, cfg, recorder, None)
    }

    /// Like [`Self::spawn_traced`], with a metrics registry: shard
    /// workers feed scheduler decision counters, batch-size histograms,
    /// cache-persistence counters, and the predicted-vs-measured audit
    /// stream into it.
    pub fn spawn_observed(
        artifacts_dir: PathBuf,
        cfg: Config,
        recorder: Option<Arc<Recorder>>,
        registry: Option<Arc<MetricsRegistry>>,
    ) -> Result<ServerPool> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let n = cfg.serve_workers.max(1);
        let shared = Arc::new(SharedScheduleCache::load(&cfg.cache_path)?);
        let metrics = Arc::new(ServerMetrics::new(n));
        let flush = Duration::from_millis(cfg.cache_flush_ms as u64);
        // The trained cost model (if any) is loaded ONCE here and shared
        // read-only across every shard — a load failure is a spawn-time
        // error, not K identical per-worker failures.
        let model = if cfg.model_path.is_empty() {
            None
        } else {
            Some(Arc::new(crate::model::read_model(std::path::Path::new(
                &cfg.model_path,
            ))?))
        };
        // Workers keep their scheduler caches in-memory: the shared
        // layer owns cross-shard visibility and persistence. The model
        // path is cleared too — workers receive the Arc, not the file.
        let mut worker_cfg = cfg.clone();
        worker_cfg.cache_path = String::new();
        worker_cfg.model_path = String::new();
        let mut shards = Vec::with_capacity(n);
        for shard_id in 0..n {
            let (tx, rx) = mpsc::sync_channel(cfg.serve_queue_depth.max(1));
            let dir = artifacts_dir.clone();
            let wcfg = worker_cfg.clone();
            let sh = Arc::clone(&shared);
            let m = Arc::clone(&metrics);
            let rec = recorder.clone();
            let reg = registry.clone();
            let mdl = model.clone();
            let join = std::thread::Builder::new()
                .name(format!("autosage-shard-{shard_id}"))
                .spawn(move || worker_loop(shard_id, rx, dir, wcfg, sh, m, rec, reg, mdl, flush))
                .with_context(|| format!("spawning shard {shard_id} worker"))?;
            shards.push(Shard { tx, join });
        }
        Ok(ServerPool {
            shards,
            metrics,
            shared,
            queue_bound: cfg.serve_queue_depth.max(1) as u64,
            recorder,
            registry,
            model,
        })
    }

    /// Non-blocking submit: rejects with [`SubmitError::QueueFull`]
    /// when the target shard's bounded queue has no room.
    pub fn try_submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        let (qr, shard, rx) = self.package(op, graph, f, operands);
        let sm = &self.metrics.shards[shard];
        // Count depth *before* the send so the worker's decrement can
        // never observe (and wrap below) zero.
        let d = sm.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.shards[shard].tx.try_send(qr) {
            Ok(()) => {
                self.note_depth(sm, d);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                sm.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Blocking submit: waits for queue room instead of rejecting.
    pub fn submit(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        self.submit_traced(op, graph, f, operands, None)
    }

    /// Blocking submit carrying a flight-recorder context: the worker's
    /// queue/schedule/execute/reply spans attach to `trace`.
    pub fn submit_traced(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
        trace: Option<TraceCtx>,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        let (mut qr, shard, rx) = self.package(op, graph, f, operands);
        qr.trace = trace;
        let sm = &self.metrics.shards[shard];
        let d = sm.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        match self.shards[shard].tx.send(qr) {
            Ok(()) => {
                self.note_depth(sm, d);
                Ok(rx)
            }
            Err(_) => {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Convenience: blocking submit + wait for the response.
    pub fn call(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> Result<ServeResponse> {
        let rx = self
            .submit(op, graph, f, operands)
            .map_err(|e| anyhow!("serve submit failed: {e}"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    fn package(
        &self,
        op: Op,
        graph: Csr,
        f: usize,
        operands: Vec<(String, Vec<f32>)>,
    ) -> (QueuedRequest, usize, Receiver<ServeResponse>) {
        let sig = graph_signature(&graph);
        let shard = shard_of(&sig, self.shards.len());
        let (respond, rx) = mpsc::channel();
        let qr = QueuedRequest {
            op,
            graph,
            f,
            operands,
            respond,
            sig,
            enqueued: Instant::now(),
            trace: None,
        };
        (qr, shard, rx)
    }

    /// The pool's flight recorder, if it was spawned with one.
    pub fn recorder(&self) -> Option<&Arc<Recorder>> {
        self.recorder.as_ref()
    }

    /// The pool's metrics registry, if it was spawned with one.
    pub fn registry(&self) -> Option<&Arc<MetricsRegistry>> {
        self.registry.as_ref()
    }

    /// Whether a trained cost model is attached to the shards.
    pub fn has_model(&self) -> bool {
        self.model.is_some()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    pub fn snapshot(&self) -> Vec<ServeShardStats> {
        self.metrics.snapshot()
    }

    /// (hits, misses, entries) of the shared schedule cache.
    pub fn cache_stats(&self) -> (usize, usize, usize) {
        self.shared.stats()
    }

    /// Record the observed queue depth after a SUCCESSFUL enqueue only
    /// (rejected/blocked submissions must not inflate the high-water
    /// mark), clamped to the configured bound since the raw counter
    /// transiently includes concurrent in-flight submitters.
    fn note_depth(&self, sm: &ShardMetrics, depth: u64) {
        sm.max_queue_depth
            .fetch_max(depth.min(self.queue_bound), Ordering::Relaxed);
    }
}

impl Drop for ServerPool {
    fn drop(&mut self) {
        // Close every shard queue first so all workers wind down in
        // parallel, then join and surface panics.
        let shards = std::mem::take(&mut self.shards);
        let mut joins = Vec::with_capacity(shards.len());
        for s in shards {
            drop(s.tx);
            joins.push(s.join);
        }
        for (i, j) in joins.into_iter().enumerate() {
            if let Err(panic) = j.join() {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                eprintln!("autosage: server shard {i} worker panicked: {msg}");
                // Never panic inside Drop while already unwinding — a
                // double panic aborts the test binary and masks the
                // original failure.
                debug_assert!(
                    std::thread::panicking(),
                    "server shard {i} worker panicked: {msg}"
                );
            }
        }
        // Final flush of dirty cache state (entries and hit/miss
        // counters) now that every worker has stopped. Failure is a
        // warning, not a panic: the serving session itself succeeded.
        if let Err(e) = self.shared.persist() {
            if let Some(r) = &self.recorder {
                r.warn(None, "cache_persist_shutdown", &format!("{e:#}"));
            }
            eprintln!("autosage: warning: schedule cache flush on shutdown failed: {e:#}");
        }
    }
}

// ------------------------------------------------------------- worker

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shard: usize,
    rx: Receiver<QueuedRequest>,
    artifacts_dir: PathBuf,
    cfg: Config,
    shared: Arc<SharedScheduleCache>,
    metrics: Arc<ServerMetrics>,
    recorder: Option<Arc<Recorder>>,
    registry: Option<Arc<MetricsRegistry>>,
    model: Option<Arc<crate::model::CostModel>>,
    flush: Duration,
) {
    let batch_max = cfg.serve_batch_max.max(1);
    let window = Duration::from_micros(cfg.serve_batch_window_us as u64);
    let mut sage = match AutoSage::new(&artifacts_dir, cfg, None) {
        Ok(s) => s,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("shard {shard} init failed: {e:#}");
            let sm = &metrics.shards[shard];
            for req in rx {
                sm.queue_depth.fetch_sub(1, Ordering::Relaxed);
                sm.requests.fetch_add(1, Ordering::Relaxed);
                sm.errors.fetch_add(1, Ordering::Relaxed);
                let _ = req.respond.send(ServeResponse {
                    result: Err(anyhow!("{msg}")),
                    variant: String::new(),
                    from_cache: false,
                    shard,
                    batch_size: 0,
                    queue_ms: 0.0,
                    total_ms: 0.0,
                });
            }
            return;
        }
    };
    sage.set_recorder(recorder.clone());
    sage.set_metrics(registry.clone());
    sage.set_model(model);
    while let Ok(first) = rx.recv() {
        let batch = collect_batch(&rx, first, batch_max, window);
        let sm = &metrics.shards[shard];
        sm.queue_depth.fetch_sub(batch.len() as u64, Ordering::Relaxed);
        sm.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
        sm.batches.fetch_add(1, Ordering::Relaxed);
        if let Some(reg) = &registry {
            // Batch *size*, not latency — reuse the log2 buckets anyway:
            // the interesting question ("did coalescing happen at all,
            // and how skewed is it") survives the coarse resolution.
            reg.histogram("autosage_pool_batch_size").record_ms(batch.len() as f64);
        }
        serve_batch(
            shard,
            &mut sage,
            &shared,
            sm,
            recorder.as_deref(),
            registry.as_deref(),
            batch,
        );
        // Satellite (PR 2 debt): cache persistence moved off the
        // pool-wide mutex and out of `ProbeTicket::resolve` — dirty
        // state flushes here, throttled, and I/O errors demote to a
        // warning trace event instead of failing requests.
        match shared.maybe_persist(flush) {
            Ok(true) => {
                if let Some(reg) = &registry {
                    reg.inc("autosage_cache_persist_total");
                }
            }
            Ok(false) => {}
            Err(e) => {
                if let Some(reg) = &registry {
                    reg.inc("autosage_cache_persist_errors_total");
                }
                if let Some(r) = &recorder {
                    r.warn(None, "cache_persist", &format!("{e:#}"));
                }
                eprintln!("autosage: warning: schedule cache flush failed: {e:#}");
            }
        }
        // Same throttle pattern for the trace ring: long serving runs
        // stream spans to disk instead of holding everything in memory.
        if let Some(r) = &recorder {
            if let Err(e) = r.maybe_flush() {
                r.warn(None, "trace_flush", &format!("{e:#}"));
                eprintln!("autosage: warning: trace flush failed: {e:#}");
            }
        }
    }
}

/// Drain up to `batch_max` requests, waiting at most `window` past the
/// first one for stragglers (window 0 = drain whatever is queued now).
fn collect_batch(
    rx: &Receiver<QueuedRequest>,
    first: QueuedRequest,
    batch_max: usize,
    window: Duration,
) -> Vec<QueuedRequest> {
    let mut batch = vec![first];
    let opened = Instant::now();
    while batch.len() < batch_max {
        let elapsed = opened.elapsed();
        if elapsed >= window {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        } else {
            match rx.recv_timeout(window - elapsed) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    batch
}

/// Group a batch by coalescing key (graph signature, op, F) preserving
/// arrival order, then schedule each group ONCE and execute its members
/// under that decision.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    shard: usize,
    sage: &mut AutoSage,
    shared: &SharedScheduleCache,
    sm: &ShardMetrics,
    recorder: Option<&Recorder>,
    registry: Option<&MetricsRegistry>,
    batch: Vec<QueuedRequest>,
) {
    let mut groups: Vec<(String, Vec<QueuedRequest>)> = Vec::new();
    for qr in batch {
        let gk = format!("{}|{}|F{}", qr.sig, qr.op.as_str(), qr.f);
        match groups.iter_mut().find(|(k, _)| *k == gk) {
            Some((_, members)) => members.push(qr),
            None => groups.push((gk, vec![qr])),
        }
    }
    for (_, group) in groups {
        let batch_size = group.len();
        if batch_size > 1 {
            sm.coalesced.fetch_add(batch_size as u64 - 1, Ordering::Relaxed);
        }
        let leader = &group[0];
        // Pre-allocate the schedule span id and point the scheduler's
        // trace context at it, so estimate/probe/guardrail sub-spans and
        // cache events emitted inside `decide` parent under it.
        let sched = match (recorder, leader.trace) {
            (Some(r), Some(ctx)) => {
                let span = r.next_span_id();
                sage.set_trace_ctx(Some((ctx.trace, span)));
                Some((r, ctx, span, r.now_us()))
            }
            _ => {
                sage.set_trace_ctx(None);
                None
            }
        };
        let decided = decide_for(sage, shared, sm, leader);
        if let Some((r, ctx, span, start_us)) = sched {
            let (outcome, source, variant) = match &decided {
                Ok((v, src)) => {
                    let s = match src {
                        DecisionSource::Cache => "cache",
                        DecisionSource::Probe => "probe",
                        DecisionSource::Model => "model",
                        DecisionSource::ReplayFallback => "replay",
                    };
                    ("ok", s, v.clone())
                }
                Err(_) => ("error", "-", String::new()),
            };
            r.record(SpanRecord {
                trace: ctx.trace,
                span,
                parent: Some(ctx.parent),
                name: "schedule".to_string(),
                start_us,
                dur_us: r.now_us().saturating_sub(start_us),
                attrs: vec![
                    ("batch_size".to_string(), batch_size.to_string()),
                    ("outcome".to_string(), outcome.to_string()),
                    ("source".to_string(), source.to_string()),
                    ("variant".to_string(), variant),
                ],
            });
        }
        match decided {
            Err(e) => {
                let msg = format!("{e:#}");
                for qr in group {
                    sm.errors.fetch_add(1, Ordering::Relaxed);
                    let total_ms = ms_since(qr.enqueued);
                    sm.latency.record_ms(total_ms);
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        r.event(
                            ctx.trace,
                            Some(ctx.parent),
                            "reply",
                            vec![("ok".to_string(), "false".to_string())],
                        );
                    }
                    let _ = qr.respond.send(ServeResponse {
                        result: Err(anyhow!("{msg}")),
                        variant: String::new(),
                        from_cache: false,
                        shard,
                        batch_size,
                        queue_ms: total_ms,
                        total_ms,
                    });
                }
            }
            Ok((variant, source)) => {
                let from_cache = source == DecisionSource::Cache;
                // Audit loop: the roofline's prediction for the chosen
                // variant, computed ONCE per coalescing group (members
                // share graph/op/F by construction), compared below
                // against each member's measured execute time. Every
                // executed request is audited — the audit stream is
                // deliberately NOT subject to trace sampling.
                let audit = registry.map(|_| {
                    let leader = &group[0];
                    (
                        sage.estimate_ms(&leader.graph, leader.op, leader.f, &variant),
                        feature_bucket(leader.graph.n_rows, leader.graph.nnz(), leader.f),
                        leader.op.as_str().to_string(),
                    )
                });
                for qr in group {
                    let queue_ms = ms_since(qr.enqueued);
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        r.span_between(
                            ctx.trace,
                            Some(ctx.parent),
                            "queue",
                            r.us_of(qr.enqueued),
                            r.now_us(),
                            vec![("shard".to_string(), shard.to_string())],
                        );
                    }
                    let exec_start_us = recorder.map(|r| r.now_us());
                    let exec_started = Instant::now();
                    let result = execute_one(sage, &qr, &variant);
                    let exec_ms = ms_since(exec_started);
                    if let (Some(reg), Some((pred, bucket, op))) = (registry, audit.as_ref()) {
                        if let (Some(p), true) = (pred, result.is_ok()) {
                            reg.record_audit(AuditSample::executed(
                                op.clone(),
                                variant.clone(),
                                bucket.clone(),
                                *p,
                                exec_ms,
                            ));
                        }
                        reg.histogram("autosage_execute_ms").record_ms(exec_ms);
                    }
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        let mut attrs = vec![
                            ("variant".to_string(), variant.clone()),
                            ("backend".to_string(), sage.backend_name().to_string()),
                            ("shard".to_string(), shard.to_string()),
                        ];
                        if let Some((Some(p), _, _)) = audit.as_ref() {
                            attrs.push(("predicted_ms".to_string(), format!("{p:.4}")));
                        }
                        r.span_between(
                            ctx.trace,
                            Some(ctx.parent),
                            "execute",
                            exec_start_us.unwrap_or(0),
                            r.now_us(),
                            attrs,
                        );
                    }
                    let ok = result.is_ok();
                    match &result {
                        Ok(_) => sm.completed.fetch_add(1, Ordering::Relaxed),
                        Err(_) => sm.errors.fetch_add(1, Ordering::Relaxed),
                    };
                    let total_ms = ms_since(qr.enqueued);
                    sm.latency.record_ms(total_ms);
                    if let (Some(r), Some(ctx)) = (recorder, qr.trace) {
                        r.event(
                            ctx.trace,
                            Some(ctx.parent),
                            "reply",
                            vec![("ok".to_string(), ok.to_string())],
                        );
                    }
                    let _ = qr.respond.send(ServeResponse {
                        result,
                        variant: variant.clone(),
                        from_cache,
                        shard,
                        batch_size,
                        queue_ms,
                        total_ms,
                    });
                }
            }
        }
    }
}

/// Schedule one coalescing group: shared-cache lookup with
/// single-flight — concurrent misses on the same key across shards
/// block on ONE probe instead of probing K times.
fn decide_for(
    sage: &mut AutoSage,
    shared: &SharedScheduleCache,
    sm: &ShardMetrics,
    leader: &QueuedRequest,
) -> Result<(String, DecisionSource)> {
    let key = cache_key(
        &sage.backend_signature(),
        &leader.sig,
        if leader.op.has_f() { leader.f } else { 0 },
        leader.op.as_str(),
    );
    match shared.lookup(&key) {
        Lookup::Hit(c) => {
            sm.cache_hits.fetch_add(1, Ordering::Relaxed);
            Ok((c.variant, DecisionSource::Cache))
        }
        Lookup::Probe(ticket) => {
            // On error the ticket drops unresolved, handing the probe
            // to a waiter instead of wedging the key.
            let d = sage.decide(&leader.graph, leader.op, leader.f)?;
            if d.source == DecisionSource::Probe {
                sm.probes.fetch_add(1, Ordering::Relaxed);
            }
            // Probe resolutions carry the input's feature vector into
            // the shared cache (training data for `autosage train`);
            // model-predicted decisions deliberately carry none.
            ticket.resolve(CachedChoice {
                variant: d.choice.variant().to_string(),
                t_baseline_ms: d.t_baseline_ms,
                t_star_ms: d.t_star_ms,
                alpha: sage.config().alpha,
                features: d.features,
            });
            Ok((d.choice.variant().to_string(), d.source))
        }
    }
}

fn execute_one(sage: &mut AutoSage, qr: &QueuedRequest, variant: &str) -> Result<Vec<f32>> {
    let get = |name: &str| -> Result<&Vec<f32>> {
        qr.operands
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("request missing operand {name:?}"))
    };
    match qr.op {
        Op::Spmm => sage.spmm_with(&qr.graph, get("b")?, qr.f, variant),
        Op::Sddmm => sage.sddmm_with(&qr.graph, get("x")?, get("y")?, qr.f, variant),
        Op::Softmax => sage.softmax_with(&qr.graph, get("val")?, variant),
        Op::Attention => sage.attention_with(
            &qr.graph,
            get("q")?,
            get("k")?,
            get("v")?,
            qr.f,
            variant,
        ),
    }
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_stable_and_bounded() {
        for n in 1..9 {
            let s = shard_of("abc123ff00", n);
            assert!(s < n);
            assert_eq!(s, shard_of("abc123ff00", n), "routing must be pure");
        }
        assert_eq!(shard_of("anything", 1), 0);
    }

    #[test]
    fn submit_error_display() {
        assert!(SubmitError::QueueFull.to_string().contains("full"));
        assert!(SubmitError::Closed.to_string().contains("shut down"));
        assert_ne!(SubmitError::QueueFull, SubmitError::Closed);
    }
}
