//! Resilience layer for the serve pool: typed serve errors,
//! deterministic fault injection, and the quarantine log that worker
//! supervision writes poisoning requests into.
//!
//! Fault injection is a *pure function of (fault seed, request id)*:
//! each request id draws from its own `Rng::for_stream` stream, so two
//! runs at the same seed inject the identical {(request id, kind)} set
//! regardless of client/shard interleaving — chaos runs replay
//! bit-identically (`AUTOSAGE_FAULT_{RATE,KINDS,SEED}`).
//!
//! Quarantine: when per-request execution panics (injected or
//! organic), supervision catches it via `catch_unwind`, records the
//! poisoning request's signature + op here, replies with a typed
//! [`ServeError::Panic`], and the shard keeps serving — a crashed
//! worker is no longer discovered only in pool `Drop`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::Config;
use crate::data::sample::{sample_edges, SampleSpec, SampledGraph};
use crate::graph::Csr;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Typed serving failure carried in `ServeResponse::result`.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed at dequeue: queue wait already exceeded the deadline.
    DeadlineExceeded { waited_ms: f64, deadline_ms: f64 },
    /// Per-request execution panicked; supervision caught it and the
    /// request was quarantined. `injected` marks chaos-injected panics.
    Panic { msg: String, injected: bool },
    /// Backend/setup failure. `injected` marks chaos-injected errors.
    Execute { msg: String, injected: bool },
}

impl ServeError {
    /// Stable short tag used for metrics labels and error breakdowns.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::DeadlineExceeded { .. } => "deadline",
            ServeError::Panic { .. } => "panic",
            ServeError::Execute { .. } => "error",
        }
    }

    /// True when this failure was placed by the fault injector (so
    /// harnesses can separate chaos from organic failures).
    pub fn injected(&self) -> bool {
        match self {
            ServeError::DeadlineExceeded { .. } => false,
            ServeError::Panic { injected, .. } | ServeError::Execute { injected, .. } => {
                *injected
            }
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded { waited_ms, deadline_ms } => write!(
                f,
                "deadline exceeded: queued {waited_ms:.3} ms > deadline {deadline_ms:.3} ms"
            ),
            ServeError::Panic { msg, injected } => {
                let tag = if *injected { " [injected]" } else { "" };
                write!(f, "worker panic{tag}: {msg}")
            }
            ServeError::Execute { msg, injected } => {
                let tag = if *injected { " [injected]" } else { "" };
                write!(f, "execute failed{tag}: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

// ------------------------------------------------------ fault injection

/// What kind of chaos a faulty request receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Backend error: the request fails with an injected `Execute`.
    Error,
    /// Worker panic inside execution, caught by supervision.
    Panic,
    /// Latency spike: the request sleeps `fault_latency_ms`, then runs.
    Latency,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::Latency => "latency",
        }
    }

    pub fn parse(s: &str) -> Result<FaultKind, String> {
        match s.trim() {
            "error" => Ok(FaultKind::Error),
            "panic" => Ok(FaultKind::Panic),
            "latency" => Ok(FaultKind::Latency),
            other => Err(format!(
                "unknown fault kind {other:?} (valid: error, panic, latency)"
            )),
        }
    }
}

/// Parse the `AUTOSAGE_FAULT_KINDS` comma list (empty entries skipped).
pub fn parse_kinds(csv: &str) -> Result<Vec<FaultKind>, String> {
    let mut kinds = Vec::new();
    for tok in csv.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let k = FaultKind::parse(tok)?;
        if !kinds.contains(&k) {
            kinds.push(k);
        }
    }
    Ok(kinds)
}

const FAULT_LOG_CAP: usize = 65536;

/// Deterministic fault injector shared by every shard of a pool.
///
/// `decide` is a pure function — no interior state is consulted — so
/// placement never depends on thread interleaving. Counters and the
/// replay log are updated separately via `note` by whichever worker
/// actually applied the fault.
pub struct FaultInjector {
    rate: f64,
    kinds: Vec<FaultKind>,
    seed: u64,
    latency_ms: f64,
    injected: [AtomicU64; 3],
    log: Mutex<Vec<(u64, FaultKind)>>,
}

impl FaultInjector {
    pub fn new(rate: f64, kinds: Vec<FaultKind>, seed: u64, latency_ms: f64) -> FaultInjector {
        FaultInjector {
            rate,
            kinds,
            seed,
            latency_ms,
            injected: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            log: Mutex::new(Vec::new()),
        }
    }

    /// Build from config; `Ok(None)` when injection is off
    /// (rate 0 or no kinds enabled).
    pub fn from_config(cfg: &Config) -> Result<Option<FaultInjector>, String> {
        if cfg.fault_rate <= 0.0 {
            return Ok(None);
        }
        let kinds = parse_kinds(&cfg.fault_kinds)?;
        if kinds.is_empty() {
            return Ok(None);
        }
        Ok(Some(FaultInjector::new(
            cfg.fault_rate,
            kinds,
            cfg.fault_seed as u64,
            cfg.fault_latency_ms,
        )))
    }

    /// Pure placement decision for one request id.
    pub fn decide(&self, req_id: u64) -> Option<FaultKind> {
        let mut rng = Rng::for_stream(self.seed, req_id);
        if rng.next_f64() >= self.rate {
            return None;
        }
        Some(self.kinds[rng.below(self.kinds.len())])
    }

    /// Record that a fault was actually applied (counter + replay log).
    pub fn note(&self, req_id: u64, kind: FaultKind) {
        self.injected[kind as usize].fetch_add(1, Ordering::Relaxed);
        let mut log = self.log.lock().unwrap();
        if log.len() < FAULT_LOG_CAP {
            log.push((req_id, kind));
        }
    }

    pub fn latency_ms(&self) -> f64 {
        self.latency_ms
    }

    pub fn injected_of(&self, kind: FaultKind) -> u64 {
        self.injected[kind as usize].load(Ordering::Relaxed)
    }

    pub fn injected_total(&self) -> u64 {
        self.injected.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sorted copy of the applied-fault log — the determinism witness
    /// chaos tests compare across same-seed runs.
    pub fn log_snapshot(&self) -> Vec<(u64, FaultKind)> {
        let mut log = self.log.lock().unwrap().clone();
        log.sort_unstable_by_key(|&(id, k)| (id, k as usize));
        log
    }
}

// ----------------------------------------------------------- quarantine

/// One quarantined request: enough to identify and replay the
/// poisoning input without holding the (potentially huge) graph.
#[derive(Debug, Clone, PartialEq)]
pub struct QuarantineEntry {
    pub req_id: u64,
    pub shard: usize,
    pub sig: String,
    pub op: String,
    pub f: usize,
    pub injected: bool,
    pub msg: String,
}

impl QuarantineEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("req_id", Json::num(self.req_id as f64)),
            ("shard", Json::num(self.shard as f64)),
            ("sig", Json::str(&self.sig)),
            ("op", Json::str(&self.op)),
            ("f", Json::num(self.f as f64)),
            ("injected", Json::from(self.injected)),
            ("msg", Json::str(&self.msg)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<QuarantineEntry> {
        Some(QuarantineEntry {
            req_id: j.get("req_id").as_usize()? as u64,
            shard: j.get("shard").as_usize()?,
            sig: j.get("sig").as_str()?.to_string(),
            op: j.get("op").as_str()?.to_string(),
            f: j.get("f").as_usize()?,
            injected: j.get("injected").as_bool()?,
            msg: j.get("msg").as_str().unwrap_or("").to_string(),
        })
    }
}

const QUARANTINE_CAP: usize = 4096;

/// Bounded in-memory quarantine, flushed to `quarantine.jsonl` by
/// `serve-bench --out` (and inspectable by tests/handlers live).
#[derive(Default)]
pub struct QuarantineLog {
    entries: Mutex<Vec<QuarantineEntry>>,
    dropped: AtomicU64,
}

impl QuarantineLog {
    pub fn record(&self, entry: QuarantineEntry) {
        let mut entries = self.entries.lock().unwrap();
        if entries.len() >= QUARANTINE_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        entries.push(entry);
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> Vec<QuarantineEntry> {
        self.entries.lock().unwrap().clone()
    }

    /// Write one JSON object per line; returns the entry count.
    pub fn write_jsonl(&self, path: &std::path::Path) -> Result<usize> {
        self.write_jsonl_capped(path, 0)
    }

    /// [`write_jsonl`] with size-capped rotation: when the existing
    /// file already holds `cap_bytes` or more it is first rotated to
    /// `<path>.1` (`cap_bytes == 0` disables rotation). The write
    /// itself goes through the fault-injectable wrapper.
    pub fn write_jsonl_capped(
        &self,
        path: &std::path::Path,
        cap_bytes: u64,
    ) -> Result<usize> {
        let entries = self.snapshot();
        let mut out = String::new();
        for e in &entries {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        crate::util::iofault::rotate_if_large(path, cap_bytes)?;
        crate::util::iofault::write_file("obs.quarantine.write", path, out.as_bytes())?;
        Ok(entries.len())
    }

    /// Parse a `quarantine.jsonl` body, salvaging a torn tail: returns
    /// the valid-prefix entries plus the count of dropped lines (also
    /// accounted in `iofault::recovery()`). Parsed-JSON lines that are
    /// not quarantine entries drop too — the file has exactly one
    /// schema, so a mismatch is tail corruption, not drift.
    pub fn salvage_jsonl(text: &str) -> (Vec<QuarantineEntry>, usize) {
        let (lines, mut dropped) = crate::util::iofault::salvage_jsonl(text);
        let mut out = Vec::new();
        for (i, line) in lines.iter().enumerate() {
            match Json::parse(line).ok().as_ref().and_then(QuarantineEntry::from_json) {
                Some(e) => out.push(e),
                None => {
                    dropped += lines.len() - i;
                    break;
                }
            }
        }
        if dropped > 0 {
            crate::util::iofault::recovery()
                .jsonl_lines_dropped
                .fetch_add(dropped as u64, Ordering::Relaxed);
        }
        (out, dropped)
    }
}

// ----------------------------------------------------- degraded serving

/// Cache of edge-sampled graphs keyed by graph signature, shared by
/// all shards so each distinct graph is sampled at most once per pool.
#[derive(Default)]
pub struct DegradeCache {
    map: Mutex<HashMap<String, Arc<SampledGraph>>>,
}

impl DegradeCache {
    pub fn get_or_build(&self, sig: &str, g: &Csr, spec: &SampleSpec) -> Arc<SampledGraph> {
        if let Some(hit) = self.map.lock().unwrap().get(sig) {
            return Arc::clone(hit);
        }
        // Sample outside the lock: only the loser of a race resamples,
        // and both produce identical graphs (the pass is deterministic).
        let built = Arc::new(sample_edges(g, spec));
        let mut map = self.map.lock().unwrap();
        Arc::clone(map.entry(sig.to_string()).or_insert(built))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Everything the pool + workers share for fault handling: one
/// injector (optional), one quarantine log, one degrade cache.
pub struct Resilience {
    pub injector: Option<FaultInjector>,
    pub quarantine: QuarantineLog,
    pub degrade: DegradeCache,
}

impl Resilience {
    pub fn from_config(cfg: &Config) -> Result<Resilience, String> {
        Ok(Resilience {
            injector: FaultInjector::from_config(cfg)?,
            quarantine: QuarantineLog::default(),
            degrade: DegradeCache::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_error_kinds_and_display() {
        let e = ServeError::DeadlineExceeded { waited_ms: 3.0, deadline_ms: 1.0 };
        assert_eq!(e.kind(), "deadline");
        assert!(!e.injected());
        assert!(e.to_string().contains("deadline"));
        let p = ServeError::Panic { msg: "boom".into(), injected: true };
        assert_eq!(p.kind(), "panic");
        assert!(p.injected());
        assert!(p.to_string().contains("[injected]"));
        let x = ServeError::Execute { msg: "bad".into(), injected: false };
        assert_eq!(x.kind(), "error");
        assert!(!x.to_string().contains("[injected]"));
    }

    #[test]
    fn parse_kinds_dedups_and_rejects_unknown() {
        let ks = parse_kinds("error, panic,error,,latency").unwrap();
        assert_eq!(ks, vec![FaultKind::Error, FaultKind::Panic, FaultKind::Latency]);
        assert!(parse_kinds("error,oom").is_err());
        assert!(parse_kinds("").unwrap().is_empty());
    }

    #[test]
    fn injector_decisions_are_pure_and_seeded() {
        let inj = FaultInjector::new(0.3, parse_kinds("error,panic,latency").unwrap(), 9, 1.0);
        let a: Vec<_> = (0..500).map(|id| inj.decide(id)).collect();
        let b: Vec<_> = (0..500).map(|id| inj.decide(id)).collect();
        assert_eq!(a, b, "decide must be a pure function of (seed, id)");
        let hit = a.iter().flatten().count();
        assert!(hit > 50 && hit < 300, "rate 0.3 over 500 ids, got {hit}");
        // A different seed moves the fault set.
        let other = FaultInjector::new(0.3, parse_kinds("error").unwrap(), 10, 1.0);
        let c: Vec<_> = (0..500).map(|id| other.decide(id).is_some()).collect();
        let a_hits: Vec<_> = a.iter().map(|d| d.is_some()).collect();
        assert_ne!(a_hits, c);
    }

    #[test]
    fn injector_from_config_gates_on_rate_and_kinds() {
        let mut cfg = Config::default();
        assert!(FaultInjector::from_config(&cfg).unwrap().is_none());
        cfg.fault_rate = 0.5;
        cfg.fault_kinds = String::new();
        assert!(FaultInjector::from_config(&cfg).unwrap().is_none());
        cfg.fault_kinds = "latency".to_string();
        let inj = FaultInjector::from_config(&cfg).unwrap().unwrap();
        assert_eq!(inj.latency_ms(), 5.0);
        cfg.fault_kinds = "segv".to_string();
        assert!(FaultInjector::from_config(&cfg).is_err());
    }

    #[test]
    fn injector_counts_and_logs_applied_faults() {
        let inj = FaultInjector::new(1.0, vec![FaultKind::Error], 0, 1.0);
        inj.note(5, FaultKind::Error);
        inj.note(2, FaultKind::Error);
        assert_eq!(inj.injected_total(), 2);
        assert_eq!(inj.injected_of(FaultKind::Error), 2);
        assert_eq!(inj.injected_of(FaultKind::Panic), 0);
        let log = inj.log_snapshot();
        assert_eq!(log, vec![(2, FaultKind::Error), (5, FaultKind::Error)]);
    }

    #[test]
    fn quarantine_roundtrips_jsonl() {
        let q = QuarantineLog::default();
        q.record(QuarantineEntry {
            req_id: 7,
            shard: 1,
            sig: "sig-a".into(),
            op: "spmm".into(),
            f: 64,
            injected: true,
            msg: "injected worker panic".into(),
        });
        assert_eq!(q.len(), 1);
        let dir = std::env::temp_dir().join("autosage_quarantine_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("quarantine.jsonl");
        assert_eq!(q.write_jsonl(&path).unwrap(), 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let back = QuarantineEntry::from_json(&Json::parse(text.trim()).unwrap()).unwrap();
        assert_eq!(back, q.snapshot()[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_salvages_torn_tail_and_rotates_at_cap() {
        let q = QuarantineLog::default();
        for id in 0..3 {
            q.record(QuarantineEntry {
                req_id: id,
                shard: 0,
                sig: "s".into(),
                op: "spmm".into(),
                f: 32,
                injected: false,
                msg: "m".into(),
            });
        }
        let dir = std::env::temp_dir().join("autosage_quarantine_salvage_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("quarantine.jsonl");
        q.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Tear the final line mid-object.
        let torn = &text[..text.len() - 8];
        let (entries, dropped) = QuarantineLog::salvage_jsonl(torn);
        assert_eq!(entries.len(), 2, "valid prefix survives");
        assert_eq!(dropped, 1);
        assert_eq!(entries[0].req_id, 0);
        // A JSON-valid line that is not an entry drops as tail damage.
        let (entries, dropped) = QuarantineLog::salvage_jsonl(
            &format!("{}{{\"req_id\":1}}\n", &text),
        );
        assert_eq!(entries.len(), 3);
        assert_eq!(dropped, 1);
        // Rotation: a tiny cap forces the existing file aside.
        q.write_jsonl_capped(&path, 1).unwrap();
        let mut rotated = path.as_os_str().to_os_string();
        rotated.push(".1");
        assert!(std::path::PathBuf::from(rotated).exists());
        assert_eq!(QuarantineLog::salvage_jsonl(
            &std::fs::read_to_string(&path).unwrap()).0.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn degrade_cache_builds_once_per_signature() {
        let g = Csr::from_rows(
            16,
            vec![(0..16u32).map(|c| (c, 1.0 + c as f32)).collect(), vec![(0, 1.0)]],
        );
        let cache = DegradeCache::default();
        let spec = SampleSpec { keep_frac: 0.5, min_keep_deg: 2 };
        let a = cache.get_or_build("sig", &g, &spec);
        let b = cache.get_or_build("sig", &g, &spec);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert!(a.report.edges_dropped > 0);
    }
}
