//! Thread-safe schedule cache with single-flight probe deduplication.
//!
//! The paper's deployment story (§4.2, §8.6) amortizes probe cost across
//! a request stream through the persistent cache. Under concurrency that
//! only works if N simultaneous misses on one `(device, graph, F, op)`
//! key collapse into ONE probe: the first caller gets a [`ProbeTicket`]
//! and runs the probe; everyone else blocks on a condvar and wakes up to
//! a cache hit. Resolved decisions are immediately visible to every
//! shard of the worker pool.
//!
//! Crash/panic safety: a ticket dropped without [`ProbeTicket::resolve`]
//! (probe error, worker panic unwinding) removes the in-flight marker
//! and wakes the waiters, one of which inherits the probe — no key can
//! be wedged by a failed prober.
//!
//! Persistence is decoupled from the request path: `resolve` only marks
//! the cache dirty; [`SharedScheduleCache::maybe_persist`] flushes
//! dirty state periodically (serialize under the lock, file I/O outside
//! it) and [`SharedScheduleCache::persist`] flushes unconditionally at
//! shutdown. A request never blocks on — or fails because of — disk.

use std::collections::HashSet;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::scheduler::cache::{write_atomic, CacheSalvage};
use crate::scheduler::{CachedChoice, ScheduleCache};

/// Shared, thread-safe wrapper around the persistent [`ScheduleCache`].
pub struct SharedScheduleCache {
    state: Mutex<State>,
    resolved: Condvar,
    /// Reference instant for the flush throttle.
    epoch: Instant,
    /// Milliseconds-since-epoch of the last flush (attempted or done).
    last_flush_ms: AtomicU64,
}

struct State {
    cache: ScheduleCache,
    /// Keys currently being probed by exactly one caller each.
    in_flight: HashSet<String>,
}

/// Outcome of [`SharedScheduleCache::lookup`].
pub enum Lookup<'a> {
    /// Resolved decision (either pre-existing or probed by another
    /// caller while we waited).
    Hit(CachedChoice),
    /// This caller owns the probe for the key; it must call
    /// [`ProbeTicket::resolve`] (or drop the ticket to abandon).
    Probe(ProbeTicket<'a>),
}

/// Exclusive right to probe one cache key. Dropping the ticket without
/// resolving abandons the probe and unblocks waiting callers.
pub struct ProbeTicket<'a> {
    owner: &'a SharedScheduleCache,
    key: String,
    done: bool,
}

impl SharedScheduleCache {
    pub fn new(cache: ScheduleCache) -> SharedScheduleCache {
        SharedScheduleCache {
            state: Mutex::new(State { cache, in_flight: HashSet::new() }),
            resolved: Condvar::new(),
            epoch: Instant::now(),
            last_flush_ms: AtomicU64::new(0),
        }
    }

    /// Load from `cache_path`; an empty path means in-memory only (the
    /// same convention as `AUTOSAGE_CACHE=""`). Uses the salvage load
    /// path: individually-corrupt entries quarantine, a wholly-corrupt
    /// file moves aside to `<path>.corrupt` and the pool starts with an
    /// empty cache — "reprobe cold" beats "refuse to serve". Returns
    /// the salvage report next to the cache so the pool can log it.
    pub fn load(cache_path: &str) -> Result<SharedScheduleCache> {
        Ok(SharedScheduleCache::load_salvaged(cache_path).0)
    }

    /// [`SharedScheduleCache::load`] surfacing the [`CacheSalvage`]
    /// report (what was quarantined or reset, if anything).
    pub fn load_salvaged(cache_path: &str) -> (SharedScheduleCache, CacheSalvage) {
        let (cache, report) = if cache_path.is_empty() {
            (ScheduleCache::in_memory(), CacheSalvage::default())
        } else {
            ScheduleCache::load_salvaged(Path::new(cache_path))
        };
        (SharedScheduleCache::new(cache), report)
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        // A poisoned lock only means another worker panicked mid-update;
        // the map itself is always in a consistent state (single-field
        // inserts), so serving continues.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Cache lookup with single-flight semantics. Blocks while another
    /// caller probes the same key; at most one caller at a time receives
    /// [`Lookup::Probe`] for a given key.
    pub fn lookup(&self, key: &str) -> Lookup<'_> {
        let mut st = self.lock();
        if let Some(hit) = st.cache.peek(key).cloned() {
            st.cache.hits += 1;
            // Counter bumps are persisted state: warm-only runs (every
            // lookup a hit, no probe ever fires) must still flush so
            // `autosage cache stats` is accurate afterwards.
            st.cache.mark_dirty();
            return Lookup::Hit(hit);
        }
        // One miss per lookup, even if we then wait on another prober:
        // waiters are exactly the probes single-flight saved.
        st.cache.misses += 1;
        st.cache.mark_dirty();
        while st.in_flight.contains(key) {
            st = self
                .resolved
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
            if let Some(hit) = st.cache.peek(key).cloned() {
                return Lookup::Hit(hit);
            }
        }
        st.in_flight.insert(key.to_string());
        Lookup::Probe(ProbeTicket {
            owner: self,
            key: key.to_string(),
            done: false,
        })
    }

    /// (hits, misses, entries) — lifetime counters of the underlying
    /// cache plus its current size.
    pub fn stats(&self) -> (usize, usize, usize) {
        let st = self.lock();
        (st.cache.hits, st.cache.misses, st.cache.len())
    }

    pub fn len(&self) -> usize {
        self.lock().cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flush dirty cache state to its backing file (no-op when clean or
    /// in-memory). Serializes under the lock but writes outside it, so
    /// concurrent lookups never wait on disk. On write failure the
    /// cache is re-marked dirty so a later flush retries.
    pub fn persist(&self) -> Result<()> {
        let (path, text) = {
            let mut st = self.lock();
            if !st.cache.is_dirty() {
                return Ok(());
            }
            let Some(path) = st.cache.path().map(Path::to_path_buf) else {
                st.cache.clear_dirty();
                return Ok(());
            };
            let text = st.cache.serialize();
            st.cache.clear_dirty();
            (path, text)
        };
        if let Err(e) = write_atomic(&path, &text) {
            self.lock().cache.mark_dirty();
            return Err(e);
        }
        Ok(())
    }

    /// Throttled [`Self::persist`]: flushes at most once per `interval`
    /// across all callers. Returns whether a flush was attempted.
    pub fn maybe_persist(&self, interval: Duration) -> Result<bool> {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let last = self.last_flush_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(last) < interval.as_millis() as u64 {
            return Ok(false);
        }
        // One winner per interval; losers skip instead of queueing up
        // behind the flush.
        if self
            .last_flush_ms
            .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return Ok(false);
        }
        self.persist().map(|_| true)
    }
}

impl ProbeTicket<'_> {
    /// Publish the probed decision: insert (marking the cache dirty for
    /// the next periodic/shutdown flush) and wake all waiters. No disk
    /// I/O happens here — persistence is decoupled from the request
    /// path via [`SharedScheduleCache::maybe_persist`].
    pub fn resolve(mut self, choice: CachedChoice) {
        self.done = true;
        let mut st = self.owner.lock();
        st.cache.insert(self.key.clone(), choice);
        st.in_flight.remove(&self.key);
        drop(st);
        self.owner.resolved.notify_all();
    }
}

impl Drop for ProbeTicket<'_> {
    fn drop(&mut self) {
        if !self.done {
            let mut st = self.owner.lock();
            st.in_flight.remove(&self.key);
            drop(st);
            self.owner.resolved.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    fn choice(v: &str) -> CachedChoice {
        CachedChoice {
            variant: v.into(),
            t_baseline_ms: 1.0,
            t_star_ms: 0.5,
            alpha: 0.95,
            features: None,
        }
    }

    #[test]
    fn miss_then_resolve_then_hit() {
        let sc = SharedScheduleCache::new(ScheduleCache::in_memory());
        match sc.lookup("k") {
            Lookup::Probe(t) => t.resolve(choice("ell_r8_f32")),
            Lookup::Hit(_) => panic!("empty cache cannot hit"),
        }
        match sc.lookup("k") {
            Lookup::Hit(c) => assert_eq!(c.variant, "ell_r8_f32"),
            Lookup::Probe(_) => panic!("must hit after resolve"),
        }
        let (hits, misses, len) = sc.stats();
        assert_eq!((hits, misses, len), (1, 1, 1));
    }

    #[test]
    fn concurrent_lookups_yield_exactly_one_probe() {
        let sc = Arc::new(SharedScheduleCache::new(ScheduleCache::in_memory()));
        let probes = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..8 {
            let sc = Arc::clone(&sc);
            let probes = Arc::clone(&probes);
            joins.push(std::thread::spawn(move || match sc.lookup("key") {
                Lookup::Probe(t) => {
                    probes.fetch_add(1, Ordering::SeqCst);
                    // Hold the probe long enough that every other thread
                    // reaches lookup() and has to wait on the condvar.
                    std::thread::sleep(Duration::from_millis(30));
                    t.resolve(choice("ell_r8_f32"));
                    "ell_r8_f32".to_string()
                }
                Lookup::Hit(c) => c.variant,
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), "ell_r8_f32");
        }
        assert_eq!(probes.load(Ordering::SeqCst), 1, "single-flight violated");
    }

    #[test]
    fn abandoned_probe_hands_off_to_a_waiter() {
        let sc = Arc::new(SharedScheduleCache::new(ScheduleCache::in_memory()));
        let ticket = match sc.lookup("k") {
            Lookup::Probe(t) => t,
            Lookup::Hit(_) => panic!("empty cache cannot hit"),
        };
        let sc2 = Arc::clone(&sc);
        let waiter = std::thread::spawn(move || match sc2.lookup("k") {
            Lookup::Probe(t) => {
                t.resolve(choice("hub_r8_f32"));
                true
            }
            Lookup::Hit(_) => false,
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(ticket); // probe "failed" — waiters must not be wedged
        assert!(waiter.join().unwrap(), "waiter must inherit the probe");
        match sc.lookup("k") {
            Lookup::Hit(c) => assert_eq!(c.variant, "hub_r8_f32"),
            Lookup::Probe(_) => panic!("resolved key must hit"),
        }
    }

    #[test]
    fn resolve_does_not_write_until_persist() {
        let dir = std::env::temp_dir().join("autosage_shared_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("deferred.json");
        let _ = std::fs::remove_file(&path);
        let sc = SharedScheduleCache::load(path.to_str().unwrap()).unwrap();
        match sc.lookup("k") {
            Lookup::Probe(t) => t.resolve(choice("ell_r8_f32")),
            Lookup::Hit(_) => panic!("empty cache cannot hit"),
        }
        assert!(!path.exists(), "resolve must not do file I/O");
        sc.persist().unwrap();
        assert!(path.exists(), "persist flushes the dirty entry");
        let mut on_disk = ScheduleCache::load(&path).unwrap();
        assert!(on_disk.get("k").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn warm_only_counters_flush_to_disk() {
        let dir = std::env::temp_dir().join("autosage_shared_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm_only.json");
        let _ = std::fs::remove_file(&path);
        // Pre-populate the file so the serving session is all-warm.
        let mut seed = ScheduleCache::load(&path).unwrap();
        seed.insert("k".into(), choice("ell_r8_f32"));
        seed.save().unwrap();

        let sc = SharedScheduleCache::load(path.to_str().unwrap()).unwrap();
        match sc.lookup("k") {
            Lookup::Hit(c) => assert_eq!(c.variant, "ell_r8_f32"),
            Lookup::Probe(_) => panic!("pre-populated key must hit"),
        }
        sc.persist().unwrap();
        let reloaded = ScheduleCache::load(&path).unwrap();
        assert_eq!(reloaded.hits, 1, "hit counter must survive a warm-only run");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn maybe_persist_throttles() {
        let sc = SharedScheduleCache::new(ScheduleCache::in_memory());
        // First call within the interval is throttled because the
        // recorder starts at t=0; advance past it by using zero interval.
        assert!(sc.maybe_persist(Duration::from_secs(0)).unwrap());
        assert!(
            !sc.maybe_persist(Duration::from_secs(3600)).unwrap(),
            "second flush inside the interval must be skipped"
        );
    }

    #[test]
    fn persist_clean_cache_is_noop() {
        let sc = SharedScheduleCache::new(ScheduleCache::in_memory());
        sc.persist().unwrap();
    }
}
