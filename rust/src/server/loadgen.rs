//! Multi-threaded load generator for the serving pool (`autosage
//! serve-bench`): N client threads fire a mixed SpMM/SDDMM/attention
//! request stream built from `gen/` presets at the pool, verify every
//! response against the pure-Rust oracle, and report throughput +
//! client-observed latency next to the pool's per-shard serving
//! metrics.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench_kit::render::render_serving_table;
use crate::gen::{preset, preset_names};
use crate::graph::Csr;
use crate::ops::reference;
use crate::scheduler::{probe, Op};
use crate::telemetry::{serving_table, ServeShardStats};
use crate::util::csv::CsvTable;
use crate::util::stats;

use super::pool::ServerPool;

/// One load-generation run's shape.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Feature width for every request (the synthetic catalog carries
    /// SDDMM/attention buckets at F ∈ {64, 128} on er_s/products_s).
    pub f: usize,
    pub presets: Vec<String>,
    pub ops: Vec<Op>,
    pub seed: u64,
    /// Check every response against the reference oracle.
    pub verify: bool,
}

impl LoadSpec {
    /// Default bench shape: 8 clients, mixed ops over two presets.
    pub fn bench() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 8,
            f: 64,
            presets: vec!["er_s".into(), "products_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
        }
    }

    /// CI smoke shape: same worker/client concurrency, short stream.
    pub fn smoke() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 2,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
        }
    }
}

/// Aggregated result of one run.
pub struct LoadReport {
    /// Human-readable table + summary (serve-bench stdout).
    pub text: String,
    /// Per-shard serving metrics CSV (telemetry format).
    pub csv: CsvTable,
    pub total: usize,
    pub ok: usize,
    pub errors: usize,
    pub mismatches: usize,
    pub wall_ms: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Micro-probes actually run across all shards.
    pub probes: u64,
    /// Distinct (graph, op, F) request keys in the workload.
    pub unique_keys: usize,
    pub shards: Vec<ServeShardStats>,
}

/// One request template: deterministic operands + its oracle output.
struct Combo {
    op: Op,
    graph: Csr,
    f: usize,
    operands: Vec<(String, Vec<f32>)>,
    oracle: Vec<f32>,
}

fn build_combos(spec: &LoadSpec) -> Result<Vec<Combo>> {
    if spec.ops.is_empty() || spec.presets.is_empty() {
        bail!("load spec needs at least one op and one preset");
    }
    if spec.clients == 0 || spec.requests_per_client == 0 {
        bail!("load spec needs at least one client and one request");
    }
    let mut combos = Vec::new();
    for (pi, name) in spec.presets.iter().enumerate() {
        if !preset_names().contains(&name.as_str()) {
            bail!(
                "unknown preset {name:?} (valid: {})",
                preset_names().join(", ")
            );
        }
        let (g, _) = preset(name, spec.seed.wrapping_add(pi as u64));
        for (oi, &op) in spec.ops.iter().enumerate() {
            if op == Op::Softmax {
                bail!("softmax is served inside the attention pipeline; mix spmm|sddmm|attention");
            }
            let opseed = spec.seed ^ (((pi as u64) << 8) | oi as u64).wrapping_add(1);
            let data = probe::synth_operands(op, g.n_rows, spec.f, opseed);
            let get = |n: &str| -> &[f32] {
                data.dense.get(n).map(|v| v.as_slice()).unwrap_or(&[])
            };
            let oracle = match op {
                Op::Spmm => reference::spmm(&g, get("b"), spec.f),
                Op::Sddmm => reference::sddmm(&g, get("x"), get("y"), spec.f),
                Op::Attention => {
                    reference::csr_attention(&g, get("q"), get("k"), get("v"), spec.f)
                }
                Op::Softmax => unreachable!("rejected above"),
            };
            let operands = op
                .dense_operands()
                .iter()
                .map(|n| ((*n).to_string(), data.dense.get(*n).cloned().unwrap_or_default()))
                .collect();
            combos.push(Combo { op, graph: g.clone(), f: spec.f, operands, oracle });
        }
    }
    Ok(combos)
}

/// Run the load against `pool` and aggregate a report. Clients walk the
/// combo list round-robin (offset by client id so the mix interleaves)
/// using the blocking submit path.
pub fn run_load(pool: Arc<ServerPool>, spec: &LoadSpec) -> Result<LoadReport> {
    let combos = Arc::new(build_combos(spec)?);
    let unique_keys = combos.len();
    let sw = Instant::now();
    let mut handles = Vec::new();
    for c in 0..spec.clients {
        let pool = Arc::clone(&pool);
        let combos = Arc::clone(&combos);
        let rpc = spec.requests_per_client;
        let verify = spec.verify;
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-client-{c}"))
            .spawn(move || -> (Vec<f64>, usize, usize, usize) {
                let mut lat = Vec::new();
                let (mut ok, mut errors, mut mismatches) = (0usize, 0usize, 0usize);
                for r in 0..rpc {
                    let combo = &combos[(c + r) % combos.len()];
                    let t0 = Instant::now();
                    let rx = match pool.submit(
                        combo.op,
                        combo.graph.clone(),
                        combo.f,
                        combo.operands.clone(),
                    ) {
                        Ok(rx) => rx,
                        Err(_) => {
                            errors += 1;
                            continue;
                        }
                    };
                    match rx.recv() {
                        Err(_) => errors += 1,
                        Ok(resp) => match resp.result {
                            Err(_) => errors += 1,
                            Ok(out) => {
                                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                                if verify
                                    && reference::max_abs_diff(&out, &combo.oracle) >= 2e-3
                                {
                                    mismatches += 1;
                                } else {
                                    ok += 1;
                                }
                            }
                        },
                    }
                }
                (lat, ok, errors, mismatches)
            })
            .with_context(|| format!("spawning load client {c}"))?;
        handles.push(handle);
    }

    let mut lat = Vec::new();
    let (mut ok, mut errors, mut mismatches) = (0usize, 0usize, 0usize);
    for h in handles {
        let (l, o, e, m) = h.join().map_err(|_| anyhow!("load client panicked"))?;
        lat.extend(l);
        ok += o;
        errors += e;
        mismatches += m;
    }
    let wall_ms = sw.elapsed().as_secs_f64() * 1e3;
    let total = spec.clients * spec.requests_per_client;
    let (p50_ms, p95_ms, p99_ms) = if lat.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            stats::quantile(&lat, 0.50),
            stats::quantile(&lat, 0.95),
            stats::quantile(&lat, 0.99),
        )
    };
    let throughput_rps = if wall_ms > 0.0 { ok as f64 / (wall_ms / 1e3) } else { 0.0 };
    let shards = pool.metrics().snapshot();
    let probes = pool.metrics().total_probes();
    let (cache_hits, cache_misses, cache_len) = pool.cache_stats();

    let ops: Vec<&str> = spec.ops.iter().map(|o| o.as_str()).collect();
    let mut text = render_serving_table(
        &format!(
            "serve-bench: {} workers | {} clients x {} reqs | presets [{}] | ops [{}] | F={}",
            pool.n_shards(),
            spec.clients,
            spec.requests_per_client,
            spec.presets.join(","),
            ops.join(","),
            spec.f,
        ),
        &shards,
    );
    text.push_str(&format!(
        "\nrequests : {total} total | {ok} ok | {errors} errors | {mismatches} oracle mismatches\n"
    ));
    text.push_str(&format!(
        "schedule : {unique_keys} unique keys | {probes} probes | cache {cache_hits} hits / \
         {cache_misses} misses / {cache_len} entries (single-flight saved {} probes)\n",
        (cache_misses as u64).saturating_sub(probes),
    ));
    text.push_str(&format!(
        "latency  : p50 {p50_ms:.2}ms | p95 {p95_ms:.2}ms | p99 {p99_ms:.2}ms (client-observed)\n"
    ));
    text.push_str(&format!(
        "thruput  : {throughput_rps:.1} req/s over {:.1}ms wall\n",
        wall_ms
    ));

    Ok(LoadReport {
        text,
        csv: serving_table(&shards),
        total,
        ok,
        errors,
        mismatches,
        wall_ms,
        throughput_rps,
        p50_ms,
        p95_ms,
        p99_ms,
        probes,
        unique_keys,
        shards,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_cover_preset_x_op_grid() {
        let spec = LoadSpec {
            clients: 1,
            requests_per_client: 1,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm],
            seed: 7,
            verify: false,
        };
        let combos = build_combos(&spec).unwrap();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].op, Op::Spmm);
        assert_eq!(combos[0].oracle.len(), combos[0].graph.n_rows * 64);
        // SDDMM oracle is per-edge.
        assert_eq!(combos[1].oracle.len(), combos[1].graph.nnz());
    }

    #[test]
    fn combos_reject_bad_specs() {
        let mut spec = LoadSpec::smoke();
        spec.presets = vec!["nope".into()];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.ops = vec![Op::Softmax];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.clients = 0;
        assert!(build_combos(&spec).is_err());
    }

    #[test]
    fn default_specs_are_mixed_and_concurrent() {
        let b = LoadSpec::bench();
        assert!(b.clients >= 8);
        assert!(b.ops.len() == 3);
        let s = LoadSpec::smoke();
        assert!(s.clients >= 8);
        assert_eq!(s.f, 64);
    }
}
