//! Multi-threaded load generator for the serving pool (`autosage
//! serve-bench`): N client threads fire a mixed SpMM/SDDMM/attention
//! request stream built from `gen/` presets at the pool, verify every
//! response against the pure-Rust oracle, and report throughput +
//! client-observed latency next to the pool's per-shard serving
//! metrics.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench_kit::render::render_serving_table;
use crate::data::load_graph_spec;
use crate::graph::Csr;
use crate::obs::perf::{Direction, PerfProfile};
use crate::obs::trace::{Recorder, SpanRecord, TraceCtx};
use crate::ops::reference;
use crate::scheduler::{probe, Op};
use crate::telemetry::{serving_table, ServeShardStats};
use crate::util::csv::CsvTable;
use crate::util::rng::Rng;
use crate::util::stats;

use super::pool::ServerPool;

/// One load-generation run's shape.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Feature width for every request (the synthetic catalog carries
    /// SDDMM/attention buckets at F ∈ {64, 128} on er_s/products_s).
    pub f: usize,
    /// Graph specs (`data::spec` grammar): preset names or
    /// `file:PATH` loader-backed datasets.
    pub presets: Vec<String>,
    pub ops: Vec<Op>,
    pub seed: u64,
    /// Check every response against the reference oracle.
    pub verify: bool,
}

impl LoadSpec {
    /// Default bench shape: 8 clients, mixed ops over two presets.
    pub fn bench() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 8,
            f: 64,
            presets: vec!["er_s".into(), "products_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
        }
    }

    /// CI smoke shape: same worker/client concurrency, short stream.
    pub fn smoke() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 2,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
        }
    }
}

/// Aggregated result of one run.
pub struct LoadReport {
    /// Human-readable table + summary (serve-bench stdout).
    pub text: String,
    /// Per-shard serving metrics CSV (telemetry format).
    pub csv: CsvTable,
    pub total: usize,
    pub ok: usize,
    pub errors: usize,
    pub mismatches: usize,
    pub wall_ms: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Micro-probes actually run across all shards.
    pub probes: u64,
    /// Cold keys decided by the trained cost model without probing
    /// (0 when no model is attached).
    pub model_predictions: u64,
    /// Distinct (graph, op, F) request keys in the workload.
    pub unique_keys: usize,
    pub shards: Vec<ServeShardStats>,
}

impl LoadReport {
    /// Gateable perf metrics for this run. Deterministic counters carry
    /// zero tolerance (the smoke workload is seeded, so request totals,
    /// unique keys and probe counts are exact); wall-clock metrics carry
    /// wide tolerances so the gate fires on order-of-magnitude
    /// regressions, not CI-runner jitter.
    pub fn perf_profile(&self) -> PerfProfile {
        let mut p = PerfProfile::new("serve_bench");
        p.push("requests_total", self.total as f64, Direction::Exact, 0.0);
        p.push("errors", self.errors as f64, Direction::Exact, 0.0);
        p.push(
            "oracle_mismatches",
            self.mismatches as f64,
            Direction::Exact,
            0.0,
        );
        p.push("unique_keys", self.unique_keys as f64, Direction::Exact, 0.0);
        // Single-flight must keep probes at one per unique key.
        p.push("probes", self.probes as f64, Direction::Lower, 0.0);
        p.push(
            "throughput_rps",
            self.throughput_rps,
            Direction::Higher,
            0.95,
        );
        p.push("p50_ms", self.p50_ms, Direction::Lower, 19.0);
        p.push("p99_ms", self.p99_ms, Direction::Lower, 19.0);
        p
    }
}

/// One request template: deterministic operands + its oracle output.
struct Combo {
    op: Op,
    graph: Csr,
    f: usize,
    operands: Vec<(String, Vec<f32>)>,
    oracle: Vec<f32>,
}

fn build_combos(spec: &LoadSpec) -> Result<Vec<Combo>> {
    if spec.ops.is_empty() || spec.presets.is_empty() {
        bail!("load spec needs at least one op and one preset");
    }
    if spec.clients == 0 || spec.requests_per_client == 0 {
        bail!("load spec needs at least one client and one request");
    }
    let mut combos = Vec::new();
    for (pi, name) in spec.presets.iter().enumerate() {
        let (g, _label) = load_graph_spec(name, spec.seed.wrapping_add(pi as u64))?;
        for (oi, &op) in spec.ops.iter().enumerate() {
            if op == Op::Softmax {
                bail!("softmax is served inside the attention pipeline; mix spmm|sddmm|attention");
            }
            let opseed = spec.seed ^ (((pi as u64) << 8) | oi as u64).wrapping_add(1);
            let data = probe::synth_operands(op, g.n_rows, spec.f, opseed);
            let get = |n: &str| -> &[f32] {
                data.dense.get(n).map(|v| v.as_slice()).unwrap_or(&[])
            };
            let oracle = match op {
                Op::Spmm => reference::spmm(&g, get("b"), spec.f),
                Op::Sddmm => reference::sddmm(&g, get("x"), get("y"), spec.f),
                Op::Attention => {
                    reference::csr_attention(&g, get("q"), get("k"), get("v"), spec.f)
                }
                Op::Softmax => unreachable!("rejected above"),
            };
            let operands = op
                .dense_operands()
                .iter()
                .map(|n| ((*n).to_string(), data.dense.get(*n).cloned().unwrap_or_default()))
                .collect();
            combos.push(Combo { op, graph: g.clone(), f: spec.f, operands, oracle });
        }
    }
    Ok(combos)
}

/// Deterministic per-client request mix: a round-robin base (offset by
/// client id so every client covers every combo) shuffled by a
/// per-client [`Rng::for_stream`] stream of `seed`. Two runs with the
/// same seed replay the identical interleaving; changing the seed
/// reshuffles the mix — this is what makes serve-bench A/B comparisons
/// repeatable instead of racing on arrival order alone.
pub fn request_schedule(
    n_combos: usize,
    clients: usize,
    requests_per_client: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    (0..clients)
        .map(|c| {
            let mut rng = Rng::for_stream(seed, c as u64);
            let mut idx: Vec<usize> = (0..requests_per_client)
                .map(|r| (c + r) % n_combos.max(1))
                .collect();
            rng.shuffle(&mut idx);
            idx
        })
        .collect()
}

/// Run the load against `pool` and aggregate a report. Clients walk a
/// seeded [`request_schedule`] over the combo list using the blocking
/// submit path.
pub fn run_load(pool: Arc<ServerPool>, spec: &LoadSpec) -> Result<LoadReport> {
    run_load_traced(pool, spec, None)
}

/// Record a client-side root `request` span covering submit → reply.
fn record_request_span(
    recorder: Option<&Recorder>,
    ctx: Option<TraceCtx>,
    client: usize,
    op: Op,
    t0: Instant,
    ok: bool,
) {
    if let (Some(r), Some(ctx)) = (recorder, ctx) {
        r.record(SpanRecord {
            trace: ctx.trace,
            span: ctx.parent,
            parent: None,
            name: "request".to_string(),
            start_us: r.us_of(t0),
            dur_us: t0.elapsed().as_micros() as u64,
            attrs: vec![
                ("client".to_string(), client.to_string()),
                ("op".to_string(), op.as_str().to_string()),
                ("ok".to_string(), ok.to_string()),
            ],
        });
    }
}

/// [`run_load`] with a flight recorder: every request gets a fresh
/// trace id at ingress and carries it through shard queue, coalesced
/// scheduling, backend execute and reply. Pass the same recorder the
/// pool was spawned with so client- and worker-side spans share one
/// timeline.
pub fn run_load_traced(
    pool: Arc<ServerPool>,
    spec: &LoadSpec,
    recorder: Option<Arc<Recorder>>,
) -> Result<LoadReport> {
    let combos = Arc::new(build_combos(spec)?);
    let unique_keys = combos.len();
    let schedule = request_schedule(
        combos.len(),
        spec.clients,
        spec.requests_per_client,
        spec.seed,
    );
    let sw = Instant::now();
    let mut handles = Vec::new();
    for (c, mix) in schedule.into_iter().enumerate() {
        let pool = Arc::clone(&pool);
        let combos = Arc::clone(&combos);
        let verify = spec.verify;
        let recorder = recorder.clone();
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-client-{c}"))
            .spawn(move || -> (Vec<f64>, usize, usize, usize) {
                let mut lat = Vec::new();
                let (mut ok, mut errors, mut mismatches) = (0usize, 0usize, 0usize);
                for &ci in &mix {
                    let combo = &combos[ci];
                    let t0 = Instant::now();
                    // Fresh trace per request, subject to head sampling:
                    // unsampled requests travel untraced (None) but still
                    // consume a trace id, so the sampled set is a pure
                    // function of (seed, rate). The root span id doubles
                    // as the parent for every worker-side span.
                    let tctx = recorder.as_ref().and_then(|r| r.sample_ctx());
                    let rx = match pool.submit_traced(
                        combo.op,
                        combo.graph.clone(),
                        combo.f,
                        combo.operands.clone(),
                        tctx,
                    ) {
                        Ok(rx) => rx,
                        Err(_) => {
                            errors += 1;
                            record_request_span(
                                recorder.as_deref(),
                                tctx,
                                c,
                                combo.op,
                                t0,
                                false,
                            );
                            continue;
                        }
                    };
                    let mut req_ok = false;
                    match rx.recv() {
                        Err(_) => errors += 1,
                        Ok(resp) => match resp.result {
                            Err(_) => errors += 1,
                            Ok(out) => {
                                req_ok = true;
                                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                                if verify
                                    && reference::max_abs_diff(&out, &combo.oracle) >= 2e-3
                                {
                                    mismatches += 1;
                                } else {
                                    ok += 1;
                                }
                            }
                        },
                    }
                    record_request_span(
                        recorder.as_deref(),
                        tctx,
                        c,
                        combo.op,
                        t0,
                        req_ok,
                    );
                }
                (lat, ok, errors, mismatches)
            })
            .with_context(|| format!("spawning load client {c}"))?;
        handles.push(handle);
    }

    let mut lat = Vec::new();
    let (mut ok, mut errors, mut mismatches) = (0usize, 0usize, 0usize);
    for h in handles {
        let (l, o, e, m) = h.join().map_err(|_| anyhow!("load client panicked"))?;
        lat.extend(l);
        ok += o;
        errors += e;
        mismatches += m;
    }
    let wall_ms = sw.elapsed().as_secs_f64() * 1e3;
    let total = spec.clients * spec.requests_per_client;
    let (p50_ms, p95_ms, p99_ms) = if lat.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            stats::quantile(&lat, 0.50),
            stats::quantile(&lat, 0.95),
            stats::quantile(&lat, 0.99),
        )
    };
    let throughput_rps = if wall_ms > 0.0 { ok as f64 / (wall_ms / 1e3) } else { 0.0 };
    let shards = pool.metrics().snapshot();
    let pool_row = pool.metrics().pool_stats();
    let probes = pool.metrics().total_probes();
    let (cache_hits, cache_misses, cache_len) = pool.cache_stats();
    let model_counter = |name: &str| -> u64 {
        pool.registry()
            .map(|r| r.counter(name).load(std::sync::atomic::Ordering::Relaxed))
            .unwrap_or(0)
    };
    let model_predictions = model_counter("autosage_model_predictions_total");

    let ops: Vec<&str> = spec.ops.iter().map(|o| o.as_str()).collect();
    let mut text = render_serving_table(
        &format!(
            "serve-bench: {} workers | {} clients x {} reqs | presets [{}] | ops [{}] | F={}",
            pool.n_shards(),
            spec.clients,
            spec.requests_per_client,
            spec.presets.join(","),
            ops.join(","),
            spec.f,
        ),
        &shards,
        Some(&pool_row),
    );
    text.push_str(&format!(
        "\nrequests : {total} total | {ok} ok | {errors} errors | {mismatches} oracle mismatches\n"
    ));
    text.push_str(&format!(
        "schedule : {unique_keys} unique keys | {probes} probes | cache {cache_hits} hits / \
         {cache_misses} misses / {cache_len} entries (single-flight saved {} probes)\n",
        (cache_misses as u64).saturating_sub(probes),
    ));
    if pool.has_model() {
        text.push_str(&format!(
            "model    : {model_predictions} predictions | {} low-confidence probes | \
             {} agree / {} disagree vs probe\n",
            model_counter("autosage_model_low_confidence_probes_total"),
            model_counter("autosage_model_agree_total"),
            model_counter("autosage_model_disagree_total"),
        ));
    }
    text.push_str(&format!(
        "latency  : p50 {p50_ms:.2}ms | p95 {p95_ms:.2}ms | p99 {p99_ms:.2}ms (client-observed)\n"
    ));
    text.push_str(&format!(
        "thruput  : {throughput_rps:.1} req/s over {:.1}ms wall\n",
        wall_ms
    ));

    Ok(LoadReport {
        text,
        csv: serving_table(&shards, Some(&pool_row)),
        total,
        ok,
        errors,
        mismatches,
        wall_ms,
        throughput_rps,
        p50_ms,
        p95_ms,
        p99_ms,
        probes,
        model_predictions,
        unique_keys,
        shards,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_cover_preset_x_op_grid() {
        let spec = LoadSpec {
            clients: 1,
            requests_per_client: 1,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm],
            seed: 7,
            verify: false,
        };
        let combos = build_combos(&spec).unwrap();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].op, Op::Spmm);
        assert_eq!(combos[0].oracle.len(), combos[0].graph.n_rows * 64);
        // SDDMM oracle is per-edge.
        assert_eq!(combos[1].oracle.len(), combos[1].graph.nnz());
    }

    #[test]
    fn combos_reject_bad_specs() {
        let mut spec = LoadSpec::smoke();
        spec.presets = vec!["nope".into()];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.ops = vec![Op::Softmax];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.clients = 0;
        assert!(build_combos(&spec).is_err());
    }

    #[test]
    fn request_schedule_reproducible_under_seed() {
        let a = request_schedule(6, 8, 16, 42);
        let b = request_schedule(6, 8, 16, 42);
        assert_eq!(a, b, "same seed must replay the same mix");
        let c = request_schedule(6, 8, 16, 43);
        assert_ne!(a, c, "a different seed must reshuffle the mix");
        // The shuffle only reorders: every client still covers the
        // round-robin multiset, so totals per combo are unchanged.
        for (mix_a, mix_c) in a.iter().zip(&c) {
            let mut sa = mix_a.clone();
            let mut sc = mix_c.clone();
            sa.sort_unstable();
            sc.sort_unstable();
            assert_eq!(sa, sc);
        }
        // Every combo appears in every client's mix (16 reqs, 6 combos).
        for mix in &a {
            for combo in 0..6 {
                assert!(mix.contains(&combo));
            }
        }
    }

    #[test]
    fn request_schedule_survives_degenerate_shapes() {
        assert_eq!(request_schedule(0, 2, 3, 1).len(), 2); // n_combos clamped
        assert!(request_schedule(4, 0, 3, 1).is_empty());
        assert_eq!(request_schedule(4, 2, 0, 1), vec![vec![], vec![]]);
    }

    #[test]
    fn file_specs_are_accepted_by_build_combos() {
        use crate::data::write_asg;
        let dir = std::env::temp_dir().join("autosage_loadgen_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("combo.asg");
        let (g, _) = crate::data::load_graph_spec("er_s", 9).unwrap();
        write_asg(&path, &g, None).unwrap();
        let spec = LoadSpec {
            clients: 1,
            requests_per_client: 1,
            f: 64,
            presets: vec![format!("file:{}", path.display())],
            ops: vec![Op::Spmm],
            seed: 7,
            verify: false,
        };
        let combos = build_combos(&spec).unwrap();
        assert_eq!(combos.len(), 1);
        assert_eq!(combos[0].graph, g);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn default_specs_are_mixed_and_concurrent() {
        let b = LoadSpec::bench();
        assert!(b.clients >= 8);
        assert!(b.ops.len() == 3);
        let s = LoadSpec::smoke();
        assert!(s.clients >= 8);
        assert_eq!(s.f, 64);
    }
}
