//! Multi-threaded load generator for the serving pool (`autosage
//! serve-bench`): N client threads fire a mixed SpMM/SDDMM/attention
//! request stream built from `gen/` presets at the pool, verify every
//! response against the pure-Rust oracle, and report throughput +
//! client-observed latency next to the pool's per-shard serving
//! metrics.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::bench_kit::render::render_serving_table;
use crate::data::load_graph_spec;
use crate::graph::Csr;
use crate::obs::perf::{Direction, PerfProfile};
use crate::obs::trace::{Recorder, SpanRecord, TraceCtx};
use crate::ops::reference;
use crate::scheduler::{probe, Op};
use crate::telemetry::{serving_table, ServeShardStats};
use crate::util::csv::CsvTable;
use crate::util::rng::Rng;
use crate::util::stats;

use super::pool::{ServerPool, SubmitError};
use super::resilience::ServeError;

/// One load-generation run's shape.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub clients: usize,
    pub requests_per_client: usize,
    /// Feature width for every request (the synthetic catalog carries
    /// SDDMM/attention buckets at F ∈ {64, 128} on er_s/products_s).
    pub f: usize,
    /// Graph specs (`data::spec` grammar): preset names or
    /// `file:PATH` loader-backed datasets.
    pub presets: Vec<String>,
    pub ops: Vec<Op>,
    pub seed: u64,
    /// Check every response against the reference oracle.
    pub verify: bool,
    /// Bounded retry budget per request: `QueueFull` rejections and
    /// deadline sheds are retried up to this many times with seeded
    /// jittered exponential backoff (0 = no retry, blocking submit).
    pub max_retries: usize,
    /// Base backoff before the first retry, in microseconds (doubles
    /// per attempt, plus a seeded jitter of up to one base unit).
    pub retry_backoff_us: u64,
    /// Fraction of SpMM requests submitted in opt-in approximate mode
    /// (seeded per client): they route through the edge-sampled graph
    /// regardless of queue depth and verify against the reply's error
    /// bound. 0.0 = off.
    pub approx_frac: f64,
}

impl LoadSpec {
    /// Default bench shape: 8 clients, mixed ops over two presets.
    pub fn bench() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 8,
            f: 64,
            presets: vec!["er_s".into(), "products_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
            max_retries: 0,
            retry_backoff_us: 200,
            approx_frac: 0.0,
        }
    }

    /// CI smoke shape: same worker/client concurrency, short stream.
    pub fn smoke() -> LoadSpec {
        LoadSpec {
            clients: 8,
            requests_per_client: 2,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm, Op::Attention],
            seed: 42,
            verify: true,
            max_retries: 0,
            retry_backoff_us: 200,
            approx_frac: 0.0,
        }
    }
}

/// Client-observed request failures split by kind (satellite: errors
/// are no longer one opaque number).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ErrorBreakdown {
    /// `SubmitError::QueueFull` after the retry budget was exhausted.
    pub queue_full: usize,
    /// `SubmitError::Closed` — the target shard's worker is dead.
    pub closed: usize,
    /// Typed execute/scheduling failures (`ServeError::Execute`).
    pub execute: usize,
    /// Supervised worker panics (`ServeError::Panic`).
    pub panic: usize,
    /// Deadline sheds (`ServeError::DeadlineExceeded`) after retries.
    pub deadline: usize,
}

impl ErrorBreakdown {
    pub fn total(&self) -> usize {
        self.queue_full + self.closed + self.execute + self.panic + self.deadline
    }

    fn absorb(&mut self, other: &ErrorBreakdown) {
        self.queue_full += other.queue_full;
        self.closed += other.closed;
        self.execute += other.execute;
        self.panic += other.panic;
        self.deadline += other.deadline;
    }

    /// (kind label, count) pairs for metrics export and report text.
    pub fn kinds(&self) -> [(&'static str, usize); 5] {
        [
            ("queue_full", self.queue_full),
            ("closed", self.closed),
            ("execute", self.execute),
            ("panic", self.panic),
            ("deadline", self.deadline),
        ]
    }
}

/// Aggregated result of one run.
pub struct LoadReport {
    /// Human-readable table + summary (serve-bench stdout).
    pub text: String,
    /// Per-shard serving metrics CSV (telemetry format).
    pub csv: CsvTable,
    pub total: usize,
    pub ok: usize,
    pub errors: usize,
    pub mismatches: usize,
    pub wall_ms: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Micro-probes actually run across all shards.
    pub probes: u64,
    /// Cold keys decided by the trained cost model without probing
    /// (0 when no model is attached).
    pub model_predictions: u64,
    /// Distinct (graph, op, F) request keys in the workload.
    pub unique_keys: usize,
    pub shards: Vec<ServeShardStats>,
    /// Client-observed failures split by kind (sums to `errors`).
    pub errors_by_kind: ErrorBreakdown,
    /// Subset of `errors` caused by the fault injector (the chaos
    /// harness subtracts these: they are expected, not regressions).
    pub injected_errors: usize,
    /// Replies served on the edge-sampled graph (graceful degradation).
    pub degraded: usize,
    /// Retry attempts actually performed across all clients.
    pub retries: usize,
    /// Requests submitted in opt-in approximate mode.
    pub approx_requested: usize,
    /// Requests shed past their deadline, summed across shards.
    pub shed: u64,
    /// Worker panics caught by supervision, summed across shards.
    pub worker_panics: u64,
    /// Faults the injector placed (0 when chaos is off).
    pub faults_injected: u64,
    /// Requests quarantined after a supervised panic.
    pub quarantined: usize,
}

impl LoadReport {
    /// Gateable perf metrics for this run. Deterministic counters carry
    /// zero tolerance (the smoke workload is seeded, so request totals,
    /// unique keys and probe counts are exact); wall-clock metrics carry
    /// wide tolerances so the gate fires on order-of-magnitude
    /// regressions, not CI-runner jitter.
    pub fn perf_profile(&self) -> PerfProfile {
        let mut p = PerfProfile::new("serve_bench");
        p.push("requests_total", self.total as f64, Direction::Exact, 0.0);
        p.push("errors", self.errors as f64, Direction::Exact, 0.0);
        p.push(
            "oracle_mismatches",
            self.mismatches as f64,
            Direction::Exact,
            0.0,
        );
        p.push("unique_keys", self.unique_keys as f64, Direction::Exact, 0.0);
        // Single-flight must keep probes at one per unique key.
        p.push("probes", self.probes as f64, Direction::Lower, 0.0);
        p.push(
            "throughput_rps",
            self.throughput_rps,
            Direction::Higher,
            0.95,
        );
        p.push("p50_ms", self.p50_ms, Direction::Lower, 19.0);
        p.push("p99_ms", self.p99_ms, Direction::Lower, 19.0);
        p
    }
}

/// One request template: deterministic operands + its oracle output.
struct Combo {
    op: Op,
    graph: Csr,
    f: usize,
    operands: Vec<(String, Vec<f32>)>,
    oracle: Vec<f32>,
    /// max|B| of the SpMM dense operand (0 for other ops): scales the
    /// degraded-reply error bound `mass × max|B|` (see `data::sample`).
    max_abs_b: f32,
}

fn build_combos(spec: &LoadSpec) -> Result<Vec<Combo>> {
    if spec.ops.is_empty() || spec.presets.is_empty() {
        bail!("load spec needs at least one op and one preset");
    }
    if spec.clients == 0 || spec.requests_per_client == 0 {
        bail!("load spec needs at least one client and one request");
    }
    let mut combos = Vec::new();
    for (pi, name) in spec.presets.iter().enumerate() {
        let (g, _label) = load_graph_spec(name, spec.seed.wrapping_add(pi as u64))?;
        for (oi, &op) in spec.ops.iter().enumerate() {
            if op == Op::Softmax {
                bail!("softmax is served inside the attention pipeline; mix spmm|sddmm|attention");
            }
            let opseed = spec.seed ^ (((pi as u64) << 8) | oi as u64).wrapping_add(1);
            let data = probe::synth_operands(op, g.n_rows, spec.f, opseed);
            let get = |n: &str| -> &[f32] {
                data.dense.get(n).map(|v| v.as_slice()).unwrap_or(&[])
            };
            let oracle = match op {
                Op::Spmm => reference::spmm(&g, get("b"), spec.f),
                Op::Sddmm => reference::sddmm(&g, get("x"), get("y"), spec.f),
                Op::Attention => {
                    reference::csr_attention(&g, get("q"), get("k"), get("v"), spec.f)
                }
                Op::Softmax => unreachable!("rejected above"),
            };
            let operands: Vec<(String, Vec<f32>)> = op
                .dense_operands()
                .iter()
                .map(|n| ((*n).to_string(), data.dense.get(*n).cloned().unwrap_or_default()))
                .collect();
            let max_abs_b = if op == Op::Spmm {
                operands
                    .iter()
                    .find(|(n, _)| n == "b")
                    .map(|(_, v)| v.iter().fold(0.0f32, |m, x| m.max(x.abs())))
                    .unwrap_or(0.0)
            } else {
                0.0
            };
            combos.push(Combo { op, graph: g.clone(), f: spec.f, operands, oracle, max_abs_b });
        }
    }
    Ok(combos)
}

/// Deterministic per-client request mix: a round-robin base (offset by
/// client id so every client covers every combo) shuffled by a
/// per-client [`Rng::for_stream`] stream of `seed`. Two runs with the
/// same seed replay the identical interleaving; changing the seed
/// reshuffles the mix — this is what makes serve-bench A/B comparisons
/// repeatable instead of racing on arrival order alone.
pub fn request_schedule(
    n_combos: usize,
    clients: usize,
    requests_per_client: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    (0..clients)
        .map(|c| {
            let mut rng = Rng::for_stream(seed, c as u64);
            let mut idx: Vec<usize> = (0..requests_per_client)
                .map(|r| (c + r) % n_combos.max(1))
                .collect();
            rng.shuffle(&mut idx);
            idx
        })
        .collect()
}

/// Run the load against `pool` and aggregate a report. Clients walk a
/// seeded [`request_schedule`] over the combo list using the blocking
/// submit path.
pub fn run_load(pool: Arc<ServerPool>, spec: &LoadSpec) -> Result<LoadReport> {
    run_load_traced(pool, spec, None)
}

/// Everything one client thread observed.
#[derive(Default)]
struct ClientTally {
    lat: Vec<f64>,
    ok: usize,
    errors: usize,
    mismatches: usize,
    eb: ErrorBreakdown,
    injected_errors: usize,
    degraded: usize,
    retries: usize,
    approx_requested: usize,
}

/// Seeded jittered exponential backoff between retry attempts:
/// `base × 2^(attempt-1)` plus up to one base unit of jitter.
fn backoff_sleep(rng: &mut Rng, base_us: u64, attempt: usize) {
    let exp = base_us.saturating_mul(1u64 << (attempt.saturating_sub(1)).min(10));
    let jitter = rng.below(base_us.max(1) as usize) as u64;
    std::thread::sleep(Duration::from_micros(exp + jitter));
}

/// Record a client-side root `request` span covering submit → reply.
fn record_request_span(
    recorder: Option<&Recorder>,
    ctx: Option<TraceCtx>,
    client: usize,
    op: Op,
    t0: Instant,
    ok: bool,
) {
    if let (Some(r), Some(ctx)) = (recorder, ctx) {
        r.record(SpanRecord {
            trace: ctx.trace,
            span: ctx.parent,
            parent: None,
            name: "request".to_string(),
            start_us: r.us_of(t0),
            dur_us: t0.elapsed().as_micros() as u64,
            attrs: vec![
                ("client".to_string(), client.to_string()),
                ("op".to_string(), op.as_str().to_string()),
                ("ok".to_string(), ok.to_string()),
            ],
        });
    }
}

/// [`run_load`] with a flight recorder: every request gets a fresh
/// trace id at ingress and carries it through shard queue, coalesced
/// scheduling, backend execute and reply. Pass the same recorder the
/// pool was spawned with so client- and worker-side spans share one
/// timeline.
pub fn run_load_traced(
    pool: Arc<ServerPool>,
    spec: &LoadSpec,
    recorder: Option<Arc<Recorder>>,
) -> Result<LoadReport> {
    let combos = Arc::new(build_combos(spec)?);
    let unique_keys = combos.len();
    let schedule = request_schedule(
        combos.len(),
        spec.clients,
        spec.requests_per_client,
        spec.seed,
    );
    let sw = Instant::now();
    let mut handles = Vec::new();
    for (c, mix) in schedule.into_iter().enumerate() {
        let pool = Arc::clone(&pool);
        let combos = Arc::clone(&combos);
        let verify = spec.verify;
        let max_retries = spec.max_retries;
        let backoff_us = spec.retry_backoff_us;
        let approx_frac = spec.approx_frac;
        let seed = spec.seed;
        let recorder = recorder.clone();
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-client-{c}"))
            .spawn(move || -> ClientTally {
                let mut t = ClientTally::default();
                // Retry backoff jitter gets its own seeded stream per
                // client so the whole run stays replayable.
                let mut retry_rng = Rng::for_stream(seed ^ 0x9e37_79b9, c as u64);
                // Approximate-mode coin flips get their own seeded
                // stream so the same seed replays the same approx mix.
                let mut approx_rng = Rng::for_stream(seed ^ 0x00aa_55aa, c as u64);
                for &ci in &mix {
                    let combo = &combos[ci];
                    // Opt-in approximation is SpMM-only: the sampled-
                    // graph error bound is an SpMM statement.
                    let approx = combo.op == Op::Spmm
                        && approx_frac > 0.0
                        && approx_rng.next_f64() < approx_frac;
                    if approx {
                        t.approx_requested += 1;
                    }
                    let t0 = Instant::now();
                    // Fresh trace per request, subject to head sampling:
                    // unsampled requests travel untraced (None) but still
                    // consume a trace id, so the sampled set is a pure
                    // function of (seed, rate). The root span id doubles
                    // as the parent for every worker-side span. Retried
                    // attempts reuse the same trace.
                    let tctx = recorder.as_ref().and_then(|r| r.sample_ctx());
                    let mut req_ok = false;
                    let mut attempt = 0usize;
                    loop {
                        // With a retry budget, submission must not block:
                        // `QueueFull` is the backoff signal.
                        let submitted = if max_retries == 0 {
                            pool.submit_opts(
                                combo.op,
                                combo.graph.clone(),
                                combo.f,
                                combo.operands.clone(),
                                tctx,
                                approx,
                            )
                        } else {
                            pool.try_submit_opts(
                                combo.op,
                                combo.graph.clone(),
                                combo.f,
                                combo.operands.clone(),
                                tctx,
                                approx,
                            )
                        };
                        let rx = match submitted {
                            Ok(rx) => rx,
                            Err(SubmitError::QueueFull) => {
                                if attempt < max_retries {
                                    attempt += 1;
                                    t.retries += 1;
                                    backoff_sleep(&mut retry_rng, backoff_us, attempt);
                                    continue;
                                }
                                t.errors += 1;
                                t.eb.queue_full += 1;
                                break;
                            }
                            // A dead shard stays dead: retrying `Closed`
                            // only burns the backoff budget.
                            Err(SubmitError::Closed) => {
                                t.errors += 1;
                                t.eb.closed += 1;
                                break;
                            }
                        };
                        match rx.recv() {
                            Err(_) => {
                                t.errors += 1;
                                t.eb.execute += 1;
                                break;
                            }
                            Ok(resp) => match resp.result {
                                Ok(out) => {
                                    req_ok = true;
                                    t.lat.push(t0.elapsed().as_secs_f64() * 1e3);
                                    if resp.degraded.is_some() {
                                        t.degraded += 1;
                                    }
                                    if verify {
                                        // A degraded reply is verified
                                        // against its advertised bound:
                                        // |err| ≤ dropped mass × max|B|
                                        // (plus the usual float slack).
                                        let tol = 2e-3
                                            + resp.degraded.unwrap_or(0.0)
                                                * combo.max_abs_b as f64;
                                        let diff =
                                            reference::max_abs_diff(&out, &combo.oracle);
                                        if (diff as f64) >= tol {
                                            t.mismatches += 1;
                                        } else {
                                            t.ok += 1;
                                        }
                                    } else {
                                        t.ok += 1;
                                    }
                                    break;
                                }
                                Err(ServeError::DeadlineExceeded { .. })
                                    if attempt < max_retries =>
                                {
                                    attempt += 1;
                                    t.retries += 1;
                                    backoff_sleep(&mut retry_rng, backoff_us, attempt);
                                    continue;
                                }
                                Err(e) => {
                                    t.errors += 1;
                                    if e.injected() {
                                        t.injected_errors += 1;
                                    }
                                    match e {
                                        ServeError::DeadlineExceeded { .. } => {
                                            t.eb.deadline += 1
                                        }
                                        ServeError::Panic { .. } => t.eb.panic += 1,
                                        ServeError::Execute { .. } => t.eb.execute += 1,
                                    }
                                    break;
                                }
                            },
                        }
                    }
                    record_request_span(
                        recorder.as_deref(),
                        tctx,
                        c,
                        combo.op,
                        t0,
                        req_ok,
                    );
                }
                t
            })
            .with_context(|| format!("spawning load client {c}"))?;
        handles.push(handle);
    }

    let mut lat = Vec::new();
    let (mut ok, mut errors, mut mismatches) = (0usize, 0usize, 0usize);
    let mut eb = ErrorBreakdown::default();
    let (mut injected_errors, mut degraded, mut retries) = (0usize, 0usize, 0usize);
    let mut approx_requested = 0usize;
    for h in handles {
        let t = h.join().map_err(|_| anyhow!("load client panicked"))?;
        lat.extend(t.lat);
        ok += t.ok;
        errors += t.errors;
        mismatches += t.mismatches;
        eb.absorb(&t.eb);
        injected_errors += t.injected_errors;
        degraded += t.degraded;
        retries += t.retries;
        approx_requested += t.approx_requested;
    }
    let wall_ms = sw.elapsed().as_secs_f64() * 1e3;
    let total = spec.clients * spec.requests_per_client;
    let (p50_ms, p95_ms, p99_ms) = if lat.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            stats::quantile(&lat, 0.50),
            stats::quantile(&lat, 0.95),
            stats::quantile(&lat, 0.99),
        )
    };
    let throughput_rps = if wall_ms > 0.0 { ok as f64 / (wall_ms / 1e3) } else { 0.0 };
    let shards = pool.metrics().snapshot();
    let pool_row = pool.metrics().pool_stats();
    let probes = pool.metrics().total_probes();
    let (cache_hits, cache_misses, cache_len) = pool.cache_stats();
    let model_counter = |name: &str| -> u64 {
        pool.registry()
            .map(|r| r.counter(name).load(std::sync::atomic::Ordering::Relaxed))
            .unwrap_or(0)
    };
    let model_predictions = model_counter("autosage_model_predictions_total");
    let shed = pool.metrics().total_shed();
    let worker_panics = pool.metrics().total_panics();
    let resil = pool.resilience();
    let faults_injected =
        resil.injector.as_ref().map(|i| i.injected_total()).unwrap_or(0);
    let quarantined = resil.quarantine.len();
    // Satellite: client-observed failures land in the metrics registry
    // split by kind, not as one opaque number.
    if let Some(reg) = pool.registry() {
        for (kind, n) in eb.kinds() {
            if n > 0 {
                reg.add(
                    &format!("autosage_client_errors_total{{kind=\"{kind}\"}}"),
                    n as u64,
                );
            }
        }
    }

    let ops: Vec<&str> = spec.ops.iter().map(|o| o.as_str()).collect();
    let mut text = render_serving_table(
        &format!(
            "serve-bench: {} workers | {} clients x {} reqs | presets [{}] | ops [{}] | F={}",
            pool.n_shards(),
            spec.clients,
            spec.requests_per_client,
            spec.presets.join(","),
            ops.join(","),
            spec.f,
        ),
        &shards,
        Some(&pool_row),
    );
    text.push_str(&format!(
        "\nrequests : {total} total | {ok} ok | {errors} errors | {mismatches} oracle mismatches\n"
    ));
    if errors > 0 {
        let parts: Vec<String> = eb
            .kinds()
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(k, n)| format!("{n} {k}"))
            .collect();
        text.push_str(&format!(
            "errors   : {} ({injected_errors} injected)\n",
            parts.join(" | ")
        ));
    }
    if shed + worker_panics + faults_injected > 0
        || degraded + retries + quarantined > 0
    {
        text.push_str(&format!(
            "resil    : {shed} shed | {degraded} degraded | {worker_panics} panics | \
             {faults_injected} faults injected | {quarantined} quarantined | {retries} retries\n"
        ));
    }
    if approx_requested > 0 {
        text.push_str(&format!(
            "approx   : {approx_requested} requested | {degraded} served on the sampled graph \
             (replies carry the error bound)\n"
        ));
    }
    text.push_str(&format!(
        "schedule : {unique_keys} unique keys | {probes} probes | cache {cache_hits} hits / \
         {cache_misses} misses / {cache_len} entries (single-flight saved {} probes)\n",
        (cache_misses as u64).saturating_sub(probes),
    ));
    if pool.has_model() {
        text.push_str(&format!(
            "model    : {model_predictions} predictions | {} low-confidence probes | \
             {} agree / {} disagree vs probe\n",
            model_counter("autosage_model_low_confidence_probes_total"),
            model_counter("autosage_model_agree_total"),
            model_counter("autosage_model_disagree_total"),
        ));
    }
    text.push_str(&format!(
        "latency  : p50 {p50_ms:.2}ms | p95 {p95_ms:.2}ms | p99 {p99_ms:.2}ms (client-observed)\n"
    ));
    text.push_str(&format!(
        "thruput  : {throughput_rps:.1} req/s over {:.1}ms wall\n",
        wall_ms
    ));

    Ok(LoadReport {
        text,
        csv: serving_table(&shards, Some(&pool_row)),
        total,
        ok,
        errors,
        mismatches,
        wall_ms,
        throughput_rps,
        p50_ms,
        p95_ms,
        p99_ms,
        probes,
        model_predictions,
        unique_keys,
        shards,
        errors_by_kind: eb,
        injected_errors,
        degraded,
        retries,
        approx_requested,
        shed,
        worker_panics,
        faults_injected,
        quarantined,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_cover_preset_x_op_grid() {
        let spec = LoadSpec {
            clients: 1,
            requests_per_client: 1,
            f: 64,
            presets: vec!["er_s".into()],
            ops: vec![Op::Spmm, Op::Sddmm],
            seed: 7,
            verify: false,
            max_retries: 0,
            retry_backoff_us: 200,
            approx_frac: 0.0,
        };
        let combos = build_combos(&spec).unwrap();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].op, Op::Spmm);
        assert_eq!(combos[0].oracle.len(), combos[0].graph.n_rows * 64);
        // The SpMM combo must carry a usable degradation bound scale.
        assert!(combos[0].max_abs_b > 0.0);
        assert_eq!(combos[1].max_abs_b, 0.0);
        // SDDMM oracle is per-edge.
        assert_eq!(combos[1].oracle.len(), combos[1].graph.nnz());
    }

    #[test]
    fn combos_reject_bad_specs() {
        let mut spec = LoadSpec::smoke();
        spec.presets = vec!["nope".into()];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.ops = vec![Op::Softmax];
        assert!(build_combos(&spec).is_err());
        let mut spec = LoadSpec::smoke();
        spec.clients = 0;
        assert!(build_combos(&spec).is_err());
    }

    #[test]
    fn request_schedule_reproducible_under_seed() {
        let a = request_schedule(6, 8, 16, 42);
        let b = request_schedule(6, 8, 16, 42);
        assert_eq!(a, b, "same seed must replay the same mix");
        let c = request_schedule(6, 8, 16, 43);
        assert_ne!(a, c, "a different seed must reshuffle the mix");
        // The shuffle only reorders: every client still covers the
        // round-robin multiset, so totals per combo are unchanged.
        for (mix_a, mix_c) in a.iter().zip(&c) {
            let mut sa = mix_a.clone();
            let mut sc = mix_c.clone();
            sa.sort_unstable();
            sc.sort_unstable();
            assert_eq!(sa, sc);
        }
        // Every combo appears in every client's mix (16 reqs, 6 combos).
        for mix in &a {
            for combo in 0..6 {
                assert!(mix.contains(&combo));
            }
        }
    }

    #[test]
    fn request_schedule_survives_degenerate_shapes() {
        assert_eq!(request_schedule(0, 2, 3, 1).len(), 2); // n_combos clamped
        assert!(request_schedule(4, 0, 3, 1).is_empty());
        assert_eq!(request_schedule(4, 2, 0, 1), vec![vec![], vec![]]);
    }

    #[test]
    fn file_specs_are_accepted_by_build_combos() {
        use crate::data::write_asg;
        let dir = std::env::temp_dir().join("autosage_loadgen_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("combo.asg");
        let (g, _) = crate::data::load_graph_spec("er_s", 9).unwrap();
        write_asg(&path, &g, None).unwrap();
        let spec = LoadSpec {
            clients: 1,
            requests_per_client: 1,
            f: 64,
            presets: vec![format!("file:{}", path.display())],
            ops: vec![Op::Spmm],
            seed: 7,
            verify: false,
            max_retries: 0,
            retry_backoff_us: 200,
            approx_frac: 0.0,
        };
        let combos = build_combos(&spec).unwrap();
        assert_eq!(combos.len(), 1);
        assert_eq!(combos[0].graph, g);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn default_specs_are_mixed_and_concurrent() {
        let b = LoadSpec::bench();
        assert!(b.clients >= 8);
        assert!(b.ops.len() == 3);
        let s = LoadSpec::smoke();
        assert!(s.clients >= 8);
        assert_eq!(s.f, 64);
        // Retries are off by default: the perf gate's `errors: Exact 0`
        // contract relies on the blocking submit path.
        assert_eq!(s.max_retries, 0);
        assert!(s.retry_backoff_us > 0);
    }

    #[test]
    fn error_breakdown_sums_and_labels() {
        let mut a = ErrorBreakdown { queue_full: 1, closed: 2, ..Default::default() };
        let b = ErrorBreakdown { execute: 3, panic: 4, deadline: 5, ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.total(), 15);
        let kinds = a.kinds();
        assert_eq!(kinds.iter().map(|(_, n)| n).sum::<usize>(), 15);
        assert!(kinds.iter().any(|(k, n)| *k == "deadline" && *n == 5));
    }
}
