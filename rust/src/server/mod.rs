//! Concurrent serving subsystem — the ROADMAP's production-scale
//! deployment shape for the paper's scheduler (§4.2, §8.6: probe cost
//! amortizes across a request stream through the persistent cache).
//!
//! Pieces:
//! * [`pool`] — sharded worker pool: K workers, each owning its own
//!   backend, requests routed by graph-signature hash, bounded
//!   per-shard queues with backpressure, and same-`(graph, op, F)`
//!   request coalescing inside a batching window.
//! * [`shared_cache`] — pool-wide thread-safe schedule cache with
//!   single-flight probe deduplication: N concurrent misses on one key
//!   pay for ONE probe.
//! * [`metrics`] — per-shard throughput/error/queue counters and
//!   latency histograms (p50/p95/p99), exported through `telemetry`.
//! * [`loadgen`] — the `autosage serve-bench` harness: multi-threaded
//!   clients, mixed op/preset request streams, oracle verification,
//!   bounded retry with seeded jittered backoff.
//! * [`resilience`] — typed serve errors, worker supervision's
//!   quarantine log, deterministic fault injection
//!   (`AUTOSAGE_FAULT_{RATE,KINDS,SEED}`), and the edge-sampled-graph
//!   cache behind graceful degradation under overload.
//!
//! The legacy single-worker `coordinator::ServiceHandle` is a thin
//! compatibility wrapper over [`pool::ServerPool`].

pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod resilience;
pub mod shared_cache;

pub use loadgen::{
    request_schedule, run_load, run_load_traced, ErrorBreakdown, LoadReport, LoadSpec,
};
pub use metrics::{prometheus_snapshot, LatencyHistogram, ServerMetrics, ShardMetrics};
pub use pool::{ServeResponse, ServerPool, SubmitError};
pub use resilience::{
    FaultInjector, FaultKind, QuarantineEntry, QuarantineLog, Resilience, ServeError,
};
pub use shared_cache::{Lookup, ProbeTicket, SharedScheduleCache};
