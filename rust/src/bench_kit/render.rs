//! Rendering: paper-style ASCII tables and speedup-vs-F line figures,
//! plus CSV export. Figures are ASCII because the environment has no
//! plotting stack; the CSV next to each figure carries the same series
//! for external plotting.

use crate::telemetry::ServeShardStats;
use crate::util::csv::CsvTable;

use super::runner::BenchRow;

/// Render rows as the paper's table layout.
pub fn render_table(title: &str, rows: &[BenchRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!(
        "{:>5} | {:<9} | {:>13} | {:>11} | {:>7} | {}\n",
        "F", "choice", "baseline (ms)", "chosen (ms)", "speedup", "variant"
    ));
    out.push_str(&"-".repeat(72));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:>5} | {:<9} | {:>13.3} | {:>11.3} | {:>7.3} | {}\n",
            r.f, r.choice, r.baseline_ms, r.chosen_ms, r.speedup, r.variant
        ));
    }
    out
}

/// Rows → CSV (same columns as the paper + provenance).
pub fn rows_to_csv(rows: &[BenchRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "F", "choice", "variant", "baseline_ms", "chosen_ms", "speedup",
        "probe_wall_ms", "from_cache",
    ]);
    for r in rows {
        t.push(vec![
            r.f.to_string(),
            r.choice.clone(),
            r.variant.clone(),
            format!("{:.4}", r.baseline_ms),
            format!("{:.4}", r.chosen_ms),
            format!("{:.4}", r.speedup),
            format!("{:.3}", r.probe_wall_ms),
            r.from_cache.to_string(),
        ]);
    }
    t
}

/// One `autosage bench` result row: which layout (original/reordered)
/// and op produced the decision row.
pub type GraphBenchRow = (String, String, BenchRow);

/// Render `autosage bench` rows: like the paper tables, plus layout and
/// op columns so an original-vs-reordered comparison reads side by side.
pub fn render_graph_bench(title: &str, rows: &[GraphBenchRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!(
        "{:<10} | {:<9} | {:>5} | {:<9} | {:>13} | {:>11} | {:>7} | {}\n",
        "layout", "op", "F", "choice", "baseline (ms)", "chosen (ms)", "speedup", "variant"
    ));
    out.push_str(&"-".repeat(96));
    out.push('\n');
    for (layout, op, r) in rows {
        out.push_str(&format!(
            "{:<10} | {:<9} | {:>5} | {:<9} | {:>13.3} | {:>11.3} | {:>7.3} | {}\n",
            layout, op, r.f, r.choice, r.baseline_ms, r.chosen_ms, r.speedup, r.variant
        ));
    }
    out
}

/// `autosage bench` rows → CSV (layout/op columns + the table columns).
pub fn graph_bench_csv(rows: &[GraphBenchRow]) -> CsvTable {
    let mut t = CsvTable::new(&[
        "layout", "op", "F", "choice", "variant", "baseline_ms", "chosen_ms",
        "speedup", "probe_wall_ms", "from_cache",
    ]);
    for (layout, op, r) in rows {
        t.push(vec![
            layout.clone(),
            op.clone(),
            r.f.to_string(),
            r.choice.clone(),
            r.variant.clone(),
            format!("{:.4}", r.baseline_ms),
            format!("{:.4}", r.chosen_ms),
            format!("{:.4}", r.speedup),
            format!("{:.3}", r.probe_wall_ms),
            r.from_cache.to_string(),
        ]);
    }
    t
}

/// ASCII per-shard serving-metrics table (`serve-bench` stdout; the
/// CSV twin is `telemetry::serving_table`). With `pool` (counters
/// summed, latency quantiles from the MERGED per-shard histograms via
/// `ServerMetrics::pool_stats`) a separating rule and a `pool` row
/// close the table — per-shard quantiles are never averaged or maxed
/// into a pool number here.
pub fn render_serving_table(
    title: &str,
    shards: &[ServeShardStats],
    pool: Option<&ServeShardStats>,
) -> String {
    fn push_row(out: &mut String, label: &str, s: &ServeShardStats) {
        out.push_str(&format!(
            "{:>5} | {:>8} | {:>7} | {:>9} | {:>6} | {:>9} | {:>6} | {:>8} | {:>4} | {:>4} | {:>6} | {:>8.3} | {:>8.3} | {:>8.3}\n",
            label,
            s.requests,
            s.batches,
            s.coalesced,
            s.probes,
            s.cache_hits,
            s.errors,
            s.rejected,
            s.shed,
            s.degraded,
            s.panics,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms
        ));
    }
    let mut out = format!("{title}\n");
    out.push_str(&format!(
        "{:>5} | {:>8} | {:>7} | {:>9} | {:>6} | {:>9} | {:>6} | {:>8} | {:>4} | {:>4} | {:>6} | {:>8} | {:>8} | {:>8}\n",
        "shard",
        "requests",
        "batches",
        "coalesced",
        "probes",
        "cache_hit",
        "errors",
        "rejected",
        "shed",
        "deg",
        "panics",
        "p50 ms",
        "p95 ms",
        "p99 ms"
    ));
    out.push_str(&"-".repeat(135));
    out.push('\n');
    for s in shards {
        push_row(&mut out, &s.shard.to_string(), s);
    }
    if let Some(p) = pool {
        out.push_str(&"-".repeat(135));
        out.push('\n');
        push_row(&mut out, "pool", p);
    }
    out
}

/// ASCII speedup-vs-F line figure (the paper's Figures 1–7 shape):
/// one `*` series (speedup) with a `1.0x` parity rule.
pub fn render_speedup_figure(title: &str, series: &[(usize, f64)]) -> String {
    const H: usize = 14;
    const WCOL: usize = 8;
    if series.is_empty() {
        return format!("{title}\n(empty series)\n");
    }
    let max_s = series.iter().map(|(_, s)| *s).fold(1.0f64, f64::max) * 1.05;
    let min_s = series.iter().map(|(_, s)| *s).fold(1.0f64, f64::min) * 0.95;
    let span = (max_s - min_s).max(1e-9);
    let y_of = |s: f64| (((s - min_s) / span) * (H - 1) as f64).round() as usize;

    let mut grid = vec![vec![' '; series.len() * WCOL]; H];
    let parity = y_of(1.0);
    for row in grid.iter_mut() {
        row[0] = '|';
    }
    if parity < H {
        for c in grid[H - 1 - parity].iter_mut() {
            if *c == ' ' {
                *c = '.';
            }
        }
    }
    for (i, (_, s)) in series.iter().enumerate() {
        let y = y_of(*s);
        grid[H - 1 - y][i * WCOL + WCOL / 2] = '*';
    }

    let mut out = format!("{title}\n");
    out.push_str(&format!(
        "speedup (y: {:.2}x .. {:.2}x, '.' = parity 1.0x)\n",
        min_s, max_s
    ));
    for row in grid {
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    for (f, _) in series {
        out.push_str(&format!("{:^WCOL$}", f));
    }
    out.push('\n');
    for (f, s) in series {
        out.push_str(&format!("  F={:<4} speedup={:.3}\n", f, s));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(f: usize, b: f64, c: f64) -> BenchRow {
        BenchRow {
            f,
            choice: if b / c > 1.02 { "autosage" } else { "baseline" }.into(),
            variant: "ell_r8_f32".into(),
            baseline_ms: b,
            chosen_ms: c,
            speedup: b / c,
            probe_wall_ms: 3.0,
            from_cache: false,
        }
    }

    #[test]
    fn table_contains_all_rows() {
        let rows = vec![row(64, 1.6, 1.5), row(128, 3.8, 3.8)];
        let s = render_table("Reddit (scaled)", &rows);
        assert!(s.contains("Reddit"));
        assert!(s.contains("64"));
        assert!(s.contains("128"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn csv_roundtrip_width() {
        let t = rows_to_csv(&[row(64, 1.0, 0.5)]);
        assert_eq!(t.n_rows(), 1);
        assert_eq!(t.header().len(), 8);
    }

    #[test]
    fn figure_renders_and_marks_points() {
        let s = render_speedup_figure("fig", &[(32, 1.2), (64, 1.05), (128, 1.0)]);
        assert_eq!(s.matches('*').count(), 3);
        assert!(s.contains("F=32"));
        assert!(s.contains("parity"));
    }

    #[test]
    fn figure_empty_ok() {
        assert!(render_speedup_figure("fig", &[]).contains("empty"));
    }

    #[test]
    fn graph_bench_table_and_csv_carry_layout_column() {
        let rows = vec![
            ("original".to_string(), "spmm".to_string(), row(64, 2.0, 1.0)),
            ("reordered".to_string(), "spmm".to_string(), row(64, 2.0, 0.8)),
        ];
        let s = render_graph_bench("bench skewed", &rows);
        assert!(s.contains("original"), "{s}");
        assert!(s.contains("reordered"), "{s}");
        assert!(s.contains("layout"), "{s}");
        let t = graph_bench_csv(&rows);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.header()[0], "layout");
    }

    #[test]
    fn serving_table_renders_every_shard() {
        let shards = vec![
            ServeShardStats { shard: 0, requests: 12, probes: 3, ..Default::default() },
            ServeShardStats { shard: 1, requests: 7, rejected: 2, ..Default::default() },
        ];
        let s = render_serving_table("serve", &shards, None);
        assert!(s.contains("serve"));
        assert!(s.contains("coalesced"));
        assert_eq!(s.lines().count(), 5); // title + header + rule + 2 shards
    }

    #[test]
    fn serving_table_pool_row_renders_merged_quantiles() {
        let shards = vec![
            ServeShardStats { shard: 0, requests: 990, p99_ms: 1.5, ..Default::default() },
            ServeShardStats { shard: 1, requests: 10, p99_ms: 300.0, ..Default::default() },
        ];
        let pool = ServeShardStats {
            shard: 2,
            requests: 1000,
            p99_ms: 3.0, // merged histogram, below the per-shard max
            ..Default::default()
        };
        let s = render_serving_table("serve", &shards, Some(&pool));
        assert_eq!(s.lines().count(), 7); // + rule + pool row
        let pool_line = s.lines().last().unwrap();
        assert!(pool_line.starts_with(" pool"), "{pool_line}");
        assert!(pool_line.contains("1000"), "{pool_line}");
        assert!(pool_line.contains("3.000"), "{pool_line}");
        assert!(!pool_line.contains("300.000"), "never the per-shard max: {pool_line}");
    }
}
