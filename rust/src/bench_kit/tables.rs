//! One entry point per paper table/figure (see README.md §Benchmarks).
//!
//! Every `run_table(id)` regenerates the corresponding table's rows on
//! this testbed and returns text + CSV; figures reuse the same sweeps.
//! Absolute milliseconds differ from the paper's A800 numbers — the
//! object of comparison is the *shape*: who wins, where the crossover
//! falls, what the guardrail does (see EXPERIMENTS.md).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::AutoSage;
use crate::gen::preset;
use crate::scheduler::{probe, Op};
use crate::util::csv::CsvTable;

use super::render::{render_speedup_figure, render_table, rows_to_csv};
use super::runner::{decision_sweep, BenchRow};

/// Output of one table run.
pub struct TableOutput {
    pub id: String,
    pub title: String,
    pub text: String,
    pub csv: CsvTable,
    /// speedup-vs-F series for the table's figure twin (if any).
    pub series: Vec<(usize, f64)>,
}

pub fn table_ids() -> &'static [&'static str] {
    &["2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12"]
}

const SEED: u64 = 42;

fn fresh_sage(artifacts: &Path, backend: Option<&str>, alpha: f64) -> Result<AutoSage> {
    let mut cfg = Config::from_env().map_err(|e| anyhow!(e))?;
    if let Some(b) = backend {
        cfg.backend = b.to_string();
    }
    cfg.alpha = alpha;
    cfg.cache_path = String::new(); // decisions must be fresh per table
    // Table protocol: medians over >= 9 probe iterations (paper §6 uses
    // 10–15); the default 5 is for latency-sensitive online decisions
    // and flaps near the alpha margin on a single-core host.
    cfg.probe_iters = cfg.probe_iters.max(9);
    cfg.probe_cap_ms = cfg.probe_cap_ms.max(2000.0);
    AutoSage::new(artifacts, cfg, None)
}

#[allow(clippy::too_many_arguments)]
fn sweep_table(
    artifacts: &Path,
    backend: Option<&str>,
    id: &str,
    title: &str,
    preset_name: &str,
    fs: &[usize],
    alpha: f64,
    iters: usize,
    cap_ms: f64,
) -> Result<TableOutput> {
    let mut sage = fresh_sage(artifacts, backend, alpha)?;
    let (g, _) = preset(preset_name, SEED);
    let rows = decision_sweep(&mut sage, &g, Op::Spmm, fs, iters, cap_ms)?;
    Ok(finish(id, title, rows))
}

fn finish(id: &str, title: &str, rows: Vec<BenchRow>) -> TableOutput {
    let series = rows.iter().map(|r| (r.f, r.speedup)).collect();
    TableOutput {
        id: id.to_string(),
        title: title.to_string(),
        text: render_table(title, &rows),
        csv: rows_to_csv(&rows),
        series,
    }
}

/// Run one paper table by id ("2".."12"). `backend` overrides
/// `AUTOSAGE_BACKEND` (CLI `--backend`); `None` defers to the env.
pub fn run_table(
    artifacts: &Path,
    backend: Option<&str>,
    id: &str,
    iters: usize,
    cap_ms: f64,
) -> Result<TableOutput> {
    match id {
        // Table 2: Reddit, F ∈ {64,128,256}, α = 0.95.
        "2" => sweep_table(
            artifacts, backend, "2",
            "Table 2: Reddit (scaled), guardrail = 0.95",
            "reddit_s", &[64, 128, 256], 0.95, iters, cap_ms,
        ),
        // Table 3: OGBN-Products.
        "3" => sweep_table(
            artifacts, backend, "3",
            "Table 3: OGBN-Products (scaled), guardrail = 0.95",
            "products_s", &[64, 128, 256], 0.95, iters, cap_ms,
        ),
        // Table 4: ER synthetic (+ Figure 6).
        "4" => sweep_table(
            artifacts, backend, "4",
            "Table 4: Erdos-Renyi synthetic (scaled), guardrail = 0.95",
            "er_s", &[64, 128, 256], 0.95, iters, cap_ms,
        ),
        // Table 5: hub-skew synthetic (+ Figure 7).
        "5" => sweep_table(
            artifacts, backend, "5",
            "Table 5: Hub-skew synthetic (scaled), guardrail = 0.95",
            "hub_s", &[64, 128, 256], 0.95, iters, cap_ms,
        ),
        // Table 6: guardrail sensitivity — Reddit at α = 0.98 (+ Fig 3).
        "6" => sweep_table(
            artifacts, backend, "6",
            "Table 6: Guardrail sensitivity (Reddit scaled), alpha = 0.98",
            "reddit_s", &[64, 128, 256], 0.98, iters, cap_ms,
        ),
        // Table 7: Reddit wide-F sweep (+ Figure 5).
        "7" => sweep_table(
            artifacts, backend, "7",
            "Table 7: Reddit (scaled) feature-width sweep",
            "reddit_s", &[32, 64, 96, 128, 192, 256], 0.95, iters, cap_ms,
        ),
        // Table 8: Products wide-F sweep (+ Figures 1/2).
        "8" => sweep_table(
            artifacts, backend, "8",
            "Table 8: Products (scaled) feature-width sweep",
            "products_s", &[32, 64, 96, 128, 192, 256], 0.95, iters, cap_ms,
        ),
        "9" => table9_vec_ablation(artifacts, backend, iters, cap_ms),
        "10" => table10_split(artifacts, backend, iters, cap_ms),
        "11" => table11_probe_overhead(artifacts, backend, iters, cap_ms),
        "12" => table12_attention(artifacts, backend, iters, cap_ms),
        other => Err(anyhow!("unknown table id {other:?} (valid: 2..12)")),
    }
}

/// Table 9: vec ablation — where a Pallas kernel is chosen, compare the
/// wide-lane (f128, the vec4 analog) against the scalar (f32) tiling.
/// speedup = scalar_ms / wide_ms (OFF/ON; > 1 means vec helps).
fn table9_vec_ablation(artifacts: &Path, backend: Option<&str>, iters: usize, cap_ms: f64) -> Result<TableOutput> {
    let mut sage = fresh_sage(artifacts, backend, 0.95)?;
    let mut csv = CsvTable::new(&["dataset", "F", "scalar_ms", "wide_ms", "speedup"]);
    let mut text = String::from(
        "Table 9: wide-lane (vec) ablation, speedup = scalar/wide (>1 helps)\n",
    );
    let mut series = Vec::new();
    for (ds, fs, scalar_v, wide_v) in [
        ("er_s", vec![128usize, 256], "ell_r8_f32", "ell_r8_f128"),
        ("reddit_s", vec![128, 256], "ell_r8_f32", "ell_r8_f128"),
    ] {
        let (g, _) = preset(ds, SEED);
        for &f in &fs {
            let s = sage.time_op(&g, Op::Spmm, f, scalar_v, iters, cap_ms)?;
            let w = sage.time_op(&g, Op::Spmm, f, wide_v, iters, cap_ms)?;
            let sp = s.median_ms / w.median_ms.max(1e-9);
            csv.push(vec![
                ds.into(),
                f.to_string(),
                format!("{:.4}", s.median_ms),
                format!("{:.4}", w.median_ms),
                format!("{sp:.4}"),
            ]);
            text.push_str(&format!(
                "{ds:>10}  F={f:<4} scalar={:.3}ms wide={:.3}ms speedup={sp:.3}\n",
                s.median_ms, w.median_ms
            ));
            series.push((f, sp));
        }
    }
    Ok(TableOutput {
        id: "9".into(),
        title: "Table 9: vec ablation".into(),
        text,
        csv,
        series,
    })
}

/// Table 10: CTA-per-hub split vs vendor baseline on hub-skewed graphs
/// at F = 128 (the paper's two scaled configs).
fn table10_split(artifacts: &Path, backend: Option<&str>, iters: usize, cap_ms: f64) -> Result<TableOutput> {
    let mut sage = fresh_sage(artifacts, backend, 0.95)?;
    let mut csv =
        CsvTable::new(&["setting", "baseline_ms", "split_ms", "speedup"]);
    let mut text =
        String::from("Table 10: hub split vs baseline (F=128, scaled configs)\n");
    let mut series = Vec::new();
    for (i, (ds, label)) in [
        ("t10a", "N=2048, hub deg 512, other 64"),
        ("t10b", "N=2048, hub deg 1024, other 32"),
    ]
    .iter()
    .enumerate()
    {
        let (g, _) = preset(ds, SEED);
        let b = sage.time_op(&g, Op::Spmm, 128, "baseline", iters, cap_ms)?;
        let s = sage.time_op(&g, Op::Spmm, 128, "hub_gather", iters, cap_ms)?;
        let sp = b.median_ms / s.median_ms.max(1e-9);
        csv.push(vec![
            label.to_string(),
            format!("{:.4}", b.median_ms),
            format!("{:.4}", s.median_ms),
            format!("{sp:.4}"),
        ]);
        text.push_str(&format!(
            "{label}: baseline={:.3}ms split={:.3}ms speedup={sp:.3}\n",
            b.median_ms, s.median_ms
        ));
        series.push((i + 1, sp));
    }
    Ok(TableOutput {
        id: "10".into(),
        title: "Table 10: split vs baseline".into(),
        text,
        csv,
        series,
    })
}

/// §8.6: probe overhead as a fraction of one full-graph iteration at
/// Reddit F=64, for the default and the low-overhead probe settings.
fn table11_probe_overhead(artifacts: &Path, backend: Option<&str>, iters: usize, cap_ms: f64) -> Result<TableOutput> {
    let mut csv = CsvTable::new(&[
        "probe_frac", "cap_ms", "probe_wall_ms", "full_iter_ms", "overhead_pct",
    ]);
    let mut text = String::from("Probe overhead (Reddit scaled, F=64)\n");
    let mut series = Vec::new();
    for (i, (frac, cap)) in [(0.03, 1000.0), (0.02, 500.0)].iter().enumerate() {
        let mut cfg = Config::from_env().map_err(|e| anyhow!(e))?;
        if let Some(b) = backend {
            cfg.backend = b.to_string();
        }
        cfg.probe_frac = *frac;
        cfg.probe_cap_ms = *cap;
        cfg.cache_path = String::new();
        let mut sage = AutoSage::new(artifacts, cfg, None)?;
        let (g, _) = preset("reddit_s", SEED);
        let d = sage.decide(&g, Op::Spmm, 64)?;
        let full = sage.time_op(&g, Op::Spmm, 64, "baseline", iters, cap_ms)?;
        let pct = 100.0 * d.probe_wall_ms / full.median_ms.max(1e-9);
        csv.push(vec![
            format!("{frac}"),
            format!("{cap}"),
            format!("{:.3}", d.probe_wall_ms),
            format!("{:.3}", full.median_ms),
            format!("{pct:.1}"),
        ]);
        text.push_str(&format!(
            "frac={frac} cap={cap}ms: probe={:.2}ms, full-iter={:.2}ms ({pct:.1}%)\n",
            d.probe_wall_ms, full.median_ms
        ));
        series.push((i + 1, pct));
    }
    Ok(TableOutput {
        id: "11".into(),
        title: "Probe overhead (8.6)".into(),
        text,
        csv,
        series,
    })
}

/// §8.7: SDDMM-auto + softmax + SpMM composed as CSR attention on
/// products (scaled): uncached (probe-dominated) vs cached replay, with
/// per-sub-op choices.
fn table12_attention(artifacts: &Path, backend: Option<&str>, iters: usize, cap_ms: f64) -> Result<TableOutput> {
    let mut sage = fresh_sage(artifacts, backend, 0.95)?;
    let (g, _) = preset("products_s", SEED);
    let f = 64usize;
    let data = probe::synth_operands(Op::Attention, g.n_rows, f, 77);
    let q = data.dense.get("q").unwrap().clone();
    let k = data.dense.get("k").unwrap().clone();
    let v = data.dense.get("v").unwrap().clone();

    // Uncached: decision includes the probe.
    let sw = crate::util::timing::Stopwatch::start();
    let d1 = sage.decide(&g, Op::Attention, f)?;
    let _ = sage.attention_with(&g, &q, &k, &v, f, d1.choice.variant())?;
    let uncached_ms = sw.ms();

    // Cached replay: same key hits the in-memory cache.
    let sw = crate::util::timing::Stopwatch::start();
    let d2 = sage.decide(&g, Op::Attention, f)?;
    let _ = sage.attention_with(&g, &q, &k, &v, f, d2.choice.variant())?;
    let replay_ms = sw.ms();

    let base = sage.time_op(&g, Op::Attention, f, "baseline", iters, cap_ms)?;
    let chosen = sage.time_op(&g, Op::Attention, f, d1.choice.variant(), iters, cap_ms)?;

    let mut csv = CsvTable::new(&[
        "phase", "choice", "latency_ms", "baseline_ms", "speedup",
    ]);
    let sp = base.median_ms / chosen.median_ms.max(1e-9);
    csv.push(vec![
        "uncached".into(),
        d1.choice.variant().into(),
        format!("{uncached_ms:.3}"),
        format!("{:.3}", base.median_ms),
        format!("{sp:.4}"),
    ]);
    csv.push(vec![
        "replay".into(),
        d2.choice.variant().into(),
        format!("{replay_ms:.3}"),
        format!("{:.3}", base.median_ms),
        format!("{sp:.4}"),
    ]);
    let text = format!(
        "CSR attention (products scaled, F={f})\n\
         uncached (probe + exec): {uncached_ms:.2}ms, choice={}\n\
         cached replay          : {replay_ms:.2}ms (cache source={})\n\
         steady-state kernel    : baseline={:.3}ms chosen={:.3}ms speedup={sp:.3}\n",
        d1.choice.variant(),
        if d2.source == crate::scheduler::DecisionSource::Cache { "hit" } else { "MISS" },
        base.median_ms,
        chosen.median_ms,
    );
    Ok(TableOutput {
        id: "12".into(),
        title: "CSR attention pipeline (8.7)".into(),
        text,
        csv,
        series: vec![(f, sp)],
    })
}

/// Entry point for the `cargo bench` targets (criterion is unavailable
/// offline; each bench is a `harness = false` binary calling this).
/// Honors `AUTOSAGE_BENCH_ITERS`; writes CSV + txt into `results/bench/`.
pub fn bench_main(table_id: &str) {
    let iters = std::env::var("AUTOSAGE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(7usize);
    let artifacts = PathBuf::from(
        std::env::var("AUTOSAGE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let sw = crate::util::timing::Stopwatch::start();
    match run_table(&artifacts, None, table_id, iters, 1500.0) {
        Ok(out) => {
            println!("{}", out.text);
            let dir = PathBuf::from("results/bench");
            let _ = std::fs::create_dir_all(&dir);
            let _ = out.csv.write_to(&dir.join(format!("table{table_id}.csv")));
            let _ = std::fs::write(
                dir.join(format!("table{table_id}.txt")),
                &out.text,
            );
            println!(
                "bench table{table_id}: {} rows in {:.1}s -> results/bench/",
                out.csv.n_rows(),
                sw.ms() / 1e3
            );
        }
        Err(e) => {
            eprintln!("bench table{table_id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Figure ids → (title, source table). Figures re-render a table's
/// speedup series as an ASCII plot (+ CSV twin).
pub fn figure_source(id: &str) -> Option<(&'static str, &'static str)> {
    match id {
        "1" => Some(("Figure 1: speedup vs F on Products (scaled)", "8")),
        "2" => Some(("Figure 2: Products wide F sweep", "8")),
        "3" => Some(("Figure 3: Reddit guardrail = 0.98", "6")),
        "4" => Some(("Figure 4: Reddit guardrail = 0.95", "2")),
        "5" => Some(("Figure 5: Reddit wide F sweep", "7")),
        "6" => Some(("Figure 6: Synthetic ER speedups", "4")),
        "7" => Some(("Figure 7: Hub-skew synthetic speedups", "5")),
        _ => None,
    }
}

/// Render a figure by id, running its source table.
pub fn run_figure(
    artifacts: &Path,
    backend: Option<&str>,
    id: &str,
    iters: usize,
    cap_ms: f64,
) -> Result<(String, CsvTable)> {
    let (title, table_id) =
        figure_source(id).ok_or_else(|| anyhow!("unknown figure id {id:?}"))?;
    let out = run_table(artifacts, backend, table_id, iters, cap_ms)?;
    Ok((render_speedup_figure(title, &out.series), out.csv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_map_to_tables() {
        for id in ["1", "2", "3", "4", "5", "6", "7"] {
            let (_, t) = figure_source(id).unwrap();
            assert!(table_ids().contains(&t));
        }
        assert!(figure_source("9").is_none());
    }

    #[test]
    fn unknown_table_is_error() {
        assert!(run_table(Path::new("/nonexistent"), None, "99", 3, 100.0).is_err());
    }
}
