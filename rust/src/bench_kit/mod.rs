//! Bench harness (criterion replacement; criterion is unavailable in
//! this offline environment): decision-row runners for the paper's
//! tables, ASCII figure rendering, and CSV + meta-sidecar output.

pub mod render;
pub mod runner;
pub mod tables;

pub use render::{render_serving_table, render_speedup_figure, render_table};
pub use runner::{decision_row, decision_sweep, BenchRow};
pub use tables::{run_table, table_ids, TableOutput};
