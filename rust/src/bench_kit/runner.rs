//! Decision-row runner: produce one row of a paper table —
//! `F | choice | baseline (ms) | chosen (ms) | speedup` — by running the
//! scheduler and then timing both the vendor baseline and the chosen
//! kernel on the *full* graph (the paper's protocol: medians over warmed
//! iterations).

use anyhow::Result;

use crate::coordinator::AutoSage;
use crate::graph::Csr;
use crate::obs::perf::{Direction, PerfProfile};
use crate::scheduler::{DecisionSource, Op};

/// One table row.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub f: usize,
    pub choice: String,       // "autosage" | "baseline"
    pub variant: String,      // concrete variant id
    pub baseline_ms: f64,
    pub chosen_ms: f64,
    pub speedup: f64,
    pub probe_wall_ms: f64,
    pub from_cache: bool,
}

/// Run the scheduler for (g, op, f) and measure both sides on the full
/// graph. `iters`/`cap_ms` bound the timing loop per kernel.
pub fn decision_row(
    sage: &mut AutoSage,
    g: &Csr,
    op: Op,
    f: usize,
    iters: usize,
    cap_ms: f64,
) -> Result<BenchRow> {
    let d = sage.decide(g, op, f)?;
    let baseline = sage.time_op(g, op, f, "baseline", iters, cap_ms)?;
    let chosen = if d.choice.is_baseline() {
        baseline.clone()
    } else {
        sage.time_op(g, op, f, d.choice.variant(), iters, cap_ms)?
    };
    Ok(BenchRow {
        f,
        choice: d.choice_label().to_string(),
        variant: d.choice.variant().to_string(),
        baseline_ms: baseline.median_ms,
        chosen_ms: chosen.median_ms,
        speedup: baseline.median_ms / chosen.median_ms.max(1e-9),
        probe_wall_ms: d.probe_wall_ms,
        from_cache: d.source == DecisionSource::Cache,
    })
}

/// Rows for `autosage bench`: every op on the original layout, plus —
/// when a reordered twin is given — the same ops on that layout, so
/// the rendered table shows whether the reorder changed the chosen
/// variant or its measured time. Row tag = (layout, op, row).
pub fn graph_bench_rows(
    sage: &mut AutoSage,
    g: &Csr,
    reordered: Option<&Csr>,
    ops: &[Op],
    f: usize,
    iters: usize,
    cap_ms: f64,
) -> Result<Vec<(String, String, BenchRow)>> {
    let mut rows = Vec::new();
    for &op in ops {
        rows.push((
            "original".to_string(),
            op.as_str().to_string(),
            decision_row(sage, g, op, f, iters, cap_ms)?,
        ));
    }
    if let Some(rg) = reordered {
        for &op in ops {
            rows.push((
                "reordered".to_string(),
                op.as_str().to_string(),
                decision_row(sage, rg, op, f, iters, cap_ms)?,
            ));
        }
    }
    Ok(rows)
}

/// Gateable perf metrics for a set of bench rows. Keys are
/// `{layout}_{op}_chosen_ms` (lower is better, very wide tolerance —
/// the gate targets order-of-magnitude slowdowns, not runner jitter)
/// and `{layout}_{op}_speedup` (higher is better; the guardrail keeps
/// this ≥ ~1, so a large drop means a scheduling regression).
pub fn perf_profile(rows: &[(String, String, BenchRow)]) -> PerfProfile {
    let mut p = PerfProfile::new("bench");
    for (layout, op, row) in rows {
        let k = format!("{layout}_{op}");
        p.push(&format!("{k}_chosen_ms"), row.chosen_ms, Direction::Lower, 49.0);
        p.push(&format!("{k}_speedup"), row.speedup, Direction::Higher, 0.9);
    }
    p
}

/// A feature-width sweep (one paper table = one sweep).
pub fn decision_sweep(
    sage: &mut AutoSage,
    g: &Csr,
    op: Op,
    fs: &[usize],
    iters: usize,
    cap_ms: f64,
) -> Result<Vec<BenchRow>> {
    fs.iter()
        .map(|&f| decision_row(sage, g, op, f, iters, cap_ms))
        .collect()
}
