//! Synthetic workload generators: the paper's datasets, scaled.
//!
//! Real Reddit / OGBN-Products are too large for interpret-mode CPU
//! execution, so each is replaced by a seeded generator calibrated to the
//! same *degree-distribution shape* (see README.md §Workloads).
//! Every generator respects its preset's shape contract in
//! `python/compile/catalog.py` (degree cap ≤ w_plain, hub count ≤ h_pad,
//! nnz ≤ nnz_pad) so the AOT buckets always fit.

pub mod presets;
pub mod synth;

pub use presets::{preset, preset_names, PresetSpec};
pub use synth::{erdos_renyi, hub_skew, power_law};
