//! Core synthetic graph generators (ER, hub-skew, power-law).
//!
//! All are deterministic in (parameters, seed) and emit sorted CSR rows
//! with uniform [0,1) edge values. Self-loops are allowed (they are
//! ordinary nonzeros to a kernel); duplicate columns within a row are not.
//!
//! Seeding: each row draws from its own [`Rng::for_stream`] stream
//! `(seed, row)`, never from one shared generator. Row `i`'s content is
//! therefore a pure function of `(params, seed, i)` — it cannot shift
//! because an earlier row consumed a different number of draws — which
//! is what keeps serve-bench load mixes bit-reproducible run-to-run
//! under a single `--seed`.

use crate::graph::Csr;
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, p) by row: degree ~ Binomial(n, p) ≈ Poisson(np),
/// matching the paper's "ER N=200k, p=2e-5" stressor regime (tiny rows).
/// Degrees are clamped to `cap`.
pub fn erdos_renyi(n: usize, avg_deg: f64, cap: usize, seed: u64) -> Csr {
    let rows = (0..n)
        .map(|i| {
            let mut rng = Rng::for_stream(seed, i as u64);
            let d = rng.poisson(avg_deg).min(cap).min(n);
            rng.sample_distinct(n, d)
                .into_iter()
                .map(|c| (c as u32, rng.next_f32()))
                .collect()
        })
        .collect();
    Csr::from_rows(n, rows)
}

/// Hub-skew: every row has base degree `k`; a fraction `h` of rows are
/// hubs with degree `hub_deg` (paper: N=200k, k=4, h=0.15).
pub fn hub_skew(n: usize, k: usize, h: f64, hub_deg: usize, seed: u64) -> Csr {
    let n_hubs = ((n as f64) * h).round() as usize;
    // Deterministic hub placement: spread hubs evenly; each row's
    // adjacency then comes from its own (seed, row) stream.
    let mut is_hub = vec![false; n];
    if n_hubs > 0 {
        let stride = n as f64 / n_hubs as f64;
        for i in 0..n_hubs {
            is_hub[(i as f64 * stride) as usize] = true;
        }
    }
    let rows = (0..n)
        .map(|i| {
            let mut rng = Rng::for_stream(seed, i as u64);
            let d = if is_hub[i] { hub_deg } else { k }.min(n);
            rng.sample_distinct(n, d)
                .into_iter()
                .map(|c| (c as u32, rng.next_f32()))
                .collect()
        })
        .collect();
    Csr::from_rows(n, rows)
}

/// Power-law (discrete Pareto) degrees: `deg ~ floor(x_min * U^(-1/alpha))`
/// clamped to `[1, cap]` — the heavy-tailed model for Reddit/Products-like
/// graphs. `cap` doubles as the preset's `w_plain` contract.
pub fn power_law(n: usize, x_min: f64, alpha: f64, cap: usize, seed: u64) -> Csr {
    let rows = (0..n)
        .map(|i| {
            let mut rng = Rng::for_stream(seed, i as u64);
            let d = rng.pareto_deg(x_min, alpha, cap).min(n);
            rng.sample_distinct(n, d)
                .into_iter()
                .map(|c| (c as u32, rng.next_f32()))
                .collect()
        })
        .collect();
    Csr::from_rows(n, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn er_avg_degree_close() {
        let g = erdos_renyi(2000, 4.0, 32, 7);
        g.validate().unwrap();
        assert!((g.avg_degree() - 4.0).abs() < 0.3, "{}", g.avg_degree());
        assert!(g.max_degree() <= 32);
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(500, 4.0, 32, 1), erdos_renyi(500, 4.0, 32, 1));
        assert_ne!(erdos_renyi(500, 4.0, 32, 1), erdos_renyi(500, 4.0, 32, 2));
    }

    #[test]
    fn hub_skew_structure() {
        let g = hub_skew(1000, 4, 0.15, 64, 3);
        g.validate().unwrap();
        let degs = g.degrees();
        let hubs = degs.iter().filter(|&&d| d == 64).count();
        let light = degs.iter().filter(|&&d| d == 4).count();
        assert_eq!(hubs, 150);
        assert_eq!(light, 850);
    }

    #[test]
    fn hub_skew_gini_exceeds_er() {
        let er = erdos_renyi(1000, 8.0, 64, 5);
        let hs = hub_skew(1000, 4, 0.15, 64, 5);
        let gd = |g: &Csr| {
            let d: Vec<f64> = g.degrees().iter().map(|&x| x as f64).collect();
            stats::gini(&d)
        };
        assert!(gd(&hs) > gd(&er) + 0.2);
    }

    #[test]
    fn power_law_heavy_tail_and_capped() {
        let g = power_law(4000, 12.0, 1.6, 256, 11);
        g.validate().unwrap();
        assert!(g.max_degree() <= 256);
        assert!(g.max_degree() > 128, "tail too light: {}", g.max_degree());
        let avg = g.avg_degree();
        assert!((20.0..40.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn no_duplicate_columns_within_rows() {
        let g = power_law(500, 8.0, 1.4, 128, 13);
        for i in 0..g.n_rows {
            let (cols, _) = g.row(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1], "row {i} has duplicate/unsorted cols");
            }
        }
    }
}
