//! Named workload presets matching the artifact catalog's shape contract
//! (`python/compile/catalog.py` PRESETS). Each preset is the scaled
//! stand-in for a paper workload — see README.md §Workloads for the
//! substitution rationale and calibration targets.

use crate::graph::Csr;

use super::synth::{erdos_renyi, hub_skew, power_law};

/// A preset: generator parameters + the catalog bucket contract.
#[derive(Debug, Clone, PartialEq)]
pub struct PresetSpec {
    pub name: &'static str,
    /// Paper workload this stands in for.
    pub paper_name: &'static str,
    pub n: usize,
    /// Degree cap == catalog `w_plain`.
    pub w_plain: usize,
    pub nnz_pad: usize,
    pub default_seed: u64,
}

/// All preset names, in catalog order.
pub fn preset_names() -> &'static [&'static str] {
    &["er_s", "hub_s", "reddit_s", "products_s", "t10a", "t10b"]
}

/// Generate a preset graph. Panics on unknown name (CLI validates first).
pub fn preset(name: &str, seed: u64) -> (Csr, PresetSpec) {
    let (g, spec) = match name {
        // ER N=200k p=2e-5 (avg deg 4), scaled.
        "er_s" => (
            erdos_renyi(4096, 4.0, 32, seed),
            PresetSpec {
                name: "er_s",
                paper_name: "Erdos-Renyi N=200k p=2e-5",
                n: 4096,
                w_plain: 32,
                nnz_pad: 32768,
                default_seed: seed,
            },
        ),
        // Hub-skew N=200k k=4 h=0.15, scaled; hub degree = 512.
        "hub_s" => (
            hub_skew(4096, 4, 0.15, 512, seed),
            PresetSpec {
                name: "hub_s",
                paper_name: "hub-skew N=200k k=4 h=0.15",
                n: 4096,
                w_plain: 512,
                nnz_pad: 524288,
                default_seed: seed,
            },
        ),
        // Reddit (PyG): power-law, avg deg ~29 after cap 256.
        "reddit_s" => (
            power_law(4096, 12.0, 1.6, 256, seed),
            PresetSpec {
                name: "reddit_s",
                paper_name: "Reddit (PyG), scaled",
                n: 4096,
                w_plain: 256,
                nnz_pad: 262144,
                default_seed: seed,
            },
        ),
        // OGBN-Products: power-law, avg deg ~15 after cap 128.
        "products_s" => (
            power_law(8192, 6.0, 1.6, 128, seed),
            PresetSpec {
                name: "products_s",
                paper_name: "OGBN-Products, scaled",
                n: 8192,
                w_plain: 128,
                nnz_pad: 262144,
                default_seed: seed,
            },
        ),
        // Table 10 configs (scaled /10): fixed hub count + heavy degree.
        "t10a" => (
            hub_skew(2048, 64, 32.0 / 2048.0, 512, seed),
            PresetSpec {
                name: "t10a",
                paper_name: "T10: N=20k hub=5k other=64",
                n: 2048,
                w_plain: 512,
                nnz_pad: 262144,
                default_seed: seed,
            },
        ),
        "t10b" => (
            hub_skew(2048, 32, 32.0 / 2048.0, 1024, seed),
            PresetSpec {
                name: "t10b",
                paper_name: "T10: N=20k hub=12k other=32",
                n: 2048,
                w_plain: 1024,
                nnz_pad: 131072,
                default_seed: seed,
            },
        ),
        other => panic!("unknown preset {other:?}; see preset_names()"),
    };
    debug_assert!(g.validate().is_ok());
    (g, spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_respect_catalog_contract() {
        for &name in preset_names() {
            let (g, spec) = preset(name, 42);
            g.validate().unwrap();
            assert!(
                g.max_degree() <= spec.w_plain,
                "{name}: max degree {} > w_plain {}",
                g.max_degree(),
                spec.w_plain
            );
            assert!(
                g.nnz() <= spec.nnz_pad,
                "{name}: nnz {} > nnz_pad {}",
                g.nnz(),
                spec.nnz_pad
            );
            assert_eq!(g.n_rows, spec.n, "{name}");
        }
    }

    #[test]
    fn presets_deterministic() {
        for &name in preset_names() {
            assert_eq!(preset(name, 7).0, preset(name, 7).0, "{name}");
        }
    }

    #[test]
    fn er_matches_paper_regime() {
        let (g, _) = preset("er_s", 42);
        assert!((g.avg_degree() - 4.0).abs() < 0.3);
    }

    #[test]
    fn hub_s_fraction_matches_paper() {
        let (g, _) = preset("hub_s", 42);
        let hubs = g.degrees().iter().filter(|&&d| d >= 512).count();
        let frac = hubs as f64 / g.n_rows as f64;
        assert!((frac - 0.15).abs() < 0.01, "hub fraction {frac}");
    }

    #[test]
    fn reddit_s_hub_partition_fits_catalog() {
        // Catalog contract: hubs (deg > w_light=128) fit in h_pad=256.
        let (g, _) = preset("reddit_s", 42);
        let hubs = g.degrees().iter().filter(|&&d| d > 128).count();
        assert!(hubs <= 256, "{hubs} hubs overflow h_pad");
        assert!(hubs > 16, "want a meaningful hub population, got {hubs}");
    }

    #[test]
    fn products_s_hub_partition_fits_catalog() {
        let (g, _) = preset("products_s", 42);
        let hubs = g.degrees().iter().filter(|&&d| d > 64).count();
        assert!(hubs <= 256, "{hubs} hubs overflow h_pad=256");
    }

    #[test]
    fn t10_configs_fit() {
        for name in ["t10a", "t10b"] {
            let (g, spec) = preset(name, 42);
            let hubs = g
                .degrees()
                .iter()
                .filter(|&&d| d > spec.w_plain / 4)
                .count();
            assert!(hubs <= 64, "{name}: {hubs} hubs overflow h_pad=64");
        }
    }
}
