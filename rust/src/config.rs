//! Runtime configuration: the paper's one-line deployment toggles (§5).
//!
//! | Env var                 | Meaning                                | Default |
//! |-------------------------|----------------------------------------|---------|
//! | `AUTOSAGE_BACKEND`      | execution engine: `auto` \| `native` \| `pjrt`. `auto` = PJRT when built with the `pjrt` feature AND `artifacts/manifest.json` exists, else the pure-Rust native backend | auto |
//! | `AUTOSAGE_ALPHA`        | guardrail acceptance factor α          | 0.95    |
//! | `AUTOSAGE_PROBE_FRAC`   | induced-subgraph row fraction          | 0.02    |
//! | `AUTOSAGE_PROBE_MIN`    | minimum probe rows                     | 512     |
//! | `AUTOSAGE_PROBE_ITERS`  | timed probe iterations                 | 5       |
//! | `AUTOSAGE_PROBE_CAP_MS` | probe wall-time cap per candidate (ms) | 1000    |
//! | `AUTOSAGE_TOPK`         | candidates probed after the estimate   | 3       |
//! | `AUTOSAGE_HUB_T`        | hub degree threshold override (0=auto) | 0       |
//! | `AUTOSAGE_VEC`          | allow wide-lane (f128 / "vec") paths   | true    |
//! | `AUTOSAGE_GRID`         | let the scheduler pick Pallas *grid* kernels (row-tile/hub-tile). Off by default on this CPU testbed: interpret-mode grids are correctness/ablation targets whose per-step emulation cost does not extrapolate; the gather family is their executable twin; the native backend runs grids at real cost regardless (see backend/) | false |
//! | `AUTOSAGE_CACHE`        | schedule-cache path ("" disables)      | autosage_cache.json |
//! | `AUTOSAGE_REPLAY_ONLY`  | never probe; cache miss = baseline     | false   |
//! | `AUTOSAGE_BENCH_ITERS`  | bench harness timed iterations         | 12      |
//! | `AUTOSAGE_SERVE_WORKERS` | serving pool shard/worker count       | 4       |
//! | `AUTOSAGE_SERVE_QUEUE`  | bounded per-shard queue depth (submit rejects with `QueueFull` beyond it) | 64 |
//! | `AUTOSAGE_SERVE_BATCH`  | max requests drained per batch         | 16      |
//! | `AUTOSAGE_SERVE_WINDOW_US` | batching window: how long a worker waits past the first request for coalescable stragglers (µs; 0 = drain-only) | 0 |
//! | `AUTOSAGE_CACHE_FLUSH_MS` | serving pool schedule-cache flush throttle: dirty entries/counters persist at most once per this many ms (and always at shutdown) | 2000 |
//! | `AUTOSAGE_TRACE_SAMPLE` | head-sampling rate for serve-bench traces in [0,1]: each trace id is kept iff `hash(seed ^ id) < rate`, so the sampled set is deterministic under `--seed` (1.0 = trace everything, 0.0 = trace nothing) | 1.0 |
//! | `AUTOSAGE_TRACE_RING`   | flight-recorder span ring-buffer capacity (0 = unbounded); overflow evicts oldest unflushed spans and counts them as `spans_dropped` | 0 |
//! | `AUTOSAGE_TRACE_FLUSH_MS` | periodic trace flush throttle during serving: sampled spans append to `trace.jsonl` at most once per this many ms (0 = flush only at run end) | 0 |
//! | `AUTOSAGE_MODEL`        | trained cost-model file (`autosage train` output) consulted on cold keys ("" = always probe) | "" |
//! | `AUTOSAGE_MODEL_CONFIDENCE` | minimum calibrated confidence to act on a model prediction without probing; below it the prediction is recorded and the micro-probe runs anyway | 0.8 |
//! | `AUTOSAGE_DEADLINE_MS`  | per-request serving deadline (ms): requests whose queue wait already exceeds it are shed at dequeue with `DeadlineExceeded` (0 = no deadline) | 0 |
//! | `AUTOSAGE_FAULT_RATE`   | deterministic fault-injection rate in [0,1]: each request id draws from `Rng::for_stream(fault_seed, id)`, so the injected set replays bit-identically (0 = off) | 0 |
//! | `AUTOSAGE_FAULT_KINDS`  | comma list of injected fault kinds: `error` \| `panic` \| `latency` | error,panic,latency |
//! | `AUTOSAGE_FAULT_SEED`   | fault-injection RNG seed (independent of the workload seed) | 0 |
//! | `AUTOSAGE_FAULT_LATENCY_MS` | injected latency-spike duration (ms) for `latency` faults | 5 |
//! | `AUTOSAGE_DEGRADE_WATERMARK` | queue-depth fraction of `AUTOSAGE_SERVE_QUEUE` at/above which eligible SpMM requests degrade to the edge-sampled graph instead of running full (0 = degradation off) | 0 |
//! | `AUTOSAGE_DEGRADE_KEEP` | edge-sampling keep fraction per hub row in (0,1] for degraded execution | 0.5 |
//! | `AUTOSAGE_DEGRADE_MIN_DEG` | rows at/below this degree keep all edges when sampling (hub threshold) | 8 |
//! | `AUTOSAGE_IO_FAULT_RATE` | seeded I/O fault-injection rate in [0,1] applied at every durable read/write site: each (site, op-index) pair draws from `Rng::for_stream(io_fault_seed ^ fnv(site), idx)`, so same-seed runs inject the identical fault set (0 = off) | 0 |
//! | `AUTOSAGE_IO_FAULT_KINDS` | comma list of injected I/O fault kinds: `torn_write` \| `short_read` \| `failed_rename` \| `enospc` \| `bit_flip` (empty = all) | "" |
//! | `AUTOSAGE_IO_FAULT_SEED`  | I/O fault-injection RNG seed (independent of workload and chaos seeds) | 0 |
//! | `AUTOSAGE_MODEL_RELOAD_MS` | live model hot-reload poll interval (ms): the serve pool watches `AUTOSAGE_MODEL` for changes, canaries candidates in shadow mode, and promotes/rolls back without a restart (0 = hot-reload off) | 0 |
//! | `AUTOSAGE_MODEL_CANARY_N` | shadow-graded decisions a candidate model must accumulate before the promote/rollback verdict | 8 |
//! | `AUTOSAGE_MODEL_CANARY_AGREE` | minimum agreement fraction (candidate vs incumbent outcome) over the canary window to promote; below it the candidate rolls back | 0.6 |
//! | `AUTOSAGE_LOG_ROTATE_BYTES` | size cap for `audit.jsonl` / `quarantine.jsonl`: at/above it the file rotates to `<name>.1` before the next write (0 = never rotate) | 16777216 |

use crate::util::envcfg::{env_bool, env_f64, env_string, env_usize};

#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Execution backend: "auto" | "native" | "pjrt" (see
    /// `backend::resolve_kind`). Env: `AUTOSAGE_BACKEND`.
    pub backend: String,
    pub alpha: f64,
    pub probe_frac: f64,
    pub probe_min_rows: usize,
    pub probe_iters: usize,
    pub probe_cap_ms: f64,
    /// Graphs with at most this many rows are probed on their full
    /// bucket (guardrail exact on the real input, paper Prop. 1);
    /// larger graphs use the induced-subgraph probe with estimate
    /// scaling. Env: `AUTOSAGE_PROBE_FULL_MAX`.
    pub probe_full_max_rows: usize,
    pub top_k: usize,
    pub hub_t: usize,
    pub allow_vec: bool,
    pub allow_grid_kernels: bool,
    pub cache_path: String,
    pub replay_only: bool,
    pub bench_iters: usize,
    /// Serving pool worker/shard count. Env: `AUTOSAGE_SERVE_WORKERS`.
    pub serve_workers: usize,
    /// Bounded per-shard queue depth; `try_submit` returns `QueueFull`
    /// beyond it (backpressure). Env: `AUTOSAGE_SERVE_QUEUE`.
    pub serve_queue_depth: usize,
    /// Max requests a worker drains into one coalescing batch.
    /// Env: `AUTOSAGE_SERVE_BATCH`.
    pub serve_batch_max: usize,
    /// Batching window in microseconds: after the first request a
    /// worker waits up to this long for coalescable stragglers
    /// (0 = only drain what is already queued). Env:
    /// `AUTOSAGE_SERVE_WINDOW_US`.
    pub serve_batch_window_us: usize,
    /// Serving pool schedule-cache flush throttle (ms): dirty cache
    /// state persists at most once per interval off the request path,
    /// plus unconditionally at pool shutdown. Env:
    /// `AUTOSAGE_CACHE_FLUSH_MS`.
    pub cache_flush_ms: usize,
    /// Trace head-sampling rate in [0, 1]: the fraction of trace ids
    /// the flight recorder keeps during serving. Deterministic under
    /// the run seed. Env: `AUTOSAGE_TRACE_SAMPLE`.
    pub trace_sample: f64,
    /// Flight-recorder ring-buffer capacity in spans (0 = unbounded).
    /// Env: `AUTOSAGE_TRACE_RING`.
    pub trace_ring: usize,
    /// Periodic trace-flush throttle in ms (0 = flush only at run
    /// end). Env: `AUTOSAGE_TRACE_FLUSH_MS`.
    pub trace_flush_ms: usize,
    /// Trained cost-model file consulted on cold keys ("" = no model,
    /// always probe). Env: `AUTOSAGE_MODEL`.
    pub model_path: String,
    /// Minimum calibrated confidence for acting on a model prediction
    /// without probing, in [0, 1]. Below it the prediction is recorded
    /// (for the agreement counters) but the micro-probe still decides.
    /// Env: `AUTOSAGE_MODEL_CONFIDENCE`.
    pub model_confidence: f64,
    /// Per-request serving deadline in ms. A request whose queue wait
    /// already exceeds it is shed at dequeue with a typed
    /// `DeadlineExceeded` reply instead of executing. 0 disables
    /// deadlines. Env: `AUTOSAGE_DEADLINE_MS`.
    pub deadline_ms: f64,
    /// Deterministic fault-injection rate in [0, 1]. Each request id
    /// draws its fault from `Rng::for_stream(fault_seed, id)` — a pure
    /// function of (seed, id), so two runs at the same seed inject the
    /// identical fault set. 0 disables injection. Env:
    /// `AUTOSAGE_FAULT_RATE`.
    pub fault_rate: f64,
    /// Comma-separated injected fault kinds drawn uniformly per faulty
    /// request: "error" (backend failure), "panic" (worker panic,
    /// caught by supervision), "latency" (execute-time spike). Env:
    /// `AUTOSAGE_FAULT_KINDS`.
    pub fault_kinds: String,
    /// Fault-injection RNG seed, independent of the workload seed so
    /// chaos placement can vary while the request mix replays. Env:
    /// `AUTOSAGE_FAULT_SEED`.
    pub fault_seed: usize,
    /// Injected latency-spike duration in ms for `latency` faults.
    /// Env: `AUTOSAGE_FAULT_LATENCY_MS`.
    pub fault_latency_ms: f64,
    /// Graceful-degradation watermark as a fraction of
    /// `serve_queue_depth`: when a shard's queue depth at dequeue is at
    /// or above `watermark * queue_depth`, eligible SpMM requests run
    /// on the edge-sampled graph (with a per-reply error estimate)
    /// instead of the full graph. 0 disables degradation. Env:
    /// `AUTOSAGE_DEGRADE_WATERMARK`.
    pub degrade_watermark: f64,
    /// Edge-sampling keep fraction per hub row in (0, 1] used by
    /// degraded execution. Env: `AUTOSAGE_DEGRADE_KEEP`.
    pub degrade_keep_frac: f64,
    /// Rows at or below this degree keep all their edges when
    /// sampling (only hub rows lose mass). Env:
    /// `AUTOSAGE_DEGRADE_MIN_DEG`.
    pub degrade_min_deg: usize,
    /// Seeded I/O fault-injection rate in [0, 1] applied at every
    /// durable read/write site (schedule cache, model/snapshot files,
    /// JSONL streams, manifests). Pure function of (io_fault_seed,
    /// site, op index) — same-seed runs inject identically. 0 disables
    /// injection. Env: `AUTOSAGE_IO_FAULT_RATE`.
    pub io_fault_rate: f64,
    /// Comma list restricting injected I/O fault kinds (torn_write,
    /// short_read, failed_rename, enospc, bit_flip); empty = all.
    /// Env: `AUTOSAGE_IO_FAULT_KINDS`.
    pub io_fault_kinds: String,
    /// I/O fault-injection RNG seed, independent of the workload seed
    /// and the request-chaos seed. Env: `AUTOSAGE_IO_FAULT_SEED`.
    pub io_fault_seed: usize,
    /// Model hot-reload poll interval in ms: the serve pool watches
    /// `model_path` for a new generation, shadow-grades it, and
    /// promotes or rolls back live. 0 disables hot-reload. Env:
    /// `AUTOSAGE_MODEL_RELOAD_MS`.
    pub model_reload_ms: usize,
    /// Canary window: shadow-graded decisions a candidate model must
    /// accumulate before the promote/rollback verdict. Env:
    /// `AUTOSAGE_MODEL_CANARY_N`.
    pub model_canary_n: usize,
    /// Minimum candidate-vs-incumbent agreement fraction over the
    /// canary window to promote (0.0 promotes unconditionally once the
    /// window fills — deterministic promotion for tests). Env:
    /// `AUTOSAGE_MODEL_CANARY_AGREE`.
    pub model_canary_agree: f64,
    /// Size cap in bytes for the append-style JSONL artifacts
    /// (`audit.jsonl`, `quarantine.jsonl`): at/above it the file is
    /// rotated to `<name>.1` before the next write. 0 = never rotate.
    /// Env: `AUTOSAGE_LOG_ROTATE_BYTES`.
    pub log_rotate_bytes: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            backend: "auto".to_string(),
            alpha: 0.95,
            probe_frac: 0.02,
            probe_min_rows: 512,
            probe_iters: 5,
            probe_cap_ms: 1000.0,
            probe_full_max_rows: 16384,
            top_k: 3,
            hub_t: 0,
            allow_vec: true,
            allow_grid_kernels: false,
            cache_path: "autosage_cache.json".to_string(),
            replay_only: false,
            bench_iters: 12,
            serve_workers: 4,
            serve_queue_depth: 64,
            serve_batch_max: 16,
            serve_batch_window_us: 0,
            cache_flush_ms: 2000,
            trace_sample: 1.0,
            trace_ring: 0,
            trace_flush_ms: 0,
            model_path: String::new(),
            model_confidence: 0.8,
            deadline_ms: 0.0,
            fault_rate: 0.0,
            fault_kinds: "error,panic,latency".to_string(),
            fault_seed: 0,
            fault_latency_ms: 5.0,
            degrade_watermark: 0.0,
            degrade_keep_frac: 0.5,
            degrade_min_deg: 8,
            io_fault_rate: 0.0,
            io_fault_kinds: String::new(),
            io_fault_seed: 0,
            model_reload_ms: 0,
            model_canary_n: 8,
            model_canary_agree: 0.6,
            log_rotate_bytes: 16 * 1024 * 1024,
        }
    }
}

impl Config {
    /// Default config overridden by `AUTOSAGE_*` environment toggles.
    pub fn from_env() -> Result<Config, String> {
        let d = Config::default();
        Ok(Config {
            backend: env_string("AUTOSAGE_BACKEND", &d.backend),
            alpha: env_f64("AUTOSAGE_ALPHA", d.alpha)?,
            probe_frac: env_f64("AUTOSAGE_PROBE_FRAC", d.probe_frac)?,
            probe_min_rows: env_usize("AUTOSAGE_PROBE_MIN", d.probe_min_rows)?,
            probe_iters: env_usize("AUTOSAGE_PROBE_ITERS", d.probe_iters)?,
            probe_cap_ms: env_f64("AUTOSAGE_PROBE_CAP_MS", d.probe_cap_ms)?,
            probe_full_max_rows: env_usize(
                "AUTOSAGE_PROBE_FULL_MAX",
                d.probe_full_max_rows,
            )?,
            top_k: env_usize("AUTOSAGE_TOPK", d.top_k)?,
            hub_t: env_usize("AUTOSAGE_HUB_T", d.hub_t)?,
            allow_vec: env_bool("AUTOSAGE_VEC", d.allow_vec)?,
            allow_grid_kernels: env_bool("AUTOSAGE_GRID", d.allow_grid_kernels)?,
            cache_path: env_string("AUTOSAGE_CACHE", &d.cache_path),
            replay_only: env_bool("AUTOSAGE_REPLAY_ONLY", d.replay_only)?,
            bench_iters: env_usize("AUTOSAGE_BENCH_ITERS", d.bench_iters)?,
            serve_workers: env_usize("AUTOSAGE_SERVE_WORKERS", d.serve_workers)?,
            serve_queue_depth: env_usize("AUTOSAGE_SERVE_QUEUE", d.serve_queue_depth)?,
            serve_batch_max: env_usize("AUTOSAGE_SERVE_BATCH", d.serve_batch_max)?,
            serve_batch_window_us: env_usize(
                "AUTOSAGE_SERVE_WINDOW_US",
                d.serve_batch_window_us,
            )?,
            cache_flush_ms: env_usize("AUTOSAGE_CACHE_FLUSH_MS", d.cache_flush_ms)?,
            trace_sample: env_f64("AUTOSAGE_TRACE_SAMPLE", d.trace_sample)?,
            trace_ring: env_usize("AUTOSAGE_TRACE_RING", d.trace_ring)?,
            trace_flush_ms: env_usize("AUTOSAGE_TRACE_FLUSH_MS", d.trace_flush_ms)?,
            model_path: env_string("AUTOSAGE_MODEL", &d.model_path),
            model_confidence: env_f64("AUTOSAGE_MODEL_CONFIDENCE", d.model_confidence)?,
            deadline_ms: env_f64("AUTOSAGE_DEADLINE_MS", d.deadline_ms)?,
            fault_rate: env_f64("AUTOSAGE_FAULT_RATE", d.fault_rate)?,
            fault_kinds: env_string("AUTOSAGE_FAULT_KINDS", &d.fault_kinds),
            fault_seed: env_usize("AUTOSAGE_FAULT_SEED", d.fault_seed)?,
            fault_latency_ms: env_f64("AUTOSAGE_FAULT_LATENCY_MS", d.fault_latency_ms)?,
            degrade_watermark: env_f64("AUTOSAGE_DEGRADE_WATERMARK", d.degrade_watermark)?,
            degrade_keep_frac: env_f64("AUTOSAGE_DEGRADE_KEEP", d.degrade_keep_frac)?,
            degrade_min_deg: env_usize("AUTOSAGE_DEGRADE_MIN_DEG", d.degrade_min_deg)?,
            io_fault_rate: env_f64("AUTOSAGE_IO_FAULT_RATE", d.io_fault_rate)?,
            io_fault_kinds: env_string("AUTOSAGE_IO_FAULT_KINDS", &d.io_fault_kinds),
            io_fault_seed: env_usize("AUTOSAGE_IO_FAULT_SEED", d.io_fault_seed)?,
            model_reload_ms: env_usize("AUTOSAGE_MODEL_RELOAD_MS", d.model_reload_ms)?,
            model_canary_n: env_usize("AUTOSAGE_MODEL_CANARY_N", d.model_canary_n)?,
            model_canary_agree: env_f64(
                "AUTOSAGE_MODEL_CANARY_AGREE",
                d.model_canary_agree,
            )?,
            log_rotate_bytes: env_usize("AUTOSAGE_LOG_ROTATE_BYTES", d.log_rotate_bytes)?,
        })
    }

    /// Validate invariants the scheduler relies on.
    pub fn validate(&self) -> Result<(), String> {
        if !matches!(self.backend.as_str(), "auto" | "native" | "pjrt" | "") {
            return Err(format!(
                "unknown AUTOSAGE_BACKEND {:?} (valid: auto, native, pjrt)",
                self.backend
            ));
        }
        if !(0.0 < self.alpha && self.alpha <= 1.0) {
            return Err(format!(
                "alpha must be in (0, 1] for the non-regression guarantee \
                 (Prop. 1); got {}",
                self.alpha
            ));
        }
        if !(0.0 < self.probe_frac && self.probe_frac <= 1.0) {
            return Err(format!("probe_frac out of (0,1]: {}", self.probe_frac));
        }
        if self.probe_iters == 0 || self.bench_iters == 0 {
            return Err("iteration counts must be positive".into());
        }
        if self.top_k == 0 {
            return Err("top_k must be >= 1".into());
        }
        if self.serve_workers == 0 {
            return Err("serve_workers must be >= 1".into());
        }
        if self.serve_queue_depth == 0 || self.serve_batch_max == 0 {
            return Err("serve queue depth and batch size must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.trace_sample) {
            return Err(format!(
                "AUTOSAGE_TRACE_SAMPLE must be in [0, 1]; got {}",
                self.trace_sample
            ));
        }
        if !(0.0..=1.0).contains(&self.model_confidence) {
            return Err(format!(
                "AUTOSAGE_MODEL_CONFIDENCE must be in [0, 1]; got {}",
                self.model_confidence
            ));
        }
        if !self.deadline_ms.is_finite() || self.deadline_ms < 0.0 {
            return Err(format!(
                "AUTOSAGE_DEADLINE_MS must be >= 0; got {}",
                self.deadline_ms
            ));
        }
        if !(0.0..=1.0).contains(&self.fault_rate) {
            return Err(format!(
                "AUTOSAGE_FAULT_RATE must be in [0, 1]; got {}",
                self.fault_rate
            ));
        }
        if !self.fault_latency_ms.is_finite() || self.fault_latency_ms < 0.0 {
            return Err(format!(
                "AUTOSAGE_FAULT_LATENCY_MS must be >= 0; got {}",
                self.fault_latency_ms
            ));
        }
        for kind in self.fault_kinds.split(',') {
            let kind = kind.trim();
            if !kind.is_empty() && !matches!(kind, "error" | "panic" | "latency") {
                return Err(format!(
                    "unknown AUTOSAGE_FAULT_KINDS entry {kind:?} \
                     (valid: error, panic, latency)"
                ));
            }
        }
        if !(0.0..=1.0).contains(&self.degrade_watermark) {
            return Err(format!(
                "AUTOSAGE_DEGRADE_WATERMARK must be in [0, 1]; got {}",
                self.degrade_watermark
            ));
        }
        if !(0.0 < self.degrade_keep_frac && self.degrade_keep_frac <= 1.0) {
            return Err(format!(
                "AUTOSAGE_DEGRADE_KEEP must be in (0, 1]; got {}",
                self.degrade_keep_frac
            ));
        }
        if self.degrade_min_deg == 0 {
            return Err("AUTOSAGE_DEGRADE_MIN_DEG must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.io_fault_rate) {
            return Err(format!(
                "AUTOSAGE_IO_FAULT_RATE must be in [0, 1]; got {}",
                self.io_fault_rate
            ));
        }
        crate::util::iofault::parse_io_kinds(&self.io_fault_kinds)?;
        if self.model_canary_n == 0 {
            return Err("AUTOSAGE_MODEL_CANARY_N must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.model_canary_agree) {
            return Err(format!(
                "AUTOSAGE_MODEL_CANARY_AGREE must be in [0, 1]; got {}",
                self.model_canary_agree
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_papers() {
        let c = Config::default();
        assert_eq!(c.alpha, 0.95);
        assert_eq!(c.probe_min_rows, 512);
        assert_eq!(c.probe_frac, 0.02);
        assert_eq!(c.backend, "auto");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_unknown_backend() {
        let mut c = Config::default();
        c.backend = "cuda".to_string();
        assert!(c.validate().is_err());
        for ok in ["auto", "native", "pjrt"] {
            c.backend = ok.to_string();
            assert!(c.validate().is_ok(), "{ok}");
        }
    }

    #[test]
    fn validate_rejects_bad_alpha() {
        let mut c = Config::default();
        c.alpha = 1.5; // would break Proposition 1
        assert!(c.validate().is_err());
        c.alpha = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_iters() {
        let mut c = Config::default();
        c.probe_iters = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_serving_params() {
        let mut c = Config::default();
        c.serve_workers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.serve_queue_depth = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.serve_batch_max = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn serve_defaults_are_concurrent_and_bounded() {
        let c = Config::default();
        assert!(c.serve_workers >= 1);
        assert!(c.serve_queue_depth >= 1);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_out_of_range_trace_sample() {
        let mut c = Config::default();
        c.trace_sample = 1.5;
        assert!(c.validate().is_err());
        c.trace_sample = -0.1;
        assert!(c.validate().is_err());
        for ok in [0.0, 0.1, 1.0] {
            c.trace_sample = ok;
            assert!(c.validate().is_ok(), "{ok}");
        }
    }

    #[test]
    fn trace_defaults_keep_everything_and_never_drop() {
        let c = Config::default();
        assert_eq!(c.trace_sample, 1.0);
        assert_eq!(c.trace_ring, 0);
        assert_eq!(c.trace_flush_ms, 0);
    }

    #[test]
    fn validate_rejects_out_of_range_model_confidence() {
        let mut c = Config::default();
        assert_eq!(c.model_path, "");
        assert_eq!(c.model_confidence, 0.8);
        c.model_confidence = 1.1;
        assert!(c.validate().is_err());
        c.model_confidence = -0.01;
        assert!(c.validate().is_err());
        for ok in [0.0, 0.5, 1.0] {
            c.model_confidence = ok;
            assert!(c.validate().is_ok(), "{ok}");
        }
    }

    #[test]
    fn resilience_defaults_are_off() {
        let c = Config::default();
        assert_eq!(c.deadline_ms, 0.0);
        assert_eq!(c.fault_rate, 0.0);
        assert_eq!(c.fault_kinds, "error,panic,latency");
        assert_eq!(c.degrade_watermark, 0.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_resilience_knobs() {
        let mut c = Config::default();
        c.fault_rate = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.fault_kinds = "error,segfault".to_string();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.deadline_ms = -1.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.degrade_watermark = 2.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.degrade_keep_frac = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.degrade_min_deg = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.fault_rate = 0.05;
        c.fault_kinds = "panic".to_string();
        c.deadline_ms = 10.0;
        c.degrade_watermark = 0.75;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn durability_defaults_are_off_and_validated() {
        let c = Config::default();
        assert_eq!(c.io_fault_rate, 0.0);
        assert_eq!(c.io_fault_kinds, "");
        assert_eq!(c.model_reload_ms, 0);
        assert_eq!(c.model_canary_n, 8);
        assert_eq!(c.model_canary_agree, 0.6);
        assert_eq!(c.log_rotate_bytes, 16 * 1024 * 1024);
        assert!(c.validate().is_ok());
        let mut c = Config::default();
        c.io_fault_rate = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.io_fault_kinds = "torn_write,oom".to_string();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.model_canary_n = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.model_canary_agree = 1.01;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.io_fault_rate = 0.05;
        c.io_fault_kinds = "bit_flip, enospc".to_string();
        c.model_reload_ms = 50;
        c.model_canary_agree = 0.0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn env_overrides() {
        std::env::set_var("AUTOSAGE_ALPHA", "0.98");
        std::env::set_var("AUTOSAGE_TOPK", "5");
        let c = Config::from_env().unwrap();
        assert_eq!(c.alpha, 0.98);
        assert_eq!(c.top_k, 5);
        std::env::remove_var("AUTOSAGE_ALPHA");
        std::env::remove_var("AUTOSAGE_TOPK");
    }
}
