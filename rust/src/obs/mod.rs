//! Flight recorder: observability for scheduling decisions and runs.
//!
//! Three layers, all dependency-free:
//! * [`trace`] — structured spans (trace id, span id, parent link,
//!   microsecond offsets from a per-run epoch) recorded in memory and
//!   flushed as JSONL keyed by `run_id`. Threaded through the serve
//!   pool (queue wait → coalesce → execute → reply) and the scheduler
//!   (estimate → probe → guardrail, cache hit/miss).
//! * [`manifest`] — versioned run manifests: every `bench` /
//!   `serve-bench` run with `--out` emits `manifest.json` capturing the
//!   run id, seed, env toggles, device signature, graph checksums,
//!   per-artifact sha256 and a self-hash over the canonical JSON form.
//!   `autosage manifest validate` re-checks all of it.
//! * [`perf`] — perf profiles (`perf.json`) and the noise-aware
//!   regression gate behind `autosage perf compare`, anchored by the
//!   checked-in `benchmarks/BENCH_*.json` trajectory.

pub mod manifest;
pub mod perf;
pub mod trace;

pub use manifest::{RunManifest, ValidationReport, MANIFEST_SCHEMA_VERSION};
pub use perf::{compare, CompareReport, Direction, PerfProfile, Verdict};
pub use trace::{new_run_id, Recorder, SpanRecord, TraceCtx, TraceId};
