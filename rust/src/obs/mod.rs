//! Flight recorder: observability for scheduling decisions and runs.
//!
//! Five layers, all dependency-free:
//! * [`trace`] — structured spans (trace id, span id, parent link,
//!   microsecond offsets from a per-run epoch) recorded in memory and
//!   flushed as JSONL keyed by `run_id`. Threaded through the serve
//!   pool (queue wait → coalesce → execute → reply) and the scheduler
//!   (estimate → probe → guardrail, cache hit/miss). Production mode:
//!   head-based trace sampling (`AUTOSAGE_TRACE_SAMPLE`), ring-buffer
//!   bounding with drop counters, and throttled incremental flush.
//! * [`metrics`] — the unified metrics registry: named counters /
//!   gauges / histograms per subsystem, merged-histogram pool
//!   percentiles, Prometheus-style text exposition (`metrics.prom`,
//!   `autosage metrics`), and the estimate-accuracy audit log
//!   (`audit.jsonl`).
//! * [`report`] — `autosage obs report`: aggregates trace + audit +
//!   metrics artifacts into a stage-latency breakdown and a
//!   per-variant roofline-calibration table.
//! * [`manifest`] — versioned run manifests: every `bench` /
//!   `serve-bench` run with `--out` emits `manifest.json` capturing the
//!   run id, seed, env toggles, device signature, graph checksums,
//!   per-artifact sha256 and a self-hash over the canonical JSON form.
//!   `autosage manifest validate` re-checks all of it.
//! * [`perf`] — perf profiles (`perf.json`) and the noise-aware
//!   regression gate behind `autosage perf compare`, anchored by the
//!   checked-in `benchmarks/BENCH_*.json` trajectory.

pub mod manifest;
pub mod metrics;
pub mod perf;
pub mod report;
pub mod trace;

pub use manifest::{RunManifest, ValidationReport, MANIFEST_SCHEMA_VERSION};
pub use metrics::{AuditSample, LatencyHistogram, MetricsRegistry};
pub use perf::{compare, CompareReport, Direction, PerfProfile, Verdict};
pub use trace::{new_run_id, Recorder, SpanRecord, TraceCtx, TraceId};
