//! Unified metrics registry: named counters / gauges / histograms with
//! a Prometheus-style text exposition, plus the estimate-accuracy audit
//! log — all zero-dep and lock-cheap (hot-path increments are relaxed
//! atomics behind `Arc` handles; the registry maps are only locked to
//! register or snapshot).
//!
//! Naming convention: `autosage_<subsystem>_<what>[_total]` with
//! optional inline Prometheus labels, e.g.
//! `autosage_scheduler_decisions_total{source="probe"}`. The full
//! string (labels included) is the registry key; exposition groups
//! label variants under one `# TYPE` line per family.
//!
//! Pool-wide latency percentiles MUST come from merging per-shard
//! [`LatencyHistogram`]s bucket-wise ([`LatencyHistogram::merge_from`])
//! — never from averaging per-shard quantiles, which has no statistical
//! meaning (a shard with 3 slow requests would weigh as much as one
//! with 30 000 fast ones).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Histogram bucket count: 40 log2 buckets cover 1 µs .. ~9 minutes.
pub const N_BUCKETS: usize = 40;

/// Log2-bucketed latency histogram. Bucket `b` counts samples in
/// `[2^b, 2^(b+1))` microseconds; quantiles report the geometric
/// midpoint of the bucket holding the q-th sample (≤ ~50% relative
/// error, which is plenty for p50/p95/p99 monitoring without locks).
/// Also keeps a running sum so exposition can report summary `_sum`.
pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(ms: f64) -> usize {
        let us = (ms * 1000.0).max(1.0) as u64;
        ((63 - us.leading_zeros()) as usize).min(N_BUCKETS - 1)
    }

    pub fn record_ms(&self, ms: f64) {
        self.buckets[Self::bucket_of(ms)].fetch_add(1, Ordering::Relaxed);
        self.sum_us
            .fetch_add((ms.max(0.0) * 1000.0) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of recorded values in milliseconds (µs-truncated).
    pub fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Latency quantile estimate in milliseconds (0.0 when empty).
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            cum += bucket.load(Ordering::Relaxed);
            if cum >= rank {
                return (1u64 << b) as f64 * 1.5 / 1000.0;
            }
        }
        (1u64 << (N_BUCKETS - 1)) as f64 * 1.5 / 1000.0
    }

    /// Bucket-wise accumulate `other` into `self`. This is the ONLY
    /// correct way to derive pool-level quantiles from per-shard
    /// histograms: the merged distribution weighs every sample equally,
    /// where averaging per-shard quantiles would weigh shards equally
    /// regardless of how many samples each saw.
    pub fn merge_from(&self, other: &LatencyHistogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Overwrite `self` with `other`'s contents (bucket-wise store).
    /// Used to mirror a live histogram into a registry snapshot
    /// idempotently — repeated mirrors must not accumulate.
    pub fn store_from(&self, other: &LatencyHistogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum_us
            .store(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Merge an iterator of histograms into one fresh histogram.
    pub fn merged<'a>(parts: impl IntoIterator<Item = &'a LatencyHistogram>) -> LatencyHistogram {
        let out = LatencyHistogram::new();
        for h in parts {
            out.merge_from(h);
        }
        out
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One estimate-vs-measured observation for the calibration audit:
/// what the roofline model predicted for the chosen variant vs what the
/// backend actually took, keyed by op, variant, and a coarse
/// `InputFeatures` bucket (log2 rows / log2 nnz / F).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditSample {
    pub op: String,
    pub variant: String,
    pub bucket: String,
    pub predicted_ms: f64,
    pub measured_ms: f64,
    /// How this variant fared in the decision that produced the sample:
    /// `"executed"` (served request — the original per-request stream),
    /// `"chosen"` (probe winner), `"rejected"` (probed but lost),
    /// `"baseline"` (vendor-path reference timing when a candidate won),
    /// or `"fallback"` (guardrail rejected every candidate and the
    /// baseline won defensively). The non-"executed" outcomes carry the
    /// negative labels the trained cost model learns from.
    pub outcome: String,
    /// Full `InputFeatures::to_vec()` vector of the scheduling input.
    /// Probe-path samples carry it so `autosage train` can mine labeled
    /// examples straight from `audit.jsonl`; per-request "executed"
    /// samples omit it (the coarse `bucket` suffices for calibration).
    pub features: Option<Vec<f64>>,
}

impl AuditSample {
    /// An "executed" sample — the per-request calibration stream.
    pub fn executed(
        op: impl Into<String>,
        variant: impl Into<String>,
        bucket: impl Into<String>,
        predicted_ms: f64,
        measured_ms: f64,
    ) -> AuditSample {
        AuditSample {
            op: op.into(),
            variant: variant.into(),
            bucket: bucket.into(),
            predicted_ms,
            measured_ms,
            outcome: "executed".to_string(),
            features: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("op", Json::str(&self.op)),
            ("variant", Json::str(&self.variant)),
            ("bucket", Json::str(&self.bucket)),
            ("predicted_ms", Json::num(self.predicted_ms)),
            ("measured_ms", Json::num(self.measured_ms)),
            ("outcome", Json::str(&self.outcome)),
        ];
        if let Some(fv) = &self.features {
            let arr = fv.iter().map(|&v| Json::num(v)).collect();
            pairs.push(("features", Json::Arr(arr)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Option<AuditSample> {
        Some(AuditSample {
            op: j.get("op").as_str()?.to_string(),
            variant: j.get("variant").as_str()?.to_string(),
            bucket: j.get("bucket").as_str()?.to_string(),
            predicted_ms: j.get("predicted_ms").as_f64()?,
            measured_ms: j.get("measured_ms").as_f64()?,
            // Audit files written before outcomes existed read back as
            // the per-request stream they were.
            outcome: j.get("outcome").as_str().unwrap_or("executed").to_string(),
            features: j
                .get("features")
                .as_arr()
                .map(|arr| arr.iter().filter_map(|v| v.as_f64()).collect()),
        })
    }
}

/// Coarse feature bucket used as the audit key: log2(rows), log2(nnz),
/// and the dense feature width. Stable, low-cardinality, and derivable
/// from any graph without a full `InputFeatures::extract`.
pub fn feature_bucket(n_rows: usize, nnz: usize, f: usize) -> String {
    fn log2_floor(x: usize) -> u32 {
        63 - (x.max(1) as u64).leading_zeros()
    }
    format!("r2^{}|z2^{}|F{}", log2_floor(n_rows), log2_floor(nnz), f)
}

/// Cap on buffered audit samples; beyond it new samples are dropped and
/// counted (`autosage_audit_dropped_total`) — the audit loop must never
/// become an unbounded memory leak in a long serve run.
const AUDIT_CAP: usize = 65_536;

/// Process-wide metrics registry. Cheap to share (`Arc`), cheap to
/// update (handles are `Arc<AtomicU64>` / `Arc<LatencyHistogram>`), and
/// snapshot-rendered into Prometheus text exposition on demand.
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    /// Gauges store `f64::to_bits` so one atomic word carries floats.
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<LatencyHistogram>>>,
    audit: Mutex<Vec<AuditSample>>,
    audit_dropped: AtomicU64,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
            audit: Mutex::new(Vec::new()),
            audit_dropped: AtomicU64::new(0),
        }
    }

    fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        m.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register-or-get a counter handle. Callers on hot paths should
    /// cache the returned `Arc` instead of re-resolving by name.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        Self::lock(&self.counters)
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Increment a counter by 1.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a counter by `v`.
    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    /// Overwrite a counter with an externally-maintained total (used to
    /// mirror counters owned by other subsystems into the exposition).
    pub fn set_counter(&self, name: &str, v: u64) {
        self.counter(name).store(v, Ordering::Relaxed);
    }

    /// Set a gauge to a float value.
    pub fn set_gauge(&self, name: &str, v: f64) {
        Self::lock(&self.gauges)
            .entry(name.to_string())
            .or_default()
            .store(v.to_bits(), Ordering::Relaxed);
    }

    /// Register-or-get a histogram handle.
    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        Self::lock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(LatencyHistogram::new()))
            .clone()
    }

    /// Record one estimate-vs-measured audit observation. Bounded: past
    /// [`AUDIT_CAP`] samples are dropped and counted.
    pub fn record_audit(&self, s: AuditSample) {
        let mut buf = Self::lock(&self.audit);
        if buf.len() >= AUDIT_CAP {
            drop(buf);
            self.audit_dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        buf.push(s);
    }

    pub fn audit_snapshot(&self) -> Vec<AuditSample> {
        Self::lock(&self.audit).clone()
    }

    pub fn audit_dropped(&self) -> u64 {
        self.audit_dropped.load(Ordering::Relaxed)
    }

    /// Write the buffered audit samples as JSONL (one object per line).
    pub fn write_audit_jsonl(&self, path: &Path) -> Result<usize> {
        self.write_audit_jsonl_capped(path, 0)
    }

    /// [`write_audit_jsonl`] with size-capped rotation: when the file
    /// on disk already holds `cap_bytes` or more, it is rotated to
    /// `<path>.1` first (`cap_bytes == 0` disables rotation). The write
    /// goes through the fault-injectable wrapper.
    pub fn write_audit_jsonl_capped(&self, path: &Path, cap_bytes: u64) -> Result<usize> {
        let samples = self.audit_snapshot();
        let mut out = String::new();
        for s in &samples {
            out.push_str(&s.to_json().to_string());
            out.push('\n');
        }
        crate::util::iofault::rotate_if_large(path, cap_bytes)
            .with_context(|| format!("rotating audit JSONL {}", path.display()))?;
        crate::util::iofault::write_file("obs.audit.write", path, out.as_bytes())
            .with_context(|| format!("writing audit JSONL {}", path.display()))?;
        Ok(samples.len())
    }

    /// Prometheus text exposition of everything registered, sorted by
    /// name (label variants of one family share a `# TYPE` line).
    /// Histograms render as summaries: `{quantile=...}` + `_count` +
    /// `_sum` (sum in milliseconds, like the quantiles).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        let mut type_line = |out: &mut String, name: &str, kind: &str| {
            let family = family_of(name).to_string();
            if family != last_family {
                out.push_str(&format!("# TYPE {family} {kind}\n"));
                last_family = family;
            }
        };
        for (name, v) in Self::lock(&self.counters).iter() {
            type_line(&mut out, name, "counter");
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        let dropped = self.audit_dropped.load(Ordering::Relaxed);
        if dropped > 0 {
            out.push_str("# TYPE autosage_audit_dropped_total counter\n");
            out.push_str(&format!("autosage_audit_dropped_total {dropped}\n"));
        }
        for (name, v) in Self::lock(&self.gauges).iter() {
            type_line(&mut out, name, "gauge");
            out.push_str(&format!(
                "{name} {}\n",
                fmt_f64(f64::from_bits(v.load(Ordering::Relaxed)))
            ));
        }
        for (name, h) in Self::lock(&self.histograms).iter() {
            type_line(&mut out, name, "summary");
            for q in [0.5, 0.95, 0.99] {
                out.push_str(&format!(
                    "{name}{{quantile=\"{q}\"}} {}\n",
                    fmt_f64(h.quantile_ms(q))
                ));
            }
            out.push_str(&format!("{name}_count {}\n", h.count()));
            out.push_str(&format!("{name}_sum {}\n", fmt_f64(h.sum_ms())));
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Family name = series name with any `{labels}` suffix stripped.
fn family_of(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// One parsed exposition snapshot: series name (labels included) → value.
pub type PromSnapshot = BTreeMap<String, f64>;

/// Parse Prometheus text exposition. Rejects lines that are neither
/// comments nor `name[{labels}] value` pairs, duplicate series, and
/// non-numeric values — enough validation for `autosage metrics
/// validate` to catch a corrupted or truncated snapshot.
pub fn parse_prometheus(text: &str) -> Result<PromSnapshot> {
    let mut out = PromSnapshot::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // Labels may contain spaces inside quotes; the value is the
        // last whitespace-separated token after the name/labels part.
        let split_at = match line.find('{') {
            Some(b) => {
                let close = line[b..]
                    .find('}')
                    .map(|c| b + c + 1)
                    .with_context(|| format!("line {}: unterminated labels", i + 1))?;
                close
            }
            None => line
                .find(char::is_whitespace)
                .with_context(|| format!("line {}: missing value", i + 1))?,
        };
        let (name, rest) = line.split_at(split_at);
        let name = name.trim();
        let value: f64 = rest
            .trim()
            .parse()
            .with_context(|| format!("line {}: bad value {:?}", i + 1, rest.trim()))?;
        if name.is_empty() || !family_of(name).chars().all(|c| c.is_alphanumeric() || c == '_') {
            bail!("line {}: bad series name {:?}", i + 1, name);
        }
        if out.insert(name.to_string(), value).is_some() {
            bail!("line {}: duplicate series {:?}", i + 1, name);
        }
    }
    Ok(out)
}

/// Series every serving snapshot must carry: the drop/overflow counters
/// (satellite requirement), the merged-histogram pool percentiles, the
/// learned-scheduler prediction counters (zero when no model is
/// loaded — a missing series means a miswired registry, not "no model"),
/// and the resilience counters (zero when fault injection / deadlines /
/// degradation are off, for the same reason).
pub const REQUIRED_SERVING_SERIES: &[&str] = &[
    "autosage_traces_sampled_out_total",
    "autosage_spans_dropped_total",
    "autosage_pool_latency_ms{quantile=\"0.5\"}",
    "autosage_pool_latency_ms{quantile=\"0.95\"}",
    "autosage_pool_latency_ms{quantile=\"0.99\"}",
    "autosage_pool_requests_total",
    "autosage_model_predictions_total",
    "autosage_model_low_confidence_probes_total",
    "autosage_model_agree_total",
    "autosage_model_disagree_total",
    "autosage_faults_injected_total",
    "autosage_requests_quarantined_total",
    "autosage_pool_shed_total",
    "autosage_pool_degraded_total",
    "autosage_worker_panics_total",
    "autosage_io_faults_injected_total",
    "autosage_io_write_retries_total",
    "autosage_salvage_total",
    "autosage_log_rotations_total",
    "autosage_model_reloads_total",
    "autosage_model_rollbacks_total",
];

/// Validate a serving `metrics.prom` snapshot: well-formed exposition
/// text that carries every [`REQUIRED_SERVING_SERIES`]. Returns the
/// parsed snapshot for further inspection.
pub fn validate_serving_snapshot(text: &str) -> Result<PromSnapshot> {
    let snap = parse_prometheus(text)?;
    for required in REQUIRED_SERVING_SERIES {
        if !snap.contains_key(*required) {
            bail!("metrics snapshot is missing required series {required}");
        }
    }
    Ok(snap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_merge_is_bucket_wise_not_quantile_average() {
        // Skewed shards: shard A has 900 fast samples, shard B has 10
        // slow ones. The merged p50 must stay fast (the pool really did
        // serve mostly-fast requests); max / average of per-shard p50s
        // would both report a slow pool.
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        for _ in 0..900 {
            a.record_ms(1.0);
        }
        for _ in 0..10 {
            b.record_ms(100.0);
        }
        let merged = LatencyHistogram::merged([&a, &b]);
        assert_eq!(merged.count(), 910);
        let p50 = merged.quantile_ms(0.5);
        let p99 = merged.quantile_ms(0.99);
        assert!(p50 < 2.0, "merged p50 {p50} must stay near 1ms");
        assert!(p99 > 50.0, "merged p99 {p99} must see the slow tail");
        let avg_p50 = (a.quantile_ms(0.5) + b.quantile_ms(0.5)) / 2.0;
        let max_p50 = a.quantile_ms(0.5).max(b.quantile_ms(0.5));
        assert!(p50 < avg_p50, "merged {p50} vs avg {avg_p50}");
        assert!(p50 < max_p50, "merged {p50} vs max {max_p50}");
    }

    #[test]
    fn histogram_sum_accumulates_and_merges() {
        let a = LatencyHistogram::new();
        a.record_ms(2.0);
        a.record_ms(3.0);
        assert!((a.sum_ms() - 5.0).abs() < 0.01);
        let b = LatencyHistogram::new();
        b.record_ms(1.0);
        b.merge_from(&a);
        assert_eq!(b.count(), 3);
        assert!((b.sum_ms() - 6.0).abs() < 0.01);
    }

    #[test]
    fn registry_counters_gauges_histograms_render() {
        let reg = MetricsRegistry::new();
        reg.inc("autosage_test_total{kind=\"a\"}");
        reg.add("autosage_test_total{kind=\"b\"}", 4);
        reg.set_gauge("autosage_depth", 2.5);
        reg.histogram("autosage_lat_ms").record_ms(1.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE autosage_test_total counter\n"));
        assert!(text.contains("autosage_test_total{kind=\"a\"} 1\n"));
        assert!(text.contains("autosage_test_total{kind=\"b\"} 4\n"));
        // One TYPE line for the whole family, not one per label variant.
        assert_eq!(text.matches("# TYPE autosage_test_total").count(), 1);
        assert!(text.contains("autosage_depth 2.5\n"));
        assert!(text.contains("autosage_lat_ms{quantile=\"0.5\"}"));
        assert!(text.contains("autosage_lat_ms_count 1\n"));
        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(parsed["autosage_test_total{kind=\"b\"}"], 4.0);
        assert_eq!(parsed["autosage_depth"], 2.5);
    }

    #[test]
    fn parse_rejects_malformed_snapshots() {
        assert!(parse_prometheus("just words no value").is_err());
        assert!(parse_prometheus("name notanumber").is_err());
        assert!(parse_prometheus("a 1\na 2").is_err(), "duplicate series");
        assert!(parse_prometheus("bad-name 1").is_err());
        assert!(parse_prometheus("open{label=\"x 1").is_err());
        let ok = parse_prometheus("# comment\n\nx_total 3\ny{q=\"0.5\"} 1.25\n").unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok["y{q=\"0.5\"}"], 1.25);
    }

    #[test]
    fn serving_snapshot_validation_requires_drop_counters_and_pool_quantiles() {
        let reg = MetricsRegistry::new();
        reg.set_counter("autosage_traces_sampled_out_total", 3);
        reg.set_counter("autosage_spans_dropped_total", 0);
        reg.set_counter("autosage_pool_requests_total", 16);
        let text = reg.render_prometheus();
        assert!(
            validate_serving_snapshot(&text).is_err(),
            "must fail without pool latency quantiles"
        );
        reg.histogram("autosage_pool_latency_ms").record_ms(1.0);
        assert!(
            validate_serving_snapshot(&reg.render_prometheus()).is_err(),
            "must fail without model prediction counters"
        );
        reg.set_counter("autosage_model_predictions_total", 0);
        reg.set_counter("autosage_model_low_confidence_probes_total", 0);
        reg.set_counter("autosage_model_agree_total", 0);
        reg.set_counter("autosage_model_disagree_total", 0);
        assert!(
            validate_serving_snapshot(&reg.render_prometheus()).is_err(),
            "must fail without resilience counters"
        );
        reg.set_counter("autosage_faults_injected_total", 0);
        reg.set_counter("autosage_requests_quarantined_total", 0);
        reg.set_counter("autosage_pool_shed_total", 0);
        reg.set_counter("autosage_pool_degraded_total", 0);
        reg.set_counter("autosage_worker_panics_total", 0);
        assert!(
            validate_serving_snapshot(&reg.render_prometheus()).is_err(),
            "must fail without durability counters"
        );
        reg.set_counter("autosage_io_faults_injected_total", 0);
        reg.set_counter("autosage_io_write_retries_total", 0);
        reg.set_counter("autosage_salvage_total", 0);
        reg.set_counter("autosage_log_rotations_total", 0);
        reg.set_counter("autosage_model_reloads_total", 0);
        reg.set_counter("autosage_model_rollbacks_total", 0);
        let snap = validate_serving_snapshot(&reg.render_prometheus()).unwrap();
        assert_eq!(snap["autosage_traces_sampled_out_total"], 3.0);
        assert_eq!(snap["autosage_model_predictions_total"], 0.0);
    }

    #[test]
    fn audit_log_is_bounded_and_round_trips_json() {
        let reg = MetricsRegistry::new();
        let s = AuditSample::executed("spmm", "ell_tile", feature_bucket(1000, 8000, 64), 1.5, 2.0);
        reg.record_audit(s.clone());
        let snap = reg.audit_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].bucket, "r2^9|z2^12|F64");
        let back = AuditSample::from_json(&Json::parse(&s.to_json().to_string()).unwrap());
        assert_eq!(back, Some(s));
        assert_eq!(reg.audit_dropped(), 0);
    }

    #[test]
    fn audit_outcome_and_features_round_trip_and_default() {
        let mut s =
            AuditSample::executed("spmm", "hub_split", feature_bucket(512, 2048, 128), 0.5, 0.6);
        s.outcome = "rejected".into();
        s.features = Some(vec![512.0, 2048.0, 128.0, 4.0]);
        let text = s.to_json().to_string();
        assert!(text.contains("\"outcome\":\"rejected\""));
        assert!(text.contains("\"features\":[512,2048,128,4]"));
        let back = AuditSample::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        // Pre-outcome audit lines (PR 5 format) still parse, as the
        // per-request stream they were.
        let legacy = r#"{"op":"spmm","variant":"v","bucket":"b","predicted_ms":1,"measured_ms":2}"#;
        let back = AuditSample::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(back.outcome, "executed");
        assert_eq!(back.features, None);
    }

    #[test]
    fn feature_bucket_boundaries_are_exact_powers_of_two() {
        // log2 floor: the bucket edge sits exactly ON the power of two —
        // 1023 rows is still r2^9, 1024 flips to r2^10.
        assert_eq!(feature_bucket(1023, 1, 8), "r2^9|z2^0|F8");
        assert_eq!(feature_bucket(1024, 1, 8), "r2^10|z2^0|F8");
        assert_eq!(feature_bucket(1025, 1, 8), "r2^10|z2^0|F8");
        assert_eq!(feature_bucket(1, 4095, 8), "r2^0|z2^11|F8");
        assert_eq!(feature_bucket(1, 4096, 8), "r2^0|z2^12|F8");
        // F is carried verbatim, not bucketed.
        assert_eq!(feature_bucket(2, 2, 127), "r2^1|z2^1|F127");
    }

    #[test]
    fn feature_bucket_is_log2_coarse() {
        assert_eq!(feature_bucket(1, 1, 8), "r2^0|z2^0|F8");
        assert_eq!(feature_bucket(1024, 1_000_000, 64), "r2^10|z2^19|F64");
        assert_eq!(feature_bucket(0, 0, 1), "r2^0|z2^0|F1");
    }
}
