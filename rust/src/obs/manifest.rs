//! Versioned run manifests with artifact checksums and a self-hash.
//!
//! Every `autosage bench` / `serve-bench` run with `--out` writes a
//! `manifest.json` next to its artifacts capturing provenance: run id,
//! kind, seed, device signature, the env-toggle snapshot (the same
//! object as the `.meta.json` sidecars), graph checksums, per-artifact
//! sha256 + byte counts, and summary metrics. The manifest carries a
//! `manifest_sha256` self-hash computed over its *canonical* JSON form —
//! compact serialization with keys sorted (which the [`Json`] type
//! guarantees via `BTreeMap`) and the self-hash field removed — so any
//! edit to the manifest, however the keys are ordered on disk, is
//! detectable. `autosage manifest validate` re-checks the self-hash and
//! re-hashes every listed artifact.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::sha256::{sha256_file, sha256_hex};
use anyhow::{anyhow, bail, Context, Result};

/// Manifest schema version (semver). Validators accept any 1.x.y.
pub const MANIFEST_SCHEMA_VERSION: &str = "1.0.0";

/// A graph that participated in the run, identified by its spec string
/// (`"preset"` | `"file:PATH"`) and structural signature.
#[derive(Debug, Clone)]
pub struct GraphRef {
    pub spec: String,
    pub signature: String,
    pub rows: usize,
    pub nnz: usize,
}

/// One artifact file written by the run, hashed at manifest-build time.
#[derive(Debug, Clone)]
pub struct ArtifactRef {
    /// Path relative to the manifest's directory.
    pub path: String,
    pub sha256: String,
    pub bytes: u64,
}

/// Builder + serializer for one run's manifest.
#[derive(Debug, Clone)]
pub struct RunManifest {
    pub run_id: String,
    /// Run kind: `"bench"` or `"serve-bench"`.
    pub kind: String,
    pub timestamp_unix_s: u64,
    pub seed: u64,
    pub device_sig: String,
    /// Env-toggle / config snapshot (same shape as the `.meta.json`
    /// sidecars from [`crate::telemetry::meta_sidecar`]).
    pub meta: Json,
    pub graphs: Vec<GraphRef>,
    pub artifacts: Vec<ArtifactRef>,
    pub metrics: BTreeMap<String, f64>,
}

/// What `validate` found in a good manifest.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub run_id: String,
    pub kind: String,
    pub n_artifacts: usize,
}

impl RunManifest {
    pub fn new(run_id: &str, kind: &str, seed: u64, device_sig: &str, meta: Json) -> RunManifest {
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        RunManifest {
            run_id: run_id.to_string(),
            kind: kind.to_string(),
            timestamp_unix_s: ts,
            seed,
            device_sig: device_sig.to_string(),
            meta,
            graphs: Vec::new(),
            artifacts: Vec::new(),
            metrics: BTreeMap::new(),
        }
    }

    pub fn add_graph(&mut self, spec: &str, signature: &str, rows: usize, nnz: usize) {
        self.graphs.push(GraphRef {
            spec: spec.to_string(),
            signature: signature.to_string(),
            rows,
            nnz,
        });
    }

    pub fn add_metric(&mut self, key: &str, value: f64) {
        self.metrics.insert(key.to_string(), value);
    }

    /// Hash `base/rel` and record it under its manifest-relative path.
    pub fn add_artifact(&mut self, base: &Path, rel: &str) -> Result<()> {
        let full = base.join(rel);
        let (sha, bytes) = sha256_file(&full)
            .with_context(|| format!("hashing artifact {}", full.display()))?;
        self.artifacts.push(ArtifactRef { path: rel.to_string(), sha256: sha, bytes });
        Ok(())
    }

    /// The manifest as JSON, *without* the `manifest_sha256` self-hash.
    pub fn to_json(&self) -> Json {
        let graphs: Vec<Json> = self
            .graphs
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("spec", Json::str(&g.spec)),
                    ("signature", Json::str(&g.signature)),
                    ("rows", Json::from(g.rows)),
                    ("nnz", Json::from(g.nnz)),
                ])
            })
            .collect();
        let artifacts: Vec<Json> = self
            .artifacts
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("path", Json::str(&a.path)),
                    ("sha256", Json::str(&a.sha256)),
                    ("bytes", Json::num(a.bytes as f64)),
                ])
            })
            .collect();
        let metrics: BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        Json::obj(vec![
            ("schema_version", Json::str(MANIFEST_SCHEMA_VERSION)),
            ("run_id", Json::str(&self.run_id)),
            ("kind", Json::str(&self.kind)),
            ("timestamp_unix_s", Json::num(self.timestamp_unix_s as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("device_sig", Json::str(&self.device_sig)),
            ("meta", self.meta.clone()),
            ("graphs", Json::Arr(graphs)),
            ("artifacts", Json::Arr(artifacts)),
            ("metrics", Json::Obj(metrics)),
        ])
    }

    /// Write `manifest.json` (self-hash included) into `dir`.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let root = self.to_json();
        let hash = canonical_hash(&root);
        let mut obj = match root {
            Json::Obj(o) => o,
            _ => unreachable!("to_json returns an object"),
        };
        obj.insert("manifest_sha256".to_string(), Json::Str(hash));
        let path = dir.join("manifest.json");
        let mut text = Json::Obj(obj).pretty();
        text.push('\n');
        crate::util::iofault::write_atomic("obs.manifest.write", &path, text.as_bytes())
            .with_context(|| format!("writing manifest {}", path.display()))?;
        Ok(path)
    }
}

/// Self-hash of a manifest value: SHA-256 over the compact serialization
/// with the `manifest_sha256` field removed. Compact [`Json`] output is
/// already canonical — object keys sort via `BTreeMap` and separators
/// are bare `,`/`:` — so on-disk key order and whitespace don't matter.
pub fn canonical_hash(root: &Json) -> String {
    let canon = match root {
        Json::Obj(o) => {
            let mut c = o.clone();
            c.remove("manifest_sha256");
            Json::Obj(c)
        }
        other => other.clone(),
    };
    sha256_hex(canon.to_string().as_bytes())
}

/// Validate a manifest file: schema version, required fields, self-hash,
/// and every listed artifact's sha256 + size (resolved relative to the
/// manifest's own directory).
pub fn validate(path: &Path) -> Result<ValidationReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest {}", path.display()))?;
    let root = Json::parse(&text)
        .map_err(|e| anyhow!("{e}"))
        .with_context(|| format!("parsing manifest {}", path.display()))?;
    if root.as_obj().is_none() {
        bail!("manifest root is not a JSON object");
    }

    let version = root
        .get("schema_version")
        .as_str()
        .context("manifest missing schema_version")?;
    let major = version.split('.').next().unwrap_or("");
    if major != "1" {
        bail!("unsupported manifest schema_version {version} (want 1.x.y)");
    }

    let run_id = root.get("run_id").as_str().context("manifest missing run_id")?;
    let kind = root.get("kind").as_str().context("manifest missing kind")?;
    root.get("device_sig").as_str().context("manifest missing device_sig")?;
    root.get("seed").as_f64().context("manifest missing seed")?;
    root.get("metrics").as_obj().context("manifest missing metrics object")?;

    let declared = root
        .get("manifest_sha256")
        .as_str()
        .context("manifest missing manifest_sha256 self-hash")?;
    let recomputed = canonical_hash(&root);
    if declared != recomputed {
        bail!("manifest self-hash mismatch: declared {declared}, recomputed {recomputed}");
    }

    let base = path.parent().unwrap_or_else(|| Path::new("."));
    let artifacts = root
        .get("artifacts")
        .as_arr()
        .context("manifest missing artifacts array")?;
    for a in artifacts {
        let rel = a.get("path").as_str().context("artifact entry missing path")?;
        let want_sha = a.get("sha256").as_str().context("artifact entry missing sha256")?;
        let want_bytes = a
            .get("bytes")
            .as_f64()
            .context("artifact entry missing bytes")? as u64;
        let full = base.join(rel);
        let (got_sha, got_bytes) = sha256_file(&full)
            .with_context(|| format!("hashing artifact {}", full.display()))?;
        if got_bytes != want_bytes {
            bail!("artifact {rel}: size mismatch (manifest {want_bytes} B, on disk {got_bytes} B)");
        }
        if got_sha != want_sha {
            bail!("artifact {rel}: sha256 mismatch (manifest {want_sha}, on disk {got_sha})");
        }
    }

    Ok(ValidationReport {
        run_id: run_id.to_string(),
        kind: kind.to_string(),
        n_artifacts: artifacts.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("autosage_manifest_tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(dir: &Path) -> RunManifest {
        std::fs::write(dir.join("out.csv"), "a,b\n1,2\n").unwrap();
        let mut m = RunManifest::new("run-1", "bench", 42, "native", Json::obj(vec![]));
        m.add_graph("er_s", "deadbeef00000000", 1000, 8000);
        m.add_metric("p50_ms", 1.25);
        m.add_artifact(dir, "out.csv").unwrap();
        m
    }

    #[test]
    fn emit_then_validate() {
        let dir = tmp_dir("roundtrip");
        let m = sample(&dir);
        let p = m.write(&dir).unwrap();
        let rep = validate(&p).unwrap();
        assert_eq!(rep.run_id, "run-1");
        assert_eq!(rep.kind, "bench");
        assert_eq!(rep.n_artifacts, 1);
    }

    #[test]
    fn self_hash_ignores_field_itself() {
        let dir = tmp_dir("selfhash");
        let m = sample(&dir);
        let without = canonical_hash(&m.to_json());
        let p = m.write(&dir).unwrap();
        let on_disk = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(on_disk.get("manifest_sha256").as_str(), Some(&without[..]));
        assert_eq!(canonical_hash(&on_disk), without);
    }

    #[test]
    fn artifact_tamper_rejected() {
        let dir = tmp_dir("tamper_artifact");
        let m = sample(&dir);
        let p = m.write(&dir).unwrap();
        std::fs::write(dir.join("out.csv"), "a,b\n1,3\n").unwrap();
        let err = validate(&p).unwrap_err();
        assert!(format!("{err:#}").contains("sha256 mismatch"), "{err:#}");
    }

    #[test]
    fn field_tamper_rejected() {
        let dir = tmp_dir("tamper_field");
        let m = sample(&dir);
        let p = m.write(&dir).unwrap();
        let text = std::fs::read_to_string(&p).unwrap().replace("run-1", "run-X");
        std::fs::write(&p, text).unwrap();
        let err = validate(&p).unwrap_err();
        assert!(format!("{err:#}").contains("self-hash mismatch"), "{err:#}");
    }

    #[test]
    fn wrong_major_version_rejected() {
        let dir = tmp_dir("badversion");
        let m = sample(&dir);
        let p = m.write(&dir).unwrap();
        let text = std::fs::read_to_string(&p).unwrap().replace("\"1.0.0\"", "\"2.0.0\"");
        std::fs::write(&p, text).unwrap();
        assert!(validate(&p).is_err());
    }
}
