//! `autosage obs report` — offline aggregation of the observability
//! artifacts a `serve-bench --out DIR` run leaves behind:
//!
//! * `trace.jsonl`  → stage-latency breakdown (count / mean / max per
//!   span name, in the pipeline's canonical stage order).
//! * `audit.jsonl`  → per-(op, variant) calibration-error table for the
//!   roofline estimates: mean/max relative error and sign bias of
//!   predicted vs measured execution time. This table is the direct
//!   input to the ROADMAP's learned-scheduler (`autosage train`) item.
//! * `metrics.prom` → key serving counters echoed for context
//!   (sampling drops, pool percentiles).
//!
//! Every artifact is optional — the report covers whatever exists and
//! says what it skipped — but reporting on a directory with none of
//! them is an error.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::obs::metrics::{parse_prometheus, AuditSample};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Canonical pipeline order for the stage breakdown; unknown span names
/// sort after these, alphabetically.
const STAGE_ORDER: &[&str] = &[
    "request",
    "queue",
    "schedule",
    "cache_hit",
    "cache_miss",
    "predict",
    "estimate",
    "probe",
    "guardrail",
    "execute",
    "reply",
    "warn",
];

fn stage_rank(name: &str) -> usize {
    STAGE_ORDER
        .iter()
        .position(|s| *s == name)
        .unwrap_or(STAGE_ORDER.len())
}

/// Aggregated timing for one span name.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStat {
    pub name: String,
    pub count: u64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

/// Parse a `trace.jsonl` body into per-stage stats plus the distinct
/// trace-id count (excluding the synthetic trace 0 used by warns).
/// Torn tails are salvaged (valid prefix reports, dropped lines count
/// in `iofault::recovery()`).
pub fn stage_breakdown(trace_jsonl: &str) -> Result<(Vec<StageStat>, usize)> {
    struct Acc {
        count: u64,
        sum_us: f64,
        max_us: f64,
    }
    let (lines, dropped) = crate::util::iofault::salvage_jsonl(trace_jsonl);
    if dropped > 0 {
        crate::util::iofault::recovery()
            .jsonl_lines_dropped
            .fetch_add(dropped as u64, std::sync::atomic::Ordering::Relaxed);
    }
    let mut by_name: BTreeMap<String, Acc> = BTreeMap::new();
    let mut traces: BTreeSet<String> = BTreeSet::new();
    for (i, line) in lines.into_iter().enumerate() {
        let j = Json::parse(line).with_context(|| format!("trace.jsonl line {}", i + 1))?;
        let name = j
            .get("name")
            .as_str()
            .with_context(|| format!("trace.jsonl line {}: missing name", i + 1))?
            .to_string();
        let dur = j.get("dur_us").as_f64().unwrap_or(0.0);
        if let Some(t) = j.get("trace").as_str() {
            if t != "0000000000000000" {
                traces.insert(t.to_string());
            }
        }
        let a = by_name.entry(name).or_insert(Acc {
            count: 0,
            sum_us: 0.0,
            max_us: 0.0,
        });
        a.count += 1;
        a.sum_us += dur;
        a.max_us = a.max_us.max(dur);
    }
    let mut stats: Vec<StageStat> = by_name
        .into_iter()
        .map(|(name, a)| StageStat {
            name,
            count: a.count,
            mean_ms: a.sum_us / a.count.max(1) as f64 / 1000.0,
            max_ms: a.max_us / 1000.0,
        })
        .collect();
    stats.sort_by(|a, b| {
        stage_rank(&a.name)
            .cmp(&stage_rank(&b.name))
            .then(a.name.cmp(&b.name))
    });
    Ok((stats, traces.len()))
}

/// One row of the calibration table: how well the roofline estimate
/// predicted measured execution time for (op, variant).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationRow {
    pub op: String,
    pub variant: String,
    /// Distinct `InputFeatures` buckets contributing samples.
    pub buckets: usize,
    pub n: u64,
    /// Mean of |predicted - measured| / measured.
    pub mean_rel_err: f64,
    /// Max of |predicted - measured| / measured.
    pub max_rel_err: f64,
    /// Mean of (predicted - measured) / measured: positive ⇒ the model
    /// overestimates cost, negative ⇒ underestimates.
    pub sign_bias: f64,
}

/// Parse an `audit.jsonl` body into per-(op, variant) calibration rows.
/// Samples with non-positive measured time are skipped (a relative
/// error against ~0 is noise, not signal). Torn tails are salvaged
/// (valid prefix aggregates, dropped lines count in
/// `iofault::recovery()`); JSON-valid lines that are not audit samples
/// stay hard errors.
pub fn calibration_table(audit_jsonl: &str) -> Result<Vec<CalibrationRow>> {
    struct Acc {
        buckets: BTreeSet<String>,
        n: u64,
        sum_abs: f64,
        max_abs: f64,
        sum_signed: f64,
    }
    let (lines, dropped) = crate::util::iofault::salvage_jsonl(audit_jsonl);
    if dropped > 0 {
        crate::util::iofault::recovery()
            .jsonl_lines_dropped
            .fetch_add(dropped as u64, std::sync::atomic::Ordering::Relaxed);
    }
    let mut by_key: BTreeMap<(String, String), Acc> = BTreeMap::new();
    for (i, line) in lines.into_iter().enumerate() {
        let j = Json::parse(line).with_context(|| format!("audit.jsonl line {}", i + 1))?;
        let s = AuditSample::from_json(&j)
            .with_context(|| format!("audit.jsonl line {}: not an audit sample", i + 1))?;
        if s.measured_ms <= 0.0 {
            continue;
        }
        let rel = (s.predicted_ms - s.measured_ms) / s.measured_ms;
        let a = by_key.entry((s.op, s.variant)).or_insert(Acc {
            buckets: BTreeSet::new(),
            n: 0,
            sum_abs: 0.0,
            max_abs: 0.0,
            sum_signed: 0.0,
        });
        a.buckets.insert(s.bucket);
        a.n += 1;
        a.sum_abs += rel.abs();
        a.max_abs = a.max_abs.max(rel.abs());
        a.sum_signed += rel;
    }
    Ok(by_key
        .into_iter()
        .map(|((op, variant), a)| CalibrationRow {
            op,
            variant,
            buckets: a.buckets.len(),
            n: a.n,
            mean_rel_err: a.sum_abs / a.n.max(1) as f64,
            max_rel_err: a.max_abs,
            sign_bias: a.sum_signed / a.n.max(1) as f64,
        })
        .collect())
}

fn render_stage_table(stats: &[StageStat], n_traces: usize, out: &mut String) {
    out.push_str(&format!("stage latency breakdown ({n_traces} traces)\n"));
    out.push_str(&format!(
        "{:<12} {:>8} {:>12} {:>12}\n",
        "stage", "count", "mean_ms", "max_ms"
    ));
    out.push_str(&"-".repeat(48));
    out.push('\n');
    for s in stats {
        out.push_str(&format!(
            "{:<12} {:>8} {:>12.3} {:>12.3}\n",
            s.name, s.count, s.mean_ms, s.max_ms
        ));
    }
}

fn render_calibration_table(rows: &[CalibrationRow], out: &mut String) {
    out.push_str("estimate calibration (roofline predicted vs measured execute)\n");
    out.push_str(&format!(
        "{:<10} {:<16} {:>8} {:>8} {:>12} {:>12} {:>10}\n",
        "op", "variant", "buckets", "n", "mean_rel", "max_rel", "bias"
    ));
    out.push_str(&"-".repeat(82));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:<16} {:>8} {:>8} {:>12.3} {:>12.3} {:>+10.3}\n",
            r.op, r.variant, r.buckets, r.n, r.mean_rel_err, r.max_rel_err, r.sign_bias
        ));
    }
}

/// Counters echoed in the "key serving metrics" section (text and JSON
/// reports alike).
const KEY_METRICS: &[&str] = &[
    "autosage_pool_requests_total",
    "autosage_pool_rejected_total",
    "autosage_pool_latency_ms{quantile=\"0.5\"}",
    "autosage_pool_latency_ms{quantile=\"0.95\"}",
    "autosage_pool_latency_ms{quantile=\"0.99\"}",
    "autosage_traces_sampled_out_total",
    "autosage_spans_dropped_total",
    "autosage_model_predictions_total",
    "autosage_model_low_confidence_probes_total",
    "autosage_model_agree_total",
    "autosage_model_disagree_total",
];

/// Everything an observability directory yields, parsed once and shared
/// by the text and JSON renderers. `None` = that artifact was absent.
pub struct ReportData {
    pub stages: Option<(Vec<StageStat>, usize)>,
    pub calibration: Option<Vec<CalibrationRow>>,
    pub metrics: Option<crate::obs::metrics::PromSnapshot>,
}

/// Parse whatever observability artifacts exist under `dir`. Errors on
/// malformed artifacts; errors when none exist at all.
pub fn gather_report(dir: &Path) -> Result<ReportData> {
    let read_opt = |name: &str| -> Result<Option<String>> {
        let p = dir.join(name);
        if !p.exists() {
            return Ok(None);
        }
        crate::util::iofault::read_to_string("obs.report.read", &p)
            .map(Some)
            .with_context(|| format!("reading {}", p.display()))
    };
    let stages = match read_opt("trace.jsonl")? {
        Some(text) => Some(stage_breakdown(&text)?),
        None => None,
    };
    let calibration = match read_opt("audit.jsonl")? {
        Some(text) => Some(calibration_table(&text)?),
        None => None,
    };
    let metrics = match read_opt("metrics.prom")? {
        Some(text) => Some(parse_prometheus(&text)?),
        None => None,
    };
    if stages.is_none() && calibration.is_none() && metrics.is_none() {
        bail!(
            "no observability artifacts (trace.jsonl / audit.jsonl / metrics.prom) under {}",
            dir.display()
        );
    }
    Ok(ReportData {
        stages,
        calibration,
        metrics,
    })
}

/// Aggregate the observability artifacts under `dir` into a human
/// report. Missing artifacts are noted and skipped; at least one of
/// `trace.jsonl` / `audit.jsonl` / `metrics.prom` must exist.
pub fn report_dir(dir: &Path) -> Result<String> {
    let data = gather_report(dir)?;
    let mut out = String::new();
    out.push_str(&format!("== obs report: {} ==\n", dir.display()));

    match &data.stages {
        Some((stats, n_traces)) => {
            out.push('\n');
            render_stage_table(stats, *n_traces, &mut out);
        }
        None => out.push_str("\n(no trace.jsonl — skipping stage breakdown)\n"),
    }

    match &data.calibration {
        Some(rows) => {
            out.push('\n');
            if rows.is_empty() {
                out.push_str("estimate calibration: no usable audit samples\n");
            } else {
                render_calibration_table(rows, &mut out);
            }
        }
        None => out.push_str("(no audit.jsonl — skipping calibration table)\n"),
    }

    match &data.metrics {
        Some(snap) => {
            out.push_str("\nkey serving metrics\n");
            for key in KEY_METRICS {
                if let Some(v) = snap.get(*key) {
                    out.push_str(&format!("  {key} = {v}\n"));
                }
            }
        }
        None => out.push_str("(no metrics.prom — skipping metrics echo)\n"),
    }

    Ok(out)
}

/// The same aggregation as [`report_dir`] rendered as machine-readable
/// JSON (`autosage obs report --json`): absent artifacts are `null`,
/// so consumers can distinguish "not collected" from "empty". Keys are
/// BTreeMap-sorted — the output is deterministic for a given directory.
pub fn report_dir_json(dir: &Path) -> Result<Json> {
    let data = gather_report(dir)?;
    let stages = match &data.stages {
        None => Json::Null,
        Some((stats, n_traces)) => Json::obj(vec![
            ("n_traces", Json::num(*n_traces as f64)),
            (
                "stages",
                Json::Arr(
                    stats
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::str(&s.name)),
                                ("count", Json::num(s.count as f64)),
                                ("mean_ms", Json::num(s.mean_ms)),
                                ("max_ms", Json::num(s.max_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    let calibration = match &data.calibration {
        None => Json::Null,
        Some(rows) => Json::Arr(
            rows.iter()
                .map(|r| {
                    Json::obj(vec![
                        ("op", Json::str(&r.op)),
                        ("variant", Json::str(&r.variant)),
                        ("buckets", Json::num(r.buckets as f64)),
                        ("n", Json::num(r.n as f64)),
                        ("mean_rel_err", Json::num(r.mean_rel_err)),
                        ("max_rel_err", Json::num(r.max_rel_err)),
                        ("sign_bias", Json::num(r.sign_bias)),
                    ])
                })
                .collect(),
        ),
    };
    let metrics = match &data.metrics {
        None => Json::Null,
        Some(snap) => {
            let mut m = std::collections::BTreeMap::new();
            for key in KEY_METRICS {
                if let Some(v) = snap.get(*key) {
                    m.insert((*key).to_string(), Json::num(*v));
                }
            }
            Json::Obj(m)
        }
    };
    Ok(Json::obj(vec![
        ("dir", Json::str(dir.display().to_string())),
        ("trace", stages),
        ("calibration", calibration),
        ("metrics", metrics),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_line(trace: &str, name: &str, dur_us: u64) -> String {
        format!(
            "{{\"run_id\":\"t\",\"trace\":\"{trace}\",\"span\":\"1\",\"parent\":null,\
             \"name\":\"{name}\",\"start_us\":0,\"dur_us\":{dur_us},\"attrs\":{{}}}}"
        )
    }

    #[test]
    fn stage_breakdown_aggregates_in_pipeline_order() {
        let text = [
            span_line("0000000000000001", "execute", 2000),
            span_line("0000000000000001", "queue", 500),
            span_line("0000000000000002", "execute", 4000),
            span_line("0000000000000000", "warn", 0),
        ]
        .join("\n");
        let (stats, n_traces) = stage_breakdown(&text).unwrap();
        assert_eq!(n_traces, 2, "warn's trace 0 is not a real trace");
        let names: Vec<&str> = stats.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["queue", "execute", "warn"], "canonical stage order");
        let exec = &stats[1];
        assert_eq!(exec.count, 2);
        assert!((exec.mean_ms - 3.0).abs() < 1e-9);
        assert!((exec.max_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_table_computes_error_and_bias() {
        let lines = [
            // spmm/ell: predicted 2 vs measured 1 (+100%), 0.5 vs 1 (-50%)
            r#"{"op":"spmm","variant":"ell","bucket":"b1","predicted_ms":2.0,"measured_ms":1.0}"#,
            r#"{"op":"spmm","variant":"ell","bucket":"b2","predicted_ms":0.5,"measured_ms":1.0}"#,
            // measured 0 rows are skipped
            r#"{"op":"spmm","variant":"ell","bucket":"b1","predicted_ms":1.0,"measured_ms":0.0}"#,
            r#"{"op":"sddmm","variant":"csr","bucket":"b1","predicted_ms":1.0,"measured_ms":1.0}"#,
        ]
        .join("\n");
        let rows = calibration_table(&lines).unwrap();
        assert_eq!(rows.len(), 2);
        let ell = rows.iter().find(|r| r.variant == "ell").unwrap();
        assert_eq!(ell.n, 2);
        assert_eq!(ell.buckets, 2);
        assert!((ell.mean_rel_err - 0.75).abs() < 1e-9, "(1.0 + 0.5) / 2");
        assert!((ell.max_rel_err - 1.0).abs() < 1e-9);
        assert!((ell.sign_bias - 0.25).abs() < 1e-9, "(+1.0 - 0.5) / 2");
        let csr = rows.iter().find(|r| r.variant == "csr").unwrap();
        assert_eq!(csr.mean_rel_err, 0.0);
        assert_eq!(csr.sign_bias, 0.0);
    }

    #[test]
    fn report_dir_requires_at_least_one_artifact() {
        let dir = std::env::temp_dir().join(format!("autosage_obs_report_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(report_dir(&dir).is_err());
        std::fs::write(
            dir.join("audit.jsonl"),
            r#"{"op":"spmm","variant":"ell","bucket":"b","predicted_ms":1.0,"measured_ms":2.0}"#,
        )
        .unwrap();
        let text = report_dir(&dir).unwrap();
        assert!(text.contains("estimate calibration"));
        assert!(text.contains("spmm"));
        assert!(text.contains("no trace.jsonl"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tails_salvage_but_schema_drift_is_an_error() {
        // Unparseable lines are a torn tail: salvage to the valid prefix.
        let (stats, n) = stage_breakdown("not json").unwrap();
        assert!(stats.is_empty() && n == 0);
        let torn = format!("{}\nnot json", span_line("0000000000000001", "execute", 10));
        let (stats, n) = stage_breakdown(&torn).unwrap();
        assert_eq!((stats.len(), n), (1, 1), "prefix survives the torn tail");
        let rows = calibration_table(
            "{\"op\":\"spmm\",\"variant\":\"ell\",\"bucket\":\"b\",\
             \"predicted_ms\":1.0,\"measured_ms\":2.0}\n{\"op\":",
        )
        .unwrap();
        assert_eq!(rows.len(), 1);
        // JSON-valid but not an audit sample: a bug, not disk damage.
        assert!(calibration_table(r#"{"op":"spmm"}"#).is_err());
    }
}
