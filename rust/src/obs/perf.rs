//! Perf profiles and the noise-aware regression gate.
//!
//! A [`PerfProfile`] is a named set of metrics, each with a value, a
//! goodness direction and a relative noise tolerance. Runs write one as
//! `perf.json`; the first profiles are checked in under `benchmarks/`
//! as `BENCH_*.json` and become the trajectory CI gates against via
//! `autosage perf compare <baseline> <candidate>`.
//!
//! Tolerances are per-metric because noise is: deterministic counters
//! (request totals, error counts, unique keys) gate exactly at
//! `tol_rel = 0`, while wall-clock metrics carry wide tolerances so the
//! gate only fires on order-of-magnitude regressions, not scheduler
//! jitter or a slow CI runner.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Perf profile schema version (semver).
pub const PERF_SCHEMA_VERSION: &str = "1.0.0";

/// Which way is better for a metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (throughput, speedup).
    Higher,
    /// Smaller is better (latency); growth beyond tolerance regresses.
    Lower,
    /// Must match the baseline within tolerance (deterministic counters).
    Exact,
}

impl Direction {
    pub fn as_str(&self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
            Direction::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> Result<Direction> {
        match s {
            "higher" => Ok(Direction::Higher),
            "lower" => Ok(Direction::Lower),
            "exact" => Ok(Direction::Exact),
            other => bail!("unknown metric direction '{other}'"),
        }
    }
}

/// One gated metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfMetric {
    pub value: f64,
    pub direction: Direction,
    /// Relative tolerance (0.2 = 20% slack) applied to the baseline.
    pub tol_rel: f64,
}

/// A named set of metrics, serializable as `perf.json` / `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct PerfProfile {
    pub name: String,
    pub metrics: BTreeMap<String, PerfMetric>,
}

impl PerfProfile {
    pub fn new(name: &str) -> PerfProfile {
        PerfProfile { name: name.to_string(), metrics: BTreeMap::new() }
    }

    pub fn push(&mut self, key: &str, value: f64, direction: Direction, tol_rel: f64) {
        self.metrics
            .insert(key.to_string(), PerfMetric { value, direction, tol_rel });
    }

    pub fn to_json(&self) -> Json {
        let metrics: BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, m)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("value", Json::Num(m.value)),
                        ("direction", Json::str(m.direction.as_str())),
                        ("tol_rel", Json::Num(m.tol_rel)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::str(PERF_SCHEMA_VERSION)),
            ("name", Json::str(&self.name)),
            ("metrics", Json::Obj(metrics)),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().pretty();
        text.push('\n');
        std::fs::write(path, &text)
            .with_context(|| format!("writing perf profile {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<PerfProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading perf profile {}", path.display()))?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("parsing perf profile {}", path.display()))?;
        let version = root
            .get("schema_version")
            .as_str()
            .context("perf profile missing schema_version")?;
        if version.split('.').next() != Some("1") {
            bail!("unsupported perf profile schema_version {version}");
        }
        let name = root.get("name").as_str().context("perf profile missing name")?;
        let metrics_obj = root
            .get("metrics")
            .as_obj()
            .context("perf profile missing metrics object")?;
        let mut metrics = BTreeMap::new();
        for (k, v) in metrics_obj {
            let value = v
                .get("value")
                .as_f64()
                .with_context(|| format!("metric {k} missing value"))?;
            let direction = Direction::parse(
                v.get("direction")
                    .as_str()
                    .with_context(|| format!("metric {k} missing direction"))?,
            )?;
            let tol_rel = v
                .get("tol_rel")
                .as_f64()
                .with_context(|| format!("metric {k} missing tol_rel"))?;
            metrics.insert(k.clone(), PerfMetric { value, direction, tol_rel });
        }
        Ok(PerfProfile { name: name.to_string(), metrics })
    }
}

/// Per-metric comparison outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within tolerance of the baseline.
    Pass,
    /// Beyond tolerance in the good direction.
    Improved,
    /// Beyond tolerance in the bad direction — gate fails.
    Regressed,
    /// Baseline metric absent from the candidate — gate fails.
    Missing,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Improved => "improved",
            Verdict::Regressed => "REGRESSED",
            Verdict::Missing => "MISSING",
        }
    }
}

/// One row of a comparison.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub metric: String,
    pub baseline: f64,
    pub candidate: Option<f64>,
    pub verdict: Verdict,
    /// The threshold the candidate was held to.
    pub limit: f64,
}

/// Full comparison result; the gate passes iff no regressions and no
/// missing metrics.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub rows: Vec<CompareRow>,
    pub regressions: usize,
    pub missing: usize,
}

impl CompareReport {
    pub fn passed(&self) -> bool {
        self.regressions == 0 && self.missing == 0
    }

    /// Human-readable table for CLI / CI logs.
    pub fn render(&self, baseline_name: &str, candidate_name: &str) -> String {
        let mut s = format!("perf compare: baseline={baseline_name} candidate={candidate_name}\n");
        s.push_str(&format!(
            "{:<28} {:>14} {:>14} {:>14}  verdict\n",
            "metric", "baseline", "candidate", "limit"
        ));
        for r in &self.rows {
            let cand = match r.candidate {
                Some(v) => format!("{v:.4}"),
                None => "-".to_string(),
            };
            s.push_str(&format!(
                "{:<28} {:>14.4} {:>14} {:>14.4}  {}\n",
                r.metric,
                r.baseline,
                cand,
                r.limit,
                r.verdict.as_str()
            ));
        }
        s.push_str(&format!(
            "result: {} ({} regressed, {} missing, {} metrics)\n",
            if self.passed() { "PASS" } else { "FAIL" },
            self.regressions,
            self.missing,
            self.rows.len()
        ));
        s
    }
}

/// Compare a candidate profile against a baseline. Directions and
/// tolerances come from the *baseline* (the checked-in contract);
/// candidate-only metrics are ignored. A small absolute epsilon keeps
/// float round-trips from flipping verdicts at exactly the limit.
pub fn compare(baseline: &PerfProfile, candidate: &PerfProfile) -> CompareReport {
    const EPS: f64 = 1e-9;
    let mut rows = Vec::new();
    let mut regressions = 0usize;
    let mut missing = 0usize;
    for (key, base) in &baseline.metrics {
        let cand = candidate.metrics.get(key).map(|m| m.value);
        let (verdict, limit) = match cand {
            None => (Verdict::Missing, base.value),
            Some(c) => match base.direction {
                Direction::Lower => {
                    let limit = base.value * (1.0 + base.tol_rel);
                    if c > limit + EPS {
                        (Verdict::Regressed, limit)
                    } else if c < base.value * (1.0 - base.tol_rel) - EPS {
                        (Verdict::Improved, limit)
                    } else {
                        (Verdict::Pass, limit)
                    }
                }
                Direction::Higher => {
                    let limit = (base.value * (1.0 - base.tol_rel)).max(0.0);
                    if c < limit - EPS {
                        (Verdict::Regressed, limit)
                    } else if c > base.value * (1.0 + base.tol_rel) + EPS {
                        (Verdict::Improved, limit)
                    } else {
                        (Verdict::Pass, limit)
                    }
                }
                Direction::Exact => {
                    let slack = base.tol_rel * base.value.abs() + EPS;
                    if (c - base.value).abs() > slack {
                        (Verdict::Regressed, base.value)
                    } else {
                        (Verdict::Pass, base.value)
                    }
                }
            },
        };
        match verdict {
            Verdict::Regressed => regressions += 1,
            Verdict::Missing => missing += 1,
            _ => {}
        }
        rows.push(CompareRow {
            metric: key.clone(),
            baseline: base.value,
            candidate: cand,
            verdict,
            limit,
        });
    }
    CompareReport { rows, regressions, missing }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> PerfProfile {
        let mut p = PerfProfile::new("serve_bench");
        p.push("throughput_rps", 100.0, Direction::Higher, 0.5);
        p.push("p99_ms", 50.0, Direction::Lower, 1.0);
        p.push("errors", 0.0, Direction::Exact, 0.0);
        p
    }

    #[test]
    fn identical_profile_passes() {
        let b = base();
        let rep = compare(&b, &b.clone());
        assert!(rep.passed());
        assert!(rep.rows.iter().all(|r| r.verdict == Verdict::Pass));
    }

    #[test]
    fn within_tolerance_passes_beyond_fails() {
        let b = base();
        let mut c = base();
        c.push("throughput_rps", 60.0, Direction::Higher, 0.5); // ≥ 50 ok
        c.push("p99_ms", 99.0, Direction::Lower, 1.0); // ≤ 100 ok
        assert!(compare(&b, &c).passed());

        c.push("throughput_rps", 40.0, Direction::Higher, 0.5); // < 50 bad
        let rep = compare(&b, &c);
        assert!(!rep.passed());
        assert_eq!(rep.regressions, 1);
    }

    #[test]
    fn exact_counter_must_match() {
        let b = base();
        let mut c = base();
        c.push("errors", 1.0, Direction::Exact, 0.0);
        let rep = compare(&b, &c);
        assert_eq!(rep.regressions, 1);
        assert!(!rep.passed());
    }

    #[test]
    fn missing_metric_fails_gate() {
        let b = base();
        let mut c = base();
        c.metrics.remove("p99_ms");
        let rep = compare(&b, &c);
        assert_eq!(rep.missing, 1);
        assert!(!rep.passed());
    }

    #[test]
    fn candidate_only_metrics_ignored() {
        let b = base();
        let mut c = base();
        c.push("brand_new_metric", 7.0, Direction::Lower, 0.1);
        let rep = compare(&b, &c);
        assert!(rep.passed());
        assert_eq!(rep.rows.len(), 3);
    }

    #[test]
    fn improvements_reported_not_failed() {
        let b = base();
        let mut c = base();
        c.push("p99_ms", 1.0, Direction::Lower, 1.0);
        c.push("throughput_rps", 400.0, Direction::Higher, 0.5);
        let rep = compare(&b, &c);
        assert!(rep.passed());
        // p99 tol is 1.0 → improvement threshold clamps at 0, so only
        // throughput (400 > 150) registers as Improved.
        assert_eq!(
            rep.rows.iter().filter(|r| r.verdict == Verdict::Improved).count(),
            1
        );
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("autosage_perf_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("perf.json");
        let b = base();
        b.save(&p).unwrap();
        let back = PerfProfile::load(&p).unwrap();
        assert_eq!(back.name, "serve_bench");
        assert_eq!(back.metrics.len(), 3);
        assert_eq!(back.metrics["p99_ms"].direction, Direction::Lower);
        assert_eq!(back.metrics["p99_ms"].value, 50.0);
        assert!(compare(&b, &back).passed());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn render_mentions_failures() {
        let b = base();
        let mut c = base();
        c.push("p99_ms", 5000.0, Direction::Lower, 1.0);
        let rep = compare(&b, &c);
        let text = rep.render("base", "cand");
        assert!(text.contains("REGRESSED"));
        assert!(text.contains("FAIL"));
    }
}
