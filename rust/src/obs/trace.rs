//! Structured tracing with no external deps.
//!
//! A [`Recorder`] hands out trace ids (one per logical request) and span
//! ids (one per unit of work), timestamps spans as microsecond offsets
//! from its own creation instant (monotonic — wall clock never moves a
//! span), and buffers [`SpanRecord`]s in memory. At the end of a run the
//! buffer flushes as JSONL, one span per line, every line carrying the
//! `run_id` so multiple runs can be concatenated and still separated.
//!
//! Span names used by the engine:
//! * `request` — loadgen root span (client side, submit → reply recv)
//! * `queue` — shard queue wait (enqueue → batch pickup)
//! * `schedule` — coalesced-group decision (cache lookup / probe)
//! * `estimate` / `probe` / `guardrail` — scheduler phases, parented
//!   under `schedule`
//! * `cache_hit` / `cache_miss` — zero-duration events under `schedule`
//! * `execute` — backend kernel execution for one request
//! * `reply` — zero-duration event when the response is sent
//! * `warn` — demoted non-fatal errors (e.g. cache persist I/O)

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Identifier shared by every span of one logical request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Identifier of a single span within a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpanId(pub u64);

impl std::fmt::Display for SpanId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:x}", self.0)
    }
}

/// Trace context carried across thread boundaries (loadgen → shard →
/// scheduler): which trace a piece of work belongs to and which span is
/// its parent.
#[derive(Debug, Clone, Copy)]
pub struct TraceCtx {
    pub trace: TraceId,
    pub parent: SpanId,
}

/// One completed span (or zero-duration event).
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub trace: TraceId,
    pub span: SpanId,
    pub parent: Option<SpanId>,
    pub name: String,
    /// Microseconds since the recorder's epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// String key/value attributes (variant, shard, outcome, ...).
    pub attrs: Vec<(String, String)>,
}

/// Thread-safe span sink for one run.
pub struct Recorder {
    run_id: String,
    epoch: Instant,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
}

impl Recorder {
    pub fn new(run_id: &str) -> Recorder {
        Recorder {
            run_id: run_id.to_string(),
            epoch: Instant::now(),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            spans: Mutex::new(Vec::new()),
        }
    }

    pub fn run_id(&self) -> &str {
        &self.run_id
    }

    /// Allocate a fresh trace id (one per logical request).
    pub fn new_trace(&self) -> TraceId {
        TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    /// Allocate a span id without recording anything yet — used when the
    /// parent id must be known before child spans are recorded.
    pub fn next_span_id(&self) -> SpanId {
        SpanId(self.next_span.fetch_add(1, Ordering::Relaxed))
    }

    /// Microseconds since the recorder epoch, now.
    pub fn now_us(&self) -> u64 {
        self.us_of(Instant::now())
    }

    /// Map an arbitrary `Instant` (e.g. a request's enqueue time) onto
    /// the recorder epoch; instants before the epoch clamp to 0.
    pub fn us_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<SpanRecord>> {
        self.spans.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record a fully-formed span.
    pub fn record(&self, rec: SpanRecord) {
        self.lock().push(rec);
    }

    /// Record a span with a fresh id between two epoch-relative
    /// microsecond timestamps. Returns the new span's id.
    pub fn span_between(
        &self,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &str,
        start_us: u64,
        end_us: u64,
        attrs: Vec<(String, String)>,
    ) -> SpanId {
        let span = self.next_span_id();
        self.record(SpanRecord {
            trace,
            span,
            parent,
            name: name.to_string(),
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            attrs,
        });
        span
    }

    /// Record a zero-duration event at "now".
    pub fn event(
        &self,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &str,
        attrs: Vec<(String, String)>,
    ) -> SpanId {
        let now = self.now_us();
        self.span_between(trace, parent, name, now, now, attrs)
    }

    /// Record a demoted warning (non-fatal error) as a `warn` event,
    /// optionally attached to a trace.
    pub fn warn(&self, trace: Option<TraceId>, what: &str, msg: &str) {
        let trace = trace.unwrap_or(TraceId(0));
        self.event(
            trace,
            None,
            "warn",
            vec![
                ("what".to_string(), what.to_string()),
                ("msg".to_string(), msg.to_string()),
            ],
        );
    }

    pub fn n_spans(&self) -> usize {
        self.lock().len()
    }

    /// All recorded spans with the given name, in record order.
    pub fn spans_of(&self, name: &str) -> Vec<SpanRecord> {
        self.lock().iter().filter(|s| s.name == name).cloned().collect()
    }

    /// Copy of all recorded spans, in record order.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.lock().clone()
    }

    /// Flush all spans as JSONL (one object per line, every line keyed
    /// by `run_id`). Returns the path written.
    pub fn flush_jsonl(&self, path: &Path) -> Result<PathBuf> {
        let spans = self.snapshot();
        let mut out = String::new();
        for s in &spans {
            let mut attrs: Vec<(&str, Json)> = Vec::with_capacity(s.attrs.len());
            for (k, v) in &s.attrs {
                attrs.push((k.as_str(), Json::str(v)));
            }
            let line = Json::obj(vec![
                ("run_id", Json::str(&self.run_id)),
                ("trace", Json::str(&s.trace.to_string())),
                ("span", Json::str(&s.span.to_string())),
                (
                    "parent",
                    match s.parent {
                        Some(p) => Json::str(&p.to_string()),
                        None => Json::Null,
                    },
                ),
                ("name", Json::str(&s.name)),
                ("start_us", Json::num(s.start_us as f64)),
                ("dur_us", Json::num(s.dur_us as f64)),
                ("attrs", Json::obj(attrs)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        std::fs::write(path, &out)
            .with_context(|| format!("writing trace JSONL {}", path.display()))?;
        Ok(path.to_path_buf())
    }
}

/// Process-unique run id: `{kind}-{unix_secs:x}-{pid:x}-{n:x}`.
pub fn new_run_id(kind: &str) -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    format!("{kind}-{secs:x}-{pid:x}-{n:x}", pid = std::process::id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_formatted() {
        let r = Recorder::new("t-run");
        let a = r.new_trace();
        let b = r.new_trace();
        assert_ne!(a, b);
        assert_eq!(format!("{}", TraceId(0xab)), "00000000000000ab");
        assert_eq!(format!("{}", SpanId(0xab)), "ab");
        assert_ne!(r.next_span_id(), r.next_span_id());
    }

    #[test]
    fn spans_record_and_filter() {
        let r = Recorder::new("t-run");
        let t = r.new_trace();
        let root = r.span_between(t, None, "request", 0, 100, vec![]);
        r.span_between(t, Some(root), "queue", 0, 10, vec![]);
        r.event(t, Some(root), "reply", vec![("ok".into(), "true".into())]);
        assert_eq!(r.n_spans(), 3);
        let q = r.spans_of("queue");
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].parent, Some(root));
        assert_eq!(q[0].dur_us, 10);
        assert_eq!(r.spans_of("reply")[0].dur_us, 0);
    }

    #[test]
    fn jsonl_round_trips() {
        let dir = std::env::temp_dir().join("autosage_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trace.jsonl");
        let r = Recorder::new("jsonl-run");
        let t = r.new_trace();
        let root = r.span_between(t, None, "request", 5, 25, vec![]);
        r.span_between(
            t,
            Some(root),
            "execute",
            7,
            20,
            vec![("variant".into(), "ell_tile".into())],
        );
        r.flush_jsonl(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("run_id").as_str(), Some("jsonl-run"));
            assert!(j.get("trace").as_str().is_some());
        }
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.get("name").as_str(), Some("execute"));
        assert_eq!(j.get("parent").as_str(), Some(&root.to_string()[..]));
        assert_eq!(j.get("dur_us").as_i64(), Some(13));
        assert_eq!(j.get("attrs").get("variant").as_str(), Some("ell_tile"));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn run_ids_are_unique() {
        let a = new_run_id("bench");
        let b = new_run_id("bench");
        assert_ne!(a, b);
        assert!(a.starts_with("bench-"));
    }

    #[test]
    fn us_of_clamps_before_epoch() {
        let early = Instant::now();
        let r = Recorder::new("t");
        assert_eq!(r.us_of(early), 0);
    }
}
