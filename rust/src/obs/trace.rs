//! Structured tracing with no external deps.
//!
//! A [`Recorder`] hands out trace ids (one per logical request) and span
//! ids (one per unit of work), timestamps spans as microsecond offsets
//! from its own creation instant (monotonic — wall clock never moves a
//! span), and buffers [`SpanRecord`]s in memory. Spans flush as JSONL,
//! one span per line, every line carrying the `run_id` so multiple runs
//! can be concatenated and still separated.
//!
//! Production shape (always-on tracing at serving scale):
//! * **Head-based sampling** — [`Recorder::with_sampling`] keeps a
//!   trace iff a seeded hash of its trace id lands under the sample
//!   rate. Trace ids are allocated sequentially, so the *set* of
//!   sampled ids for a given (seed, rate, request count) is a pure
//!   function — deterministic across reruns regardless of thread
//!   interleaving. Discards count in `traces_sampled_out`.
//! * **Bounded buffering** — [`Recorder::with_capacity`] turns the span
//!   buffer into a ring: oldest spans evict first, and evictions that
//!   were never flushed count in `spans_dropped`.
//! * **Incremental flush** — [`Recorder::flush_jsonl`] keeps a snapshot
//!   cursor: the first flush writes the whole buffer, later flushes
//!   append only spans recorded since (no duplicates, safe to call
//!   concurrently with `record`). [`Recorder::set_auto_flush`] +
//!   [`Recorder::maybe_flush`] add a CAS-throttled periodic flush for
//!   long `serve-bench` runs instead of only at exit.
//!
//! Span names used by the engine:
//! * `request` — loadgen root span (client side, submit → reply recv)
//! * `queue` — shard queue wait (enqueue → batch pickup)
//! * `schedule` — coalesced-group decision (cache lookup / probe)
//! * `estimate` / `probe` / `guardrail` — scheduler phases, parented
//!   under `schedule`
//! * `cache_hit` / `cache_miss` — zero-duration events under `schedule`
//! * `execute` — backend kernel execution for one request
//! * `reply` — zero-duration event when the response is sent
//! * `warn` — demoted non-fatal errors (e.g. cache persist I/O)

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Identifier shared by every span of one logical request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Identifier of a single span within a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpanId(pub u64);

impl std::fmt::Display for SpanId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:x}", self.0)
    }
}

/// Trace context carried across thread boundaries (loadgen → shard →
/// scheduler): which trace a piece of work belongs to and which span is
/// its parent.
#[derive(Debug, Clone, Copy)]
pub struct TraceCtx {
    pub trace: TraceId,
    pub parent: SpanId,
}

/// One completed span (or zero-duration event).
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub trace: TraceId,
    pub span: SpanId,
    pub parent: Option<SpanId>,
    pub name: String,
    /// Microseconds since the recorder's epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// String key/value attributes (variant, shard, outcome, ...).
    pub attrs: Vec<(String, String)>,
}

/// Span buffer with flush bookkeeping, guarded by one mutex so the
/// flush cursor can never race a concurrent `record`.
struct SpanBuf {
    spans: VecDeque<SpanRecord>,
    /// Absolute index (over all spans ever recorded) one past the last
    /// span already written by `flush_jsonl`.
    flushed: u64,
    /// Count of spans evicted from the front of the ring.
    evicted: u64,
}

/// Thread-safe span sink for one run.
pub struct Recorder {
    run_id: String,
    epoch: Instant,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    buf: Mutex<SpanBuf>,
    /// Ring capacity; 0 = unbounded.
    capacity: usize,
    /// Head-sampling rate in [0, 1] and the seed mixed into the hash.
    sample_rate: f64,
    sample_seed: u64,
    /// Traces discarded by head sampling.
    sampled_out: AtomicU64,
    /// Spans evicted from the ring before ever being flushed.
    dropped: AtomicU64,
    /// Periodic-flush target: (path, interval). CAS on `last_flush_ms`
    /// picks one flusher per interval, mirroring
    /// `SharedScheduleCache::maybe_persist`.
    flush_target: Mutex<Option<(PathBuf, Duration)>>,
    last_flush_ms: AtomicU64,
}

impl Recorder {
    /// Unbounded recorder that keeps every trace (sample rate 1.0).
    pub fn new(run_id: &str) -> Recorder {
        Recorder::with_sampling(run_id, 1.0, 0)
    }

    /// Recorder with head-based trace sampling: a trace is kept iff
    /// `mix64(seed, id) < rate * 2^64`. The sampled-id set is a pure
    /// function of (seed, rate), independent of thread interleaving.
    pub fn with_sampling(run_id: &str, rate: f64, seed: u64) -> Recorder {
        Recorder {
            run_id: run_id.to_string(),
            epoch: Instant::now(),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            buf: Mutex::new(SpanBuf {
                spans: VecDeque::new(),
                flushed: 0,
                evicted: 0,
            }),
            capacity: 0,
            sample_rate: rate.clamp(0.0, 1.0),
            sample_seed: seed,
            sampled_out: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            flush_target: Mutex::new(None),
            last_flush_ms: AtomicU64::new(0),
        }
    }

    /// Builder: bound the span buffer to a ring of `cap` spans
    /// (0 = unbounded). Evicted-before-flush spans count in
    /// [`Recorder::spans_dropped`].
    pub fn with_capacity(mut self, cap: usize) -> Recorder {
        self.capacity = cap;
        self
    }

    pub fn run_id(&self) -> &str {
        &self.run_id
    }

    pub fn sample_rate(&self) -> f64 {
        self.sample_rate
    }

    /// Traces discarded by head sampling so far.
    pub fn traces_sampled_out(&self) -> u64 {
        self.sampled_out.load(Ordering::Relaxed)
    }

    /// Spans evicted from the ring buffer without ever being flushed.
    pub fn spans_dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Allocate a fresh trace id (one per logical request).
    pub fn new_trace(&self) -> TraceId {
        TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    /// Pure head-sampling decision for a trace id: seeded hash of the
    /// id against the rate threshold. Rates 0.0 / 1.0 short-circuit to
    /// never / always.
    pub fn trace_is_sampled(&self, id: TraceId) -> bool {
        if self.sample_rate >= 1.0 {
            return true;
        }
        if self.sample_rate <= 0.0 {
            return false;
        }
        let threshold = (self.sample_rate * u64::MAX as f64) as u64;
        mix64(self.sample_seed ^ id.0.wrapping_mul(0x9E3779B97F4A7C15)) < threshold
    }

    /// Allocate the next trace id and apply head sampling: `Some` ctx
    /// (with a fresh root span id) iff the trace is kept. Ids advance
    /// either way so the sampled-id set stays a pure function of
    /// (seed, rate) — discarded traces count in `traces_sampled_out`.
    pub fn sample_ctx(&self) -> Option<TraceCtx> {
        let trace = self.new_trace();
        if self.trace_is_sampled(trace) {
            Some(TraceCtx {
                trace,
                parent: self.next_span_id(),
            })
        } else {
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Allocate a span id without recording anything yet — used when the
    /// parent id must be known before child spans are recorded.
    pub fn next_span_id(&self) -> SpanId {
        SpanId(self.next_span.fetch_add(1, Ordering::Relaxed))
    }

    /// Microseconds since the recorder epoch, now.
    pub fn now_us(&self) -> u64 {
        self.us_of(Instant::now())
    }

    /// Map an arbitrary `Instant` (e.g. a request's enqueue time) onto
    /// the recorder epoch; instants before the epoch clamp to 0.
    pub fn us_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SpanBuf> {
        self.buf.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record a fully-formed span. In ring mode the oldest span evicts
    /// when full; an eviction that was never flushed counts as dropped.
    pub fn record(&self, rec: SpanRecord) {
        let mut buf = self.lock();
        buf.spans.push_back(rec);
        if self.capacity > 0 && buf.spans.len() > self.capacity {
            buf.spans.pop_front();
            let abs = buf.evicted;
            buf.evicted += 1;
            if abs >= buf.flushed {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record a span with a fresh id between two epoch-relative
    /// microsecond timestamps. Returns the new span's id.
    pub fn span_between(
        &self,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &str,
        start_us: u64,
        end_us: u64,
        attrs: Vec<(String, String)>,
    ) -> SpanId {
        let span = self.next_span_id();
        self.record(SpanRecord {
            trace,
            span,
            parent,
            name: name.to_string(),
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            attrs,
        });
        span
    }

    /// Record a zero-duration event at "now".
    pub fn event(
        &self,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &str,
        attrs: Vec<(String, String)>,
    ) -> SpanId {
        let now = self.now_us();
        self.span_between(trace, parent, name, now, now, attrs)
    }

    /// Record a demoted warning (non-fatal error) as a `warn` event,
    /// optionally attached to a trace.
    pub fn warn(&self, trace: Option<TraceId>, what: &str, msg: &str) {
        let trace = trace.unwrap_or(TraceId(0));
        self.event(
            trace,
            None,
            "warn",
            vec![
                ("what".to_string(), what.to_string()),
                ("msg".to_string(), msg.to_string()),
            ],
        );
    }

    pub fn n_spans(&self) -> usize {
        self.lock().spans.len()
    }

    /// All recorded spans with the given name, in record order.
    pub fn spans_of(&self, name: &str) -> Vec<SpanRecord> {
        self.lock()
            .spans
            .iter()
            .filter(|s| s.name == name)
            .cloned()
            .collect()
    }

    /// Copy of all buffered spans, in record order.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.lock().spans.iter().cloned().collect()
    }

    fn jsonl_line(&self, s: &SpanRecord) -> String {
        let mut attrs: Vec<(&str, Json)> = Vec::with_capacity(s.attrs.len());
        for (k, v) in &s.attrs {
            attrs.push((k.as_str(), Json::str(v)));
        }
        Json::obj(vec![
            ("run_id", Json::str(&self.run_id)),
            ("trace", Json::str(&s.trace.to_string())),
            ("span", Json::str(&s.span.to_string())),
            (
                "parent",
                match s.parent {
                    Some(p) => Json::str(&p.to_string()),
                    None => Json::Null,
                },
            ),
            ("name", Json::str(&s.name)),
            ("start_us", Json::num(s.start_us as f64)),
            ("dur_us", Json::num(s.dur_us as f64)),
            ("attrs", Json::obj(attrs)),
        ])
        .to_string()
    }

    /// Flush spans as JSONL (one object per line, every line keyed by
    /// `run_id`). Incremental: the first flush truncates the file and
    /// writes everything buffered; repeated flushes append only spans
    /// recorded since the previous flush — never duplicates, even when
    /// `record` runs concurrently (the cursor and the write happen
    /// under the span-buffer lock). A recorder has ONE logical output
    /// stream: flushing to a second path mid-run would only carry the
    /// not-yet-flushed suffix. Returns the path written.
    pub fn flush_jsonl(&self, path: &Path) -> Result<PathBuf> {
        let mut buf = self.lock();
        let first = buf.flushed == 0;
        let start_abs = buf.flushed.max(buf.evicted);
        let skip = (start_abs - buf.evicted) as usize;
        let mut out = String::new();
        for s in buf.spans.iter().skip(skip) {
            out.push_str(&self.jsonl_line(s));
            out.push('\n');
        }
        crate::util::iofault::append_file("obs.trace.flush", path, out.as_bytes(), first)
            .with_context(|| format!("writing trace JSONL {}", path.display()))?;
        buf.flushed = buf.evicted + buf.spans.len() as u64;
        Ok(path.to_path_buf())
    }

    /// Configure a periodic flush target for [`Recorder::maybe_flush`].
    pub fn set_auto_flush(&self, path: PathBuf, interval: Duration) {
        let mut t = self
            .flush_target
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        *t = Some((path, interval));
    }

    /// Throttled incremental flush: at most one caller per configured
    /// interval actually flushes (CAS on the elapsed-ms word, same
    /// pattern as `SharedScheduleCache::maybe_persist`). Returns
    /// `Ok(true)` iff this call flushed. No-op without
    /// [`Recorder::set_auto_flush`].
    pub fn maybe_flush(&self) -> Result<bool> {
        let target = {
            let t = self
                .flush_target
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            t.clone()
        };
        let Some((path, interval)) = target else {
            return Ok(false);
        };
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let last = self.last_flush_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(last) < interval.as_millis() as u64 {
            return Ok(false);
        }
        if self
            .last_flush_ms
            .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return Ok(false);
        }
        self.flush_jsonl(&path).map(|_| true)
    }
}

/// SplitMix64 finalizer: the avalanche step used for the head-sampling
/// hash (full bit diffusion, so low ids don't bias the sampled set).
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Process-unique run id: `{kind}-{unix_secs:x}-{pid:x}-{n:x}`.
pub fn new_run_id(kind: &str) -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    format!("{kind}-{secs:x}-{pid:x}-{n:x}", pid = std::process::id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_formatted() {
        let r = Recorder::new("t-run");
        let a = r.new_trace();
        let b = r.new_trace();
        assert_ne!(a, b);
        assert_eq!(format!("{}", TraceId(0xab)), "00000000000000ab");
        assert_eq!(format!("{}", SpanId(0xab)), "ab");
        assert_ne!(r.next_span_id(), r.next_span_id());
    }

    #[test]
    fn spans_record_and_filter() {
        let r = Recorder::new("t-run");
        let t = r.new_trace();
        let root = r.span_between(t, None, "request", 0, 100, vec![]);
        r.span_between(t, Some(root), "queue", 0, 10, vec![]);
        r.event(t, Some(root), "reply", vec![("ok".into(), "true".into())]);
        assert_eq!(r.n_spans(), 3);
        let q = r.spans_of("queue");
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].parent, Some(root));
        assert_eq!(q[0].dur_us, 10);
        assert_eq!(r.spans_of("reply")[0].dur_us, 0);
    }

    #[test]
    fn jsonl_round_trips() {
        let dir = std::env::temp_dir().join("autosage_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trace.jsonl");
        let r = Recorder::new("jsonl-run");
        let t = r.new_trace();
        let root = r.span_between(t, None, "request", 5, 25, vec![]);
        r.span_between(
            t,
            Some(root),
            "execute",
            7,
            20,
            vec![("variant".into(), "ell_tile".into())],
        );
        r.flush_jsonl(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("run_id").as_str(), Some("jsonl-run"));
            assert!(j.get("trace").as_str().is_some());
        }
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.get("name").as_str(), Some("execute"));
        assert_eq!(j.get("parent").as_str(), Some(&root.to_string()[..]));
        assert_eq!(j.get("dur_us").as_i64(), Some(13));
        assert_eq!(j.get("attrs").get("variant").as_str(), Some("ell_tile"));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn repeated_flush_appends_only_new_spans() {
        let dir = std::env::temp_dir().join("autosage_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("incr-{}.jsonl", std::process::id()));
        // Stale file from a "previous run" must be truncated by the
        // first flush.
        std::fs::write(&p, "stale line\n").unwrap();
        let r = Recorder::new("incr-run");
        let t = r.new_trace();
        r.span_between(t, None, "request", 0, 10, vec![]);
        r.span_between(t, None, "queue", 0, 5, vec![]);
        r.flush_jsonl(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 2);
        // No new spans: flushing again must not duplicate anything.
        r.flush_jsonl(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 2);
        // New spans: only the delta appends.
        r.span_between(t, None, "execute", 5, 9, vec![]);
        r.flush_jsonl(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let names: Vec<String> = lines
            .iter()
            .map(|l| Json::parse(l).unwrap().get("name").as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, ["request", "queue", "execute"]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn ring_buffer_evicts_oldest_and_counts_drops() {
        let r = Recorder::new("ring-run").with_capacity(3);
        let t = r.new_trace();
        for i in 0..5 {
            r.span_between(t, None, &format!("s{i}"), i, i + 1, vec![]);
        }
        assert_eq!(r.n_spans(), 3);
        assert_eq!(r.spans_dropped(), 2, "s0 and s1 evicted unflushed");
        let names: Vec<String> = r.snapshot().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, ["s2", "s3", "s4"]);
    }

    #[test]
    fn ring_eviction_after_flush_is_not_a_drop() {
        let dir = std::env::temp_dir().join("autosage_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("ring-{}.jsonl", std::process::id()));
        let r = Recorder::new("ring-flush").with_capacity(2);
        let t = r.new_trace();
        r.span_between(t, None, "a", 0, 1, vec![]);
        r.span_between(t, None, "b", 1, 2, vec![]);
        r.flush_jsonl(&p).unwrap();
        // "a" and "b" are on disk; evicting them is not data loss.
        r.span_between(t, None, "c", 2, 3, vec![]);
        r.span_between(t, None, "d", 3, 4, vec![]);
        assert_eq!(r.spans_dropped(), 0);
        r.flush_jsonl(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4, "a b c d all flushed once");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn sampling_edge_rates_keep_all_or_none() {
        let all = Recorder::with_sampling("s1", 1.0, 7);
        let none = Recorder::with_sampling("s0", 0.0, 7);
        let mut kept = 0;
        for _ in 0..50 {
            assert!(all.sample_ctx().is_some());
            if none.sample_ctx().is_some() {
                kept += 1;
            }
        }
        assert_eq!(kept, 0);
        assert_eq!(all.traces_sampled_out(), 0);
        assert_eq!(none.traces_sampled_out(), 50);
    }

    #[test]
    fn sampled_id_set_is_a_pure_function_of_seed_and_rate() {
        let a = Recorder::with_sampling("sa", 0.3, 42);
        let b = Recorder::with_sampling("sb", 0.3, 42);
        let ids_a: Vec<u64> = (1..=200).filter(|i| a.trace_is_sampled(TraceId(*i))).collect();
        let ids_b: Vec<u64> = (1..=200).filter(|i| b.trace_is_sampled(TraceId(*i))).collect();
        assert_eq!(ids_a, ids_b, "same seed+rate ⇒ same sampled set");
        assert!(!ids_a.is_empty() && ids_a.len() < 200, "rate 0.3 samples a strict subset");
        let c = Recorder::with_sampling("sc", 0.3, 43);
        let ids_c: Vec<u64> = (1..=200).filter(|i| c.trace_is_sampled(TraceId(*i))).collect();
        assert_ne!(ids_a, ids_c, "different seed ⇒ different set");
    }

    #[test]
    fn maybe_flush_is_throttled_and_incremental() {
        let dir = std::env::temp_dir().join("autosage_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("auto-{}.jsonl", std::process::id()));
        let r = Recorder::new("auto-run");
        assert!(!r.maybe_flush().unwrap(), "no-op before set_auto_flush");
        r.set_auto_flush(p.clone(), Duration::from_millis(0));
        let t = r.new_trace();
        r.span_between(t, None, "request", 0, 1, vec![]);
        // Interval 0 + last_flush_ms starting at 0: the first tick may
        // be throttled until 1ms of recorder age, so spin briefly.
        let deadline = Instant::now() + Duration::from_secs(2);
        while !r.maybe_flush().unwrap() {
            assert!(Instant::now() < deadline, "flush never fired");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn run_ids_are_unique() {
        let a = new_run_id("bench");
        let b = new_run_id("bench");
        assert_ne!(a, b);
        assert!(a.starts_with("bench-"));
    }

    #[test]
    fn us_of_clamps_before_epoch() {
        let early = Instant::now();
        let r = Recorder::new("t");
        assert_eq!(r.us_of(early), 0);
    }
}
