//! Content signatures for the persistent schedule cache (paper §4.2:
//! cache key = `(device_sig, graph_sig, F, op)`).
//!
//! FNV-1a over the CSR structure. The signature covers *structure*
//! (rowptr/colind) and dimensions, not edge values: the paper's scheduler
//! decisions depend on sparsity pattern, never on values.

use super::csr::Csr;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over a byte stream.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// Structural signature of a graph, hex-encoded.
pub fn graph_signature(g: &Csr) -> String {
    let mut h = Fnv1a::new();
    h.write_u64(g.n_rows as u64);
    h.write_u64(g.n_cols as u64);
    h.write_u64(g.nnz() as u64);
    for &p in &g.rowptr {
        h.write_u64(p as u64);
    }
    for &c in &g.colind {
        h.write_u64(c as u64);
    }
    format!("{:016x}", h.finish())
}

/// Device signature: platform name/version + logical CPU count.
/// Encodes "device + toolchain minors" so stale cache entries from a
/// different machine are never reused (paper §12 Internal validity).
pub fn device_signature(platform: &str, version: &str) -> String {
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut h = Fnv1a::new();
    h.write(platform.as_bytes());
    h.write(version.as_bytes());
    h.write_u64(cpus as u64);
    format!("{}-{}cpu-{:08x}", platform, cpus, h.finish() as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g1() -> Csr {
        Csr::from_rows(3, vec![vec![(1, 1.0)], vec![(2, 2.0)], vec![]])
    }

    #[test]
    fn signature_deterministic() {
        assert_eq!(graph_signature(&g1()), graph_signature(&g1()));
    }

    #[test]
    fn signature_ignores_values() {
        let mut g2 = g1();
        g2.val[0] = 99.0;
        assert_eq!(graph_signature(&g1()), graph_signature(&g2));
    }

    #[test]
    fn signature_sensitive_to_structure() {
        let mut g2 = g1();
        g2.colind[0] = 2;
        assert_ne!(graph_signature(&g1()), graph_signature(&g2));

        let g3 = Csr::from_rows(3, vec![vec![], vec![(1, 1.0), (2, 2.0)], vec![]]);
        assert_ne!(graph_signature(&g1()), graph_signature(&g3));
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        let mut h = Fnv1a::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn device_signature_stable_and_named() {
        let a = device_signature("cpu", "1.0");
        let b = device_signature("cpu", "1.0");
        assert_eq!(a, b);
        assert!(a.starts_with("cpu-"));
        assert_ne!(a, device_signature("cpu", "2.0"));
    }
}
