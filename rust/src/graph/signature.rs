//! Content signatures for the persistent schedule cache (paper §4.2:
//! cache key = `(device_sig, graph_sig, F, op)`).
//!
//! FNV-1a over the CSR structure. The signature covers *structure*
//! (rowptr/colind) and dimensions, not edge values: the paper's scheduler
//! decisions depend on sparsity pattern, never on values.

use super::csr::Csr;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over a byte stream.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// Compact row-layout fingerprint: quantized bandwidth, head-block
/// density, and per-tile ELL fill (`METRIC_TILE_ROWS` tiles). NOT
/// folded into [`graph_signature`] — the full structure hash there
/// already separates any two row orders, and the signature runs in the
/// serving hot path where two extra O(nnz) passes would double its
/// cost. This digest exists for telemetry, `autosage data inspect`,
/// and as the layout key any future *sampled* signature must re-fold.
pub fn layout_digest(g: &Csr) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64((g.bandwidth_frac() * 1e6).round() as u64);
    h.write_u64((g.head_nnz_frac() * 1e6).round() as u64);
    h.write_u64((g.tile_fill(crate::graph::csr::METRIC_TILE_ROWS) * 1e6).round() as u64);
    h.finish()
}

/// Structural signature of a graph, hex-encoded. Covers dimensions and
/// the full rowptr/colind structure — which makes it row-LAYOUT
/// sensitive: a reordered layout (`data::reorder`) keys its own
/// schedule cache entries, and a reorder round-trip restores the
/// original key (tested below).
pub fn graph_signature(g: &Csr) -> String {
    let mut h = Fnv1a::new();
    h.write_u64(g.n_rows as u64);
    h.write_u64(g.n_cols as u64);
    h.write_u64(g.nnz() as u64);
    for &p in &g.rowptr {
        h.write_u64(p as u64);
    }
    for &c in &g.colind {
        h.write_u64(c as u64);
    }
    format!("{:016x}", h.finish())
}

/// Device signature: platform name/version + logical CPU count.
/// Encodes "device + toolchain minors" so stale cache entries from a
/// different machine are never reused (paper §12 Internal validity).
pub fn device_signature(platform: &str, version: &str) -> String {
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut h = Fnv1a::new();
    h.write(platform.as_bytes());
    h.write(version.as_bytes());
    h.write_u64(cpus as u64);
    format!("{}-{}cpu-{:08x}", platform, cpus, h.finish() as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g1() -> Csr {
        Csr::from_rows(3, vec![vec![(1, 1.0)], vec![(2, 2.0)], vec![]])
    }

    #[test]
    fn signature_deterministic() {
        assert_eq!(graph_signature(&g1()), graph_signature(&g1()));
    }

    #[test]
    fn signature_ignores_values() {
        let mut g2 = g1();
        g2.val[0] = 99.0;
        assert_eq!(graph_signature(&g1()), graph_signature(&g2));
    }

    #[test]
    fn signature_sensitive_to_structure() {
        let mut g2 = g1();
        g2.colind[0] = 2;
        assert_ne!(graph_signature(&g1()), graph_signature(&g2));

        let g3 = Csr::from_rows(3, vec![vec![], vec![(1, 1.0), (2, 2.0)], vec![]]);
        assert_ne!(graph_signature(&g1()), graph_signature(&g3));
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        let mut h = Fnv1a::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn empty_graph_signature_stable_and_distinct() {
        let empty = Csr::from_rows(0, vec![]);
        let s = graph_signature(&empty);
        assert_eq!(s, graph_signature(&empty));
        assert_eq!(s.len(), 16);
        // A 1-row edgeless graph is structurally different.
        let one = Csr::from_rows(1, vec![vec![]]);
        assert_ne!(s, graph_signature(&one));
    }

    #[test]
    fn all_self_loop_graph_signature() {
        let loops =
            Csr::from_rows(8, (0..8).map(|i| vec![(i as u32, 1.0)]).collect());
        let s = graph_signature(&loops);
        assert_eq!(s, graph_signature(&loops));
        // Shifting every loop off the diagonal changes the signature.
        let shifted = Csr::from_rows(
            8,
            (0..8).map(|i| vec![(((i + 1) % 8) as u32, 1.0)]).collect(),
        );
        assert_ne!(s, graph_signature(&shifted));
    }

    #[test]
    fn single_mega_hub_signature_sensitive_to_hub_position() {
        let hub_row = |at: usize| -> Csr {
            let rows = (0..16)
                .map(|i| {
                    if i == at {
                        (0..16).map(|c| (c as u32, 1.0)).collect()
                    } else {
                        vec![]
                    }
                })
                .collect();
            Csr::from_rows(16, rows)
        };
        // Same degree multiset, different row layout → different key.
        assert_ne!(
            graph_signature(&hub_row(0)),
            graph_signature(&hub_row(15))
        );
        assert_eq!(graph_signature(&hub_row(3)), graph_signature(&hub_row(3)));
    }

    #[test]
    fn signature_stable_across_reorder_roundtrip() {
        use crate::data::reorder::{reorder, ReorderPass};
        let g = crate::gen::hub_skew(128, 3, 0.1, 16, 5);
        let sig = graph_signature(&g);
        let r = reorder(&g, &[ReorderPass::HubPack, ReorderPass::SegmentSort]);
        // The reordered layout must key differently…
        assert_ne!(graph_signature(&r.graph), sig);
        // …and the round-trip must restore the exact original key.
        assert_eq!(graph_signature(&r.restore_graph()), sig);
        let digest = layout_digest(&g);
        assert_ne!(layout_digest(&r.graph), digest);
        assert_eq!(layout_digest(&r.restore_graph()), digest);
    }

    #[test]
    fn device_signature_stable_and_named() {
        let a = device_signature("cpu", "1.0");
        let b = device_signature("cpu", "1.0");
        assert_eq!(a, b);
        assert!(a.starts_with("cpu-"));
        assert_ne!(a, device_signature("cpu", "2.0"));
    }
}
