//! CSR sparse matrix: the canonical graph representation (paper §Notation:
//! `(rowptr, colind, val)`), plus the structure queries the scheduler's
//! feature extraction needs (degree quantiles, skew) and the induced
//! subgraph sampling the micro-probe needs.

use crate::util::rng::Rng;
use crate::util::stats;

/// Row-tile size the layout metrics model (matches the r=8 row tiles
/// the ELL kernels use). One shared constant so `scheduler::features`,
/// `signature::layout_digest`, and the `data::reorder` report can never
/// desynchronize on the tile width they measure.
pub const METRIC_TILE_ROWS: usize = 8;

/// CSR adjacency: row `i` owns `colind[rowptr[i]..rowptr[i+1]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub rowptr: Vec<usize>,
    pub colind: Vec<u32>,
    pub val: Vec<f32>,
}

impl Csr {
    /// Build from per-row adjacency lists (sorted for determinism).
    pub fn from_rows(n_cols: usize, rows: Vec<Vec<(u32, f32)>>) -> Csr {
        let n_rows = rows.len();
        let mut rowptr = Vec::with_capacity(n_rows + 1);
        let mut colind = Vec::new();
        let mut val = Vec::new();
        rowptr.push(0);
        for mut row in rows {
            row.sort_by_key(|(c, _)| *c);
            for (c, v) in row {
                assert!((c as usize) < n_cols, "col {c} >= n_cols {n_cols}");
                colind.push(c);
                val.push(v);
            }
            rowptr.push(colind.len());
        }
        Csr { n_rows, n_cols, rowptr, colind, val }
    }

    /// Validate structural invariants; used by tests and after loads.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.n_rows + 1 {
            return Err("rowptr length != n_rows + 1".into());
        }
        if self.rowptr[0] != 0 || *self.rowptr.last().unwrap() != self.colind.len() {
            return Err("rowptr endpoints wrong".into());
        }
        if self.colind.len() != self.val.len() {
            return Err("colind/val length mismatch".into());
        }
        for w in self.rowptr.windows(2) {
            if w[0] > w[1] {
                return Err("rowptr not monotone".into());
            }
        }
        if let Some(&c) = self.colind.iter().find(|&&c| c as usize >= self.n_cols)
        {
            return Err(format!("colind {c} out of range"));
        }
        Ok(())
    }

    pub fn nnz(&self) -> usize {
        self.colind.len()
    }

    pub fn degree(&self, row: usize) -> usize {
        self.rowptr[row + 1] - self.rowptr[row]
    }

    pub fn degrees(&self) -> Vec<usize> {
        (0..self.n_rows).map(|i| self.degree(i)).collect()
    }

    pub fn max_degree(&self) -> usize {
        self.degrees().into_iter().max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n_rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows as f64
        }
    }

    /// Degree quantile (type-7 interpolation), q in [0,1].
    pub fn degree_quantile(&self, q: f64) -> f64 {
        let degs: Vec<f64> = self.degrees().iter().map(|&d| d as f64).collect();
        if degs.is_empty() {
            return 0.0;
        }
        stats::quantile(&degs, q)
    }

    /// Row slice accessors.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.rowptr[i], self.rowptr[i + 1]);
        (&self.colind[a..b], &self.val[a..b])
    }

    /// Micro-probe workload: sample `k` rows (without replacement, seeded)
    /// and keep their full adjacency lists, remapping column ids into the
    /// probe's index space (`col % k`).  Row *degrees* — the quantity that
    /// drives kernel cost — are preserved exactly; semantics are not,
    /// which is fine: the probe is a timing device, not a compute result
    /// (paper §4.2 "induced subgraph").
    pub fn probe_sample(&self, k: usize, seed: u64) -> Csr {
        let k = k.min(self.n_rows).max(1);
        let mut rng = Rng::new(seed);
        let mut picks = rng.sample_distinct(self.n_rows, k);
        picks.sort_unstable();
        let rows = picks
            .iter()
            .map(|&r| {
                let (cols, vals) = self.row(r);
                cols.iter()
                    .zip(vals)
                    .map(|(&c, &v)| ((c as usize % k) as u32, v))
                    .collect()
            })
            .collect();
        Csr::from_rows(k, rows)
    }

    // ------------------------------------------------ layout metrics
    // Row-order-sensitive structure queries: unlike degrees/quantiles
    // they change under row permutation, which makes them the scorecard
    // for `data::reorder` passes and layout features for the scheduler.

    /// Mean |row - col| over stored edges, normalized by the node span
    /// (0 = diagonal band, → 1 = anti-diagonal scatter).
    pub fn bandwidth_frac(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            return 0.0;
        }
        let span = self.n_rows.max(self.n_cols).saturating_sub(1).max(1) as f64;
        let mut sum = 0.0f64;
        for i in 0..self.n_rows {
            let (cols, _) = self.row(i);
            for &c in cols {
                sum += ((i as f64) - (c as f64)).abs();
            }
        }
        sum / nnz as f64 / span
    }

    /// Fraction of nnz owned by the first ceil(1%) of rows — the
    /// head/hub-block density that degree-packing reorders maximize.
    pub fn head_nnz_frac(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 || self.n_rows == 0 {
            return 0.0;
        }
        let k = self.n_rows.div_ceil(100).min(self.n_rows);
        let head: usize = (0..k).map(|i| self.degree(i)).sum();
        head as f64 / nnz as f64
    }

    /// ELL fill when rows are tiled in groups of `r` with per-tile
    /// width = tile max degree: `nnz / padded slots` (1.0 = no waste).
    /// The quantity degree-bucket segment sort improves.
    pub fn tile_fill(&self, r: usize) -> f64 {
        if self.n_rows == 0 || self.nnz() == 0 {
            return 1.0;
        }
        let r = r.max(1);
        let mut padded = 0usize;
        let mut i = 0;
        while i < self.n_rows {
            let end = (i + r).min(self.n_rows);
            let wmax = (i..end).map(|j| self.degree(j)).max().unwrap_or(0);
            padded += (end - i) * wmax;
            i = end;
        }
        if padded == 0 {
            1.0
        } else {
            self.nnz() as f64 / padded as f64
        }
    }

    /// Dense row-major materialization (test oracle only; O(n^2)).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut out = vec![vec![0.0; self.n_cols]; self.n_rows];
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out[i][c as usize] += v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Csr {
        // rows: {0:[1,2], 1:[], 2:[0], 3:[0,1,2,3]}
        Csr::from_rows(
            4,
            vec![
                vec![(1, 1.0), (2, 2.0)],
                vec![],
                vec![(0, 3.0)],
                vec![(3, 4.0), (0, 5.0), (1, 6.0), (2, 7.0)],
            ],
        )
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        g.validate().unwrap();
        assert_eq!(g.nnz(), 7);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 0);
        assert_eq!(g.degree(3), 4);
        assert_eq!(g.max_degree(), 4);
        assert!((g.avg_degree() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn rows_sorted_by_column() {
        let g = tiny();
        let (cols, vals) = g.row(3);
        assert_eq!(cols, &[0, 1, 2, 3]);
        assert_eq!(vals, &[5.0, 6.0, 7.0, 4.0]);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = tiny();
        g.colind[0] = 99;
        assert!(g.validate().is_err());
        let mut g2 = tiny();
        g2.rowptr[2] = 0;
        assert!(g2.validate().is_err());
    }

    #[test]
    fn degree_quantiles() {
        let g = tiny();
        // degrees [2, 0, 1, 4]
        assert_eq!(g.degree_quantile(0.0), 0.0);
        assert_eq!(g.degree_quantile(1.0), 4.0);
        assert_eq!(g.degree_quantile(0.5), 1.5);
    }

    #[test]
    fn probe_sample_preserves_degrees() {
        let g = tiny();
        let p = g.probe_sample(4, 1);
        p.validate().unwrap();
        assert_eq!(p.n_rows, 4);
        let mut got: Vec<usize> = p.degrees();
        let mut want = g.degrees();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn probe_sample_subset_and_deterministic() {
        let mut rows = Vec::new();
        for i in 0..100u32 {
            rows.push(vec![((i * 7 % 100), 1.0f32), ((i * 13 % 100), 2.0)]);
        }
        let g = Csr::from_rows(100, rows);
        let a = g.probe_sample(10, 42);
        let b = g.probe_sample(10, 42);
        assert_eq!(a, b);
        let c = g.probe_sample(10, 43);
        assert_ne!(a, c);
        assert_eq!(a.n_rows, 10);
        assert!(a.colind.iter().all(|&c| c < 10));
    }

    #[test]
    fn layout_metrics_respond_to_row_order() {
        // 16 rows: row 0 is wide, the rest have one diagonal edge.
        let mut rows: Vec<Vec<(u32, f32)>> =
            (0..16).map(|i| vec![(i as u32, 1.0)]).collect();
        rows[15] = (0..8).map(|c| (c as u32, 1.0)).collect();
        let g = Csr::from_rows(16, rows.clone());
        // Hub at the bottom: head (1 row) owns 1/23 of nnz.
        assert!(g.head_nnz_frac() < 0.1, "{}", g.head_nnz_frac());
        // Same rows with the hub first.
        rows.rotate_right(1);
        let packed = Csr::from_rows(16, rows);
        assert!(packed.head_nnz_frac() > 0.3, "{}", packed.head_nnz_frac());
        assert_eq!(g.nnz(), packed.nnz());
        // Tile fill: hub row inflates its 8-row tile either way, but
        // the metric must be a valid ratio and move with the layout.
        let (a, b) = (g.tile_fill(8), packed.tile_fill(8));
        assert!((0.0..=1.0).contains(&a) && (0.0..=1.0).contains(&b));
        // Bandwidth: diagonal rows are 0-distance; the hub contributes
        // more distance sitting at row 15 than at row 0... both valid.
        assert!((0.0..=1.0).contains(&g.bandwidth_frac()));
    }

    #[test]
    fn layout_metrics_degenerate_inputs() {
        let empty = Csr::from_rows(0, vec![]);
        assert_eq!(empty.bandwidth_frac(), 0.0);
        assert_eq!(empty.head_nnz_frac(), 0.0);
        assert_eq!(empty.tile_fill(8), 1.0);
        let no_edges = Csr::from_rows(3, vec![vec![], vec![], vec![]]);
        assert_eq!(no_edges.head_nnz_frac(), 0.0);
        assert_eq!(no_edges.tile_fill(0), 1.0); // edgeless: no waste
        let diag = Csr::from_rows(4, (0..4).map(|i| vec![(i as u32, 1.0)]).collect());
        assert_eq!(diag.bandwidth_frac(), 0.0);
        assert_eq!(diag.tile_fill(2), 1.0);
    }

    #[test]
    fn to_dense_matches() {
        let g = tiny();
        let d = g.to_dense();
        assert_eq!(d[0][1], 1.0);
        assert_eq!(d[2][0], 3.0);
        assert_eq!(d[1], vec![0.0; 4]);
    }
}
