//! Sparse-graph substrate: CSR canonical form, padded ELL/COO buckets
//! (the static-shape encodings the AOT kernels consume), hub partition,
//! and content signatures for the schedule cache.

pub mod csr;
pub mod ell;
pub mod signature;

pub use csr::Csr;
pub use ell::{CooBuffers, EllBuffers, HubSplit};
